"""BENCH: per-stage analysis throughput over the fig14 workloads.

Seeds the repo's performance trajectory: times every stage of the columnar
trace -> IDG -> selection -> pricing pipeline (instructions/second each),
the end-to-end cold fig14-equivalent sweep, and the persisted layer-1
footprint — and compares against the recorded pre-columnar baseline
(PR-5 seed, measured on the same class of machine immediately before the
struct-of-arrays refactor).

    PYTHONPATH=src python -m benchmarks.run --timing-json BENCH_analysis.json
    PYTHONPATH=src python -m benchmarks.run --timing-json out.json \\
        --timing-workloads NB \\
        --timing-gate benchmarks/baselines/timing_nb.json   # CI gate

Most numbers are record-only (uploaded as a CI artifact so regressions
show up as a trend), but ``--timing-gate BASELINE`` turns the selection
and pricing throughputs — numpy *and* the ``EVA_CIM_ACCEL=jax`` selection
path (``select_jax``) — into a hard gate: the run fails if any drops
more than :data:`GATE_THRESHOLD` below the committed baseline.  Raw
wall-clock is meaningless across machines, so both the baseline and the
measuring run carry a ``machine_calibration`` score from a fixed numpy
kernel (:func:`calibrate`) and the baseline throughput is scaled by the
score ratio before comparison.
"""
from __future__ import annotations

import json
import pathlib
import tempfile
import time
from typing import Dict, List, Optional, Sequence

from benchmarks.common import SWEEP_BENCHES, banner

# Pre-refactor reference, measured at the PR-5 seed (object-based trace
# core) on this repo's CI-class container immediately before the columnar
# rewrite: the 27-point fig14 cold sweep and the pickled layer-1 artifacts
# (trace + flow) for the nine sweep workloads under 32K+256K.
BASELINE = {
    "fig14_cold_s": 16.22,
    "layer1_bytes": 11_284_089,
    "layer1_insts": 171_344,
}

FIG14_CACHES = ("32K+256K", "64K+256K", "64K+2M")

# the gated stages: selection + pricing throughput (ISSUE 6) and the jax
# selection path (ISSUE 7) may not drop more than this fraction below the
# calibration-scaled committed baseline
GATE_STAGES = ("select", "price", "select_jax")
GATE_THRESHOLD = 0.25


def _time(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def _best_of(fn, repeats: int = 3):
    """Best-of-N wall time — the gated stages are fast enough that a single
    sample is scheduler noise; min-of-3 is what the gate compares."""
    out, best = None, float("inf")
    for _ in range(repeats):
        out, dt = _time(fn)
        best = min(best, dt)
    return out, best


def calibrate(repeats: int = 3) -> Dict:
    """Machine-speed score from a fixed numpy kernel.

    The kernel mirrors the columnar selection/pricing mix — sort, scan,
    masked reductions over a ~1M-element array — so its throughput tracks
    how fast *this* machine runs the gated stages.  Committed baselines
    store their score; the gate scales baseline throughput by
    ``score_now / score_then`` before comparing, making the 25% threshold
    portable across container generations.
    """
    import numpy as np
    rng = np.random.default_rng(12345)
    a = rng.standard_normal(1_000_000)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        s = np.sort(a)
        c = np.cumsum(s)
        m = (a > 0.0)
        _ = float(c[m[: c.size]].sum()) + float(np.count_nonzero(m))
        best = min(best, time.perf_counter() - t0)
    return {"kernel": "sort+cumsum+masked-reduce@1M",
            "score": round(1_000_000 / best / 1e6, 2)}   # M elements/s


def gate(doc: Dict, baseline: Dict,
         threshold: float = GATE_THRESHOLD) -> List[str]:
    """Compare a fresh timing doc against a committed baseline doc.

    Returns human-readable failure strings (empty == pass).  Only docs
    measured over the same workload set are comparable; anything else is
    itself a failure so CI can't silently gate against stale baselines.
    """
    failures: List[str] = []
    if list(baseline.get("workloads", [])) != list(doc["workloads"]):
        return [f"baseline workloads {baseline.get('workloads')} != "
                f"measured {doc['workloads']} — re-record the baseline"]
    base_cal = baseline.get("machine_calibration", {}).get("score")
    if not base_cal:
        return ["baseline has no machine_calibration score — re-record it "
                "with this version of benchmarks/analysis_timing.py"]
    scale = doc["machine_calibration"]["score"] / base_cal
    for stage in GATE_STAGES:
        cur = doc["totals"].get(f"{stage}_ips")
        base = baseline["totals"].get(f"{stage}_ips")
        if not cur or not base:
            failures.append(f"{stage}: missing {stage}_ips in doc/baseline")
            continue
        floor = base * scale * (1.0 - threshold)
        if cur < floor:
            failures.append(
                f"{stage}: {cur:,.0f} inst/s < floor {floor:,.0f} "
                f"(baseline {base:,.0f} x calib {scale:.2f} x "
                f"{1.0 - threshold:.2f}) — "
                f"{(1 - cur / (base * scale)) * 100:.0f}% regression")
    return failures


def run(workloads: Optional[Sequence[str]] = None,
        json_path: Optional[str] = None,
        trace_path: Optional[str] = None) -> Dict:
    from repro import obs
    from repro.core.offload import OffloadConfig, analyze_trace
    from repro.core.profiler import profile_system
    from repro.core.reshape import reshape
    from repro.core.trace import attach_cache_results, trace_structural
    from repro.dse import AnalysisStore, DSEEngine, SweepSpace
    from repro.dse.space import CACHE_PRESETS, CacheOption
    from repro.workloads import build

    from repro.core import accel

    workloads = tuple(workloads or SWEEP_BENCHES)
    full_set = workloads == tuple(SWEEP_BENCHES)
    cfg = OffloadConfig()

    # --trace records the run as a Chrome trace-event file; the per-stage
    # loops below call the analysis functions directly (few spans), but
    # the cold fig14 sweeps exercise the fully instrumented engine path
    if trace_path:
        obs.enable(obs.Tracer())

    stages: Dict[str, Dict] = {}
    totals = {"n_instructions": 0, "trace_s": 0.0, "replay_s": 0.0,
              "idg_s": 0.0, "select_s": 0.0, "select_jax_s": 0.0,
              "price_s": 0.0}
    for name in workloads:
        fn, args = build(name)
        trace_structural(fn, *args)          # warm the jit oracles once
        st, trace_s = _time(lambda: trace_structural(fn, *args))
        n = st.n_instructions
        replay_s = 0.0
        trs = []
        for cname in FIG14_CACHES:
            tr, dt = _time(lambda: attach_cache_results(
                st, CACHE_PRESETS[cname]))
            replay_s += dt
            trs.append(tr)
        an, idg_s = _time(lambda: analyze_trace(trs[0]))
        (res, rs), select_s = _best_of(
            lambda: (lambda r: (r, reshape(trs[0].trace, r)))(an.select(cfg)))
        # same selection through the jax placement kernel (best-of-N, so
        # the first repeat absorbs any jit compile; the partition memo is
        # warm either way, exactly like the numpy measurement above)
        with accel.use_backend("jax"):
            _, select_jax_s = _best_of(
                lambda: (lambda r: (r, reshape(trs[0].trace, r)))(
                    an.select(cfg)))
        rep, price_s = _best_of(lambda: profile_system(
            trs[0], offload=res, reshaped=rs))
        stages[name] = {
            "n_instructions": n,
            "trace_s": round(trace_s, 4),
            "trace_ips": round(n / trace_s),
            "replay_s_per_geometry": round(replay_s / len(FIG14_CACHES), 4),
            "idg_s": round(idg_s, 4),
            "idg_ips": round(n / idg_s) if idg_s else None,
            "select_s": round(select_s, 4),
            "select_ips": round(n / select_s) if select_s else None,
            "select_jax_s": round(select_jax_s, 4),
            "select_jax_ips": (round(n / select_jax_s)
                               if select_jax_s else None),
            "price_s": round(price_s, 4),
            "price_ips": round(n / price_s) if price_s else None,
            "energy_improvement": round(rep.energy_improvement, 3),
        }
        totals["n_instructions"] += n
        totals["trace_s"] += trace_s
        totals["replay_s"] += replay_s
        totals["idg_s"] += idg_s
        totals["select_s"] += select_s
        totals["select_jax_s"] += select_jax_s
        totals["price_s"] += price_s
    for k in list(totals):
        if k.endswith("_s"):
            totals[k] = round(totals[k], 4)
    for stage in GATE_STAGES:       # aggregate throughput the gate compares
        dt = totals[f"{stage}_s"]
        totals[f"{stage}_ips"] = (round(totals["n_instructions"] / dt)
                                  if dt else None)
    # each stage's share of the (numpy-path) pipeline, so "X is the
    # dominant stage" is generated from the measurement, never hand-written
    pipeline = ("trace", "replay", "idg", "select", "price")
    pipeline_s = sum(totals[f"{s}_s"] for s in pipeline)
    totals["pipeline_s"] = round(pipeline_s, 4)
    totals["share"] = {s: round(totals[f"{s}_s"] / pipeline_s, 3)
                       for s in pipeline} if pipeline_s else {}
    if totals["share"]:
        totals["dominant_stage"] = max(totals["share"],
                                       key=totals["share"].get)

    # ---- end-to-end: cold fig14-equivalent sweep (fresh engine) ---------
    space = SweepSpace(workloads=workloads, caches=FIG14_CACHES)
    results, cold_s = _time(lambda: DSEEngine().run(space))
    cold = {
        "points": len(results),
        "wall_s": round(cold_s, 3),
        "instructions_per_s": round(
            sum(r.n_instructions for r in results) / cold_s),
    }
    if full_set:
        cold["baseline_wall_s"] = BASELINE["fig14_cold_s"]
        cold["improvement_x"] = round(BASELINE["fig14_cold_s"] / cold_s, 2)
    # the same cold sweep under EVA_CIM_ACCEL=jax: one batched replay per
    # workload instead of one per geometry.  Record-only — on CPU the
    # scan-based replay kernel roughly breaks even with the optimized
    # numpy replay (the trace VM dominates the cold path), so the honest
    # numbers are the jit-cost-included first run and the warm-jit rerun
    # a resident daemon actually sees.
    with accel.use_backend("jax"):
        eng_j = DSEEngine()
        _, cold_jax_s = _time(lambda: eng_j.run(space))
        cold["jax_wall_s"] = round(cold_jax_s, 3)
        cold["jax_replay_batches"] = eng_j.analysis.stats().get(
            "replay_batches", 0)
        eng_j2 = DSEEngine()
        _, warm_jit_s = _time(lambda: eng_j2.run(space))
        cold["jax_wall_warm_jit_s"] = round(warm_jit_s, 3)

    # ---- persisted layer-1 footprint (.npz columns + flow) --------------
    with tempfile.TemporaryDirectory() as tmp:
        store = AnalysisStore(tmp)
        option = CacheOption.of("32K+256K")
        from repro.dse import AnalysisCache
        cache = AnalysisCache(store=store)
        for name in workloads:
            cache.trace_analysis(name, option)
        usage = store.disk_usage()
    blob = {
        "layer1_bytes": usage["store_bytes_layer1"],
        "bytes_per_instruction": round(
            usage["store_bytes_layer1"] / max(1, totals["n_instructions"]),
            1),
    }
    if full_set:
        blob["baseline_bytes"] = BASELINE["layer1_bytes"]
        blob["shrink_x"] = round(
            BASELINE["layer1_bytes"] / usage["store_bytes_layer1"], 2)

    doc = {"workloads": list(workloads), "full_fig14_set": full_set,
           "machine_calibration": calibrate(),
           "stages": stages, "totals": totals, "cold_sweep": cold,
           "layer1_store": blob}
    if trace_path:
        n_events = obs.tracer().export_chrome(trace_path)
        doc["trace"] = {"path": str(trace_path), "events": n_events}
        obs.disable()
    if json_path:
        pathlib.Path(json_path).write_text(json.dumps(doc, indent=1))
    return doc


def main(workloads: Optional[Sequence[str]] = None,
         json_path: Optional[str] = None,
         gate_path: Optional[str] = None,
         trace_path: Optional[str] = None):
    banner("BENCH: columnar analysis pipeline throughput")
    doc = run(workloads=workloads, json_path=json_path,
              trace_path=trace_path)
    for name, s in doc["stages"].items():
        print(f"  {name:8s} n={s['n_instructions']:6d}  "
              f"trace {s['trace_ips']:>9,}/s  "
              f"idg {s['idg_ips']:>10,}/s  "
              f"select {s['select_ips']:>9,}/s  "
              f"select-jax {s['select_jax_ips']:>9,}/s  "
              f"price {s['price_ips']:>10,}/s")
    share = doc["totals"].get("share", {})
    if share:
        print("  stage shares: " + "  ".join(
            f"{s} {frac:.1%}" for s, frac in share.items())
            + f"  (dominant: {doc['totals']['dominant_stage']})")
    cold = doc["cold_sweep"]
    line = (f"  cold sweep: {cold['points']} points in {cold['wall_s']}s "
            f"({cold['instructions_per_s']:,} inst/s)")
    if "improvement_x" in cold:
        line += (f"  [baseline {cold['baseline_wall_s']}s -> "
                 f"x{cold['improvement_x']}]")
    line += (f"  [jax {cold['jax_wall_s']}s cold-jit, "
             f"{cold['jax_wall_warm_jit_s']}s warm-jit, "
             f"{cold['jax_replay_batches']} batched replays]")
    print(line)
    blob = doc["layer1_store"]
    line = (f"  layer-1 store: {blob['layer1_bytes']:,} bytes "
            f"({blob['bytes_per_instruction']} B/inst)")
    if "shrink_x" in blob:
        line += (f"  [baseline {blob['baseline_bytes']:,} -> "
                 f"x{blob['shrink_x']} smaller]")
    print(line)
    if json_path:
        print(f"  [json] {json_path}")
    if trace_path:
        print(f"  [trace] {trace_path}: {doc['trace']['events']} events "
              f"(load in ui.perfetto.dev)")
    if gate_path:
        baseline = json.loads(pathlib.Path(gate_path).read_text())
        failures = gate(doc, baseline)
        doc["gate"] = {"baseline": str(gate_path),
                       "threshold": GATE_THRESHOLD,
                       "stages": list(GATE_STAGES),
                       "calibration_scale": round(
                           doc["machine_calibration"]["score"]
                           / baseline.get("machine_calibration",
                                          {}).get("score", 1) or 1, 3),
                       "failures": failures}
        if json_path:       # re-write with the verdict attached
            pathlib.Path(json_path).write_text(json.dumps(doc, indent=1))
        for f in failures:
            print(f"  GATE FAIL: {f}")
        if not failures:
            scale = doc["gate"]["calibration_scale"]
            print(f"  gate: select+price+select_jax within "
                  f"{GATE_THRESHOLD:.0%} of "
                  f"{gate_path} (calibration scale x{scale}) — passed")
    return doc


if __name__ == "__main__":
    main(json_path="BENCH_analysis.json")
