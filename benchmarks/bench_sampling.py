"""BENCH: statistical sampling — accuracy on the suite, speedup at scale.

Two claims make sampled analysis trustworthy, and this benchmark measures
and gates both:

1. **Suite accuracy** — across the Table-IV kernel suite, pricing through
   ``SamplingSpec`` (phase and stratified modes, default knobs) must agree
   with the exact pipeline to within 2% relative error on energy
   improvement and MACR.  Registry-sized kernels fit inside
   ``interval * budget``, so the plan degenerates to full coverage and the
   agreement is exact (0.000%) — the gate proves the sampled path *is* the
   identity when coverage is complete, with real sampling error bounded by
   the synthetic probe below.

2. **Speedup at scale** — a loop-scaled synthetic workload
   (``KM@256`` ~7.6M virtual instructions by default) must price >= 10x
   faster through sampling than through the exact pipeline, and the
   structural skim must walk virtual instructions >= 10x faster than the
   full trace VM emits rows.  The sampled-vs-exact error on the synthetic
   is *recorded* alongside (dominated by cold-window cache state; the
   ``warmup`` knob trades it against speed — see docs/architecture.md).

Results land in ``BENCH_sampling.json``::

    PYTHONPATH=src python -m benchmarks.bench_sampling
    PYTHONPATH=src python -m benchmarks.bench_sampling \\
        --workloads NB,LCS,KM --synthetic KM@256 --json BENCH_sampling.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time
from typing import Dict, List, Optional

from benchmarks.common import banner
from repro.core.cache import L1_32K, L2_256K
from repro.core.offload import OffloadConfig, analyze_trace
from repro.core.profiler import profile_system
from repro.core.reshape import reshape
from repro.core.sampling import (SamplingSpec, build_workload, sampled_report,
                                 skim_program)
from repro.core.trace import (TraceLimits, attach_cache_results,
                              trace_structural)

LEVELS = (L1_32K, L2_256K)
CFG = OffloadConfig()
LIMITS = TraceLimits(max_instructions=1 << 62)

SUITE_TOL = 0.02              # gate 1: suite relative error on EI and MACR
SPEEDUP_MIN = 10.0            # gate 2: sampled vs exact wall-clock
SKIM_RATE_MIN = 10.0          # gate 2b: skim rate vs full-trace row rate

#: the synthetic probe's sampling spec — larger windows + warmup than the
#: defaults, trading some speed for representative cache/register state
SYNTH_SPEC = dict(interval=32768, budget=16, warmup=32768)


def _exact(workload: str):
    fn, args = build_workload(workload)
    t0 = time.perf_counter()
    st = trace_structural(fn, *args, limits=LIMITS)
    t_trace = time.perf_counter() - t0
    tr = attach_cache_results(st, LEVELS)
    analysis = analyze_trace(tr)
    result = analysis.select(CFG)
    rep = profile_system(tr, offload=result,
                         reshaped=reshape(analysis.trace, result))
    return rep, time.perf_counter() - t0, t_trace, st.columns.n


def _rel(est: float, ref: float) -> float:
    return abs(est - ref) / max(abs(ref), 1e-12)


def suite_accuracy(workloads: List[str]) -> Dict:
    """Gate 1: sampled-vs-exact error per suite kernel, both modes."""
    rows = []
    worst = 0.0
    for wl in workloads:
        rep, _, _, _ = _exact(wl)
        row = {"workload": wl, "exact_ei": rep.energy_improvement,
               "exact_macr": rep.macr}
        for mode in ("phase", "stratified"):
            est = sampled_report(wl, SamplingSpec(mode=mode), LEVELS, CFG)
            e_ei = _rel(est.metrics["energy_improvement"],
                        rep.energy_improvement)
            e_macr = _rel(est.metrics["macr"], rep.macr)
            worst = max(worst, e_ei, e_macr)
            row[mode] = {"ei_err": round(e_ei, 6),
                         "macr_err": round(e_macr, 6),
                         "n_windows": est.n_windows,
                         "n_intervals": est.n_intervals,
                         "ei_ci": round(est.ci["energy_improvement"], 6)}
        rows.append(row)
        print(f"  {wl:8s} phase ei/macr err "
              f"{row['phase']['ei_err']:.4%}/{row['phase']['macr_err']:.4%}"
              f"  stratified {row['stratified']['ei_err']:.4%}/"
              f"{row['stratified']['macr_err']:.4%}", flush=True)
    return {"rows": rows, "worst_rel_err": round(worst, 6)}


def synthetic_speedup(workload: str) -> Dict:
    """Gate 2: wall-clock and skim-rate advantage on a >=10^6-instruction
    loop-scaled workload, with the sampled-vs-exact error recorded."""
    fn, args = build_workload(workload)
    t0 = time.perf_counter()
    skim = skim_program(fn, *args, interval=SYNTH_SPEC["interval"])
    t_skim = time.perf_counter() - t0
    skim_rate = skim.total_virtual / max(t_skim, 1e-9)

    rep, t_exact, t_trace, n_rows = _exact(workload)
    trace_rate = skim.total_virtual / max(t_trace, 1e-9)

    out = {"workload": workload, "virtual_instructions": skim.total_virtual,
           "exact_rows": int(n_rows),
           "exact_s": round(t_exact, 3), "trace_s": round(t_trace, 3),
           "skim_s": round(t_skim, 3), "skim_rate_per_s": int(skim_rate),
           "trace_rate_per_s": int(trace_rate),
           "skim_rate_x": round(skim_rate / trace_rate, 2),
           "spec": dict(SYNTH_SPEC), "modes": {}}
    for mode in ("phase", "stratified"):
        spec = SamplingSpec(mode=mode, **SYNTH_SPEC)
        t0 = time.perf_counter()
        est = sampled_report(workload, spec, LEVELS, CFG)
        t_s = time.perf_counter() - t0
        out["modes"][mode] = {
            "sampled_s": round(t_s, 3),
            "speedup_x": round(t_exact / t_s, 2),
            "n_windows": est.n_windows, "n_intervals": est.n_intervals,
            "ei_err": round(_rel(est.metrics["energy_improvement"],
                                 rep.energy_improvement), 6),
            "macr_err": round(_rel(est.metrics["macr"], rep.macr), 6),
            "ei_ci": round(est.ci["energy_improvement"], 6)}
        m = out["modes"][mode]
        print(f"  {mode:10s} {t_s:6.2f}s vs exact {t_exact:.2f}s "
              f"-> {m['speedup_x']:.1f}x  (ei err {m['ei_err']:.2%}, "
              f"macr err {m['macr_err']:.2%})", flush=True)
    print(f"  skim: {skim.total_virtual:,} virtual instrs at "
          f"{int(skim_rate):,}/s = {out['skim_rate_x']:.1f}x the "
          f"full-trace rate", flush=True)
    return out


def check(doc: Dict) -> List[str]:
    failures = []
    worst = doc["suite"]["worst_rel_err"]
    if worst > SUITE_TOL:
        failures.append(f"suite accuracy: worst relative error {worst:.4%} "
                        f"> {SUITE_TOL:.0%}")
    syn = doc["synthetic"]
    if syn["virtual_instructions"] < 1_000_000:
        failures.append(f"synthetic workload too small: "
                        f"{syn['virtual_instructions']:,} < 1,000,000 "
                        f"virtual instructions")
    best = max(m["speedup_x"] for m in syn["modes"].values())
    if best < SPEEDUP_MIN:
        failures.append(f"synthetic speedup {best:.1f}x < {SPEEDUP_MIN}x")
    if syn["skim_rate_x"] < SKIM_RATE_MIN:
        failures.append(f"skim rate {syn['skim_rate_x']:.1f}x full-trace "
                        f"rate < {SKIM_RATE_MIN}x")
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workloads", default=None,
                    help="comma-separated suite kernels for the accuracy "
                         "gate (default: the whole Table-IV registry)")
    ap.add_argument("--synthetic", default="KM@256",
                    help="loop-scaled 'name@scale' workload for the "
                         "speedup gate (>= 10^6 virtual instructions)")
    ap.add_argument("--json", default="BENCH_sampling.json")
    ap.add_argument("--no-check", action="store_true",
                    help="record only; skip the accuracy/speedup gates")
    args = ap.parse_args(argv)

    from repro.workloads import WORKLOADS
    workloads = (args.workloads.split(",") if args.workloads
                 else sorted(WORKLOADS))

    banner("BENCH: statistical sampling — accuracy and speedup")
    print(f"[1/2] suite accuracy ({len(workloads)} kernels, "
          f"default SamplingSpec)", flush=True)
    t0 = time.perf_counter()
    suite = suite_accuracy(workloads)
    print(f"  worst relative error: {suite['worst_rel_err']:.4%}")
    print(f"[2/2] synthetic speedup ({args.synthetic})", flush=True)
    synthetic = synthetic_speedup(args.synthetic)
    doc = {"suite": suite, "synthetic": synthetic,
           "gates": {"suite_tol": SUITE_TOL, "speedup_min": SPEEDUP_MIN,
                     "skim_rate_min": SKIM_RATE_MIN},
           "elapsed_s": round(time.perf_counter() - t0, 1)}
    if args.json:
        pathlib.Path(args.json).write_text(json.dumps(doc, indent=1))
        print(f"  [json] {args.json}")
    if not args.no_check:
        failures = check(doc)
        for f in failures:
            print(f"  FAIL: {f}")
        if failures:
            return 1
        print(f"  gates: suite err <= {SUITE_TOL:.0%}, speedup >= "
              f"{SPEEDUP_MIN:.0f}x, skim rate >= {SKIM_RATE_MIN:.0f}x "
              f"— all passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
