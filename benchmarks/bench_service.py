"""BENCH: DSE-service load generation — "heavy traffic" with a number.

N concurrent clients sweep *overlapping* design spaces against one
daemon, so the same canonical ``SweepPoint.key``s arrive from many
requests at once; the daemon's coalescing stack (record memo +
single-flight + warm analysis cache) must collapse them to one
evaluation per unique key.  The benchmark measures and asserts exactly
that:

* **requests/sec, p50/p99 latency** over the whole storm,
* **dedup ratio** — points requested / points evaluated (> 1.5× with the
  default overlapping spaces),
* **evaluations == unique keys** — the daemon never computed a design
  twice,
* **warm repeat** — an exhaustive sweep re-issued against the warm
  daemon performs zero new trace builds and zero new evaluations.

Results land in ``BENCH_service.json``.  By default the daemon runs
in-process (deterministic for CI); ``--url`` points the storm at an
externally started ``python -m repro.dse.service`` instead — the CI
service smoke job uses that to exercise the real process + SIGTERM
path::

    PYTHONPATH=src python -m benchmarks.bench_service
    PYTHONPATH=src python -m benchmarks.bench_service \\
        --clients 8 --json BENCH_service.json
    PYTHONPATH=src python -m benchmarks.bench_service \\
        --url http://127.0.0.1:8321 --workloads NB
"""
from __future__ import annotations

import argparse
import contextlib
import json
import pathlib
import statistics
import threading
import time
from typing import Dict, List, Optional, Sequence

from benchmarks.common import banner
from repro.dse import SweepSpace
from repro.dse.service import ServiceClient, running_server

CACHES = ("32K+256K", "64K+256K", "64K+2M")
LEVELS = ("L1_only", "L2_only", "both")
TECHS = ("sram", "fefet")

# reserved for the coalesce probe: never part of the main storm, so its
# analysis keys are guaranteed cold when the probe fires
PROBE_WORKLOAD = "DT"


def client_space(client_id: int, workloads: Sequence[str]) -> Dict:
    """The request document for one client: a rotated, truncated slice of
    the full axis grid — every client overlaps its neighbors on most keys
    but no two slices are identical."""
    def rotate(axis: Sequence[str], k: int) -> List[str]:
        k = k % len(axis)
        return list(axis[k:] + axis[:k])

    caches = rotate(CACHES, client_id)[: 2 + client_id % 2]
    levels = rotate(LEVELS, client_id // 2)[: 2 + (client_id + 1) % 2]
    return {"workloads": list(workloads), "caches": caches,
            "cim_levels": levels, "techs": list(TECHS), "mode": "sweep"}


def unique_keys(requests: Sequence[Dict]) -> int:
    """How many distinct canonical designs the storm asks for in total —
    computed client-side from the same SweepSpace enumeration the daemon
    uses, so `evaluated == unique` is an exact cross-check."""
    keys = set()
    for doc in requests:
        space = SweepSpace(workloads=tuple(doc["workloads"]),
                           caches=tuple(doc["caches"]),
                           cim_levels=tuple(doc["cim_levels"]),
                           techs=tuple(doc["techs"]))
        keys.update(p.key for p in space.points())
    return len(keys)


def run(url: Optional[str] = None, clients: int = 8,
        requests_per_client: int = 2,
        workloads: Sequence[str] = ("NB", "LCS"),
        cache_dir: Optional[str] = None,
        json_path: Optional[str] = None) -> Dict:
    ctx = (contextlib.nullcontext((url, None)) if url
           else running_server(cache_dir=cache_dir, max_workers=4))
    with ctx as (base_url, _service):
        client = ServiceClient(base_url)
        client.wait_ready()
        m0 = client.metrics()

        # ---- the storm: clients * requests, overlapping spaces ---------
        docs = [client_space(i % clients, workloads)
                for i in range(clients * requests_per_client)]
        latencies: List[float] = []
        trace_ids: List[Optional[str]] = []
        errors: List[str] = []
        lock = threading.Lock()
        barrier = threading.Barrier(clients)

        def one_client(cid: int) -> None:
            local = ServiceClient(base_url)
            barrier.wait()                   # all clients fire together
            for rid in range(requests_per_client):
                doc = docs[cid * requests_per_client + rid]
                t0 = time.perf_counter()
                try:
                    reply = local.sweep(doc["workloads"],
                                        caches=doc["caches"],
                                        cim_levels=doc["cim_levels"],
                                        techs=doc["techs"])
                except Exception as exc:  # noqa: BLE001 — reported below
                    with lock:
                        errors.append(f"client {cid} req {rid}: {exc}")
                    return
                with lock:
                    latencies.append(time.perf_counter() - t0)
                    trace_ids.append(reply.trace_id)

        threads = [threading.Thread(target=one_client, args=(i,))
                   for i in range(clients)]
        t_start = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        storm_s = time.perf_counter() - t_start
        if errors:
            raise RuntimeError("bench clients failed: " + "; ".join(errors))

        # ---- per-request traces: distinct ids, last one queryable ------
        # every request must come back with its own server-side trace id
        # (None across the board when the daemon runs --no-trace), and the
        # most recent id must still resolve through /v1/trace/<id> — i.e.
        # the daemon's ring buffer outlives at least one full storm
        if any(tid is None for tid in trace_ids):
            tracing = {"enabled": False}
        else:
            try:
                tree = client.trace(trace_ids[-1])
                last_spans: Optional[int] = tree["n_spans"]
            except Exception as exc:  # noqa: BLE001 — gated in check()
                last_spans = None
                print(f"  trace lookup failed: {exc}")
            tracing = {"enabled": True,
                       "n_requests": len(trace_ids),
                       "distinct_ids": len(set(trace_ids)),
                       "last_trace_spans": last_spans}

        m1 = client.metrics()
        pts0 = m0["service"].get("points", {})
        pts1 = m1["service"]["points"]
        requested = pts1["requested"] - pts0.get("requested", 0)
        evaluated = pts1["evaluated"] - pts0.get("evaluated", 0)
        coalesced = pts1["coalesced"] - pts0.get("coalesced", 0)
        memo_hits = pts1["memo_hits"] - pts0.get("memo_hits", 0)
        unique = unique_keys(docs)

        # ---- coalesce probe: guaranteed-overlap identical requests -----
        # A perfectly serialized storm could in principle satisfy every
        # duplicate from the memo; fire identical requests at a cold
        # workload simultaneously so the single-flight path itself is
        # exercised (trace builds take ~100ms, launch skew ~1ms).
        if coalesced == 0:
            probe_barrier = threading.Barrier(4)

            def probe() -> None:
                local = ServiceClient(base_url)
                probe_barrier.wait()
                local.sweep([PROBE_WORKLOAD], caches=list(CACHES))

            probe_threads = [threading.Thread(target=probe)
                             for _ in range(4)]
            for t in probe_threads:
                t.start()
            for t in probe_threads:
                t.join()
            m1 = client.metrics()
            pts1 = m1["service"]["points"]
            coalesced = pts1["coalesced"] - pts0.get("coalesced", 0)

        # ---- warm repeat: zero new trace builds, zero evaluations ------
        warm_doc = client_space(0, workloads)
        builds_before = m1["cache"]["cim"]["layer1"]["builds"]
        eval_before = m1["service"]["points"]["evaluated"]
        reply = client.sweep(warm_doc["workloads"], caches=warm_doc["caches"],
                             cim_levels=warm_doc["cim_levels"],
                             techs=warm_doc["techs"])
        m2 = client.metrics()
        warm_trace_builds = (m2["cache"]["cim"]["layer1"]["builds"]
                             - builds_before)
        warm_evaluated = m2["service"]["points"]["evaluated"] - eval_before

        ordered = sorted(latencies)

        def pick(q: float) -> float:
            return ordered[min(len(ordered) - 1,
                               max(0, round(q * (len(ordered) - 1))))]

        doc = {
            "clients": clients,
            "requests_per_client": requests_per_client,
            "workloads": list(workloads),
            "n_requests": len(latencies),
            "storm_wall_s": round(storm_s, 3),
            "requests_per_s": round(len(latencies) / storm_s, 2),
            "latency_s": {
                "p50": round(pick(0.50), 4), "p90": round(pick(0.90), 4),
                "p99": round(pick(0.99), 4),
                "mean": round(statistics.fmean(latencies), 4),
                "max": round(ordered[-1], 4)},
            "points": {"requested": requested, "evaluated": evaluated,
                       "unique_keys": unique, "coalesced": coalesced,
                       "memo_hits": memo_hits},
            "dedup_ratio": round(requested / evaluated, 3) if evaluated
                           else None,
            "warm_repeat": {"n_records": len(reply.records),
                            "trace_builds": warm_trace_builds,
                            "evaluated": warm_evaluated},
            "tracing": tracing,
        }
    if json_path:
        pathlib.Path(json_path).write_text(json.dumps(doc, indent=1))
    return doc


def check(doc: Dict) -> List[str]:
    """The bench's own gates (ISSUE 6 acceptance criteria)."""
    failures = []
    pts = doc["points"]
    if pts["evaluated"] != pts["unique_keys"]:
        failures.append(f"evaluated {pts['evaluated']} != unique keys "
                        f"{pts['unique_keys']} — a design was computed twice")
    if doc["dedup_ratio"] is None or doc["dedup_ratio"] <= 1.5:
        failures.append(f"dedup ratio {doc['dedup_ratio']} <= 1.5x — "
                        f"overlapping requests were not coalesced")
    if pts["coalesced"] < 1:
        failures.append("zero coalesced evaluations — the single-flight "
                        "path never fired")
    warm = doc["warm_repeat"]
    if warm["trace_builds"] != 0 or warm["evaluated"] != 0:
        failures.append(f"warm repeat did work: {warm['trace_builds']} "
                        f"trace builds, {warm['evaluated']} evaluations")
    tr = doc.get("tracing") or {}
    if tr.get("enabled"):          # a --no-trace daemon is record-only here
        if tr["distinct_ids"] != tr["n_requests"]:
            failures.append(f"{tr['n_requests']} storm requests produced "
                            f"only {tr['distinct_ids']} distinct trace ids "
                            f"— per-request root spans are not isolated")
        if not tr.get("last_trace_spans"):
            failures.append("the last storm trace id did not resolve via "
                            "/v1/trace/<id> — ring buffer evicted or the "
                            "trace was never finished")
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--url", default=None,
                    help="target an externally started daemon instead of "
                         "an in-process server")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests-per-client", type=int, default=2)
    ap.add_argument("--workloads", default="NB,LCS",
                    help="comma-separated Table-IV programs for the storm "
                         f"(keep {PROBE_WORKLOAD} out: it is the reserved "
                         "coalesce-probe workload)")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent store for the in-process daemon")
    ap.add_argument("--json", default="BENCH_service.json")
    ap.add_argument("--no-check", action="store_true",
                    help="record only; skip the dedup/coalesce gates")
    args = ap.parse_args(argv)

    banner("BENCH: DSE service under concurrent load")
    workloads = tuple(args.workloads.split(","))
    doc = run(url=args.url, clients=args.clients,
              requests_per_client=args.requests_per_client,
              workloads=workloads, cache_dir=args.cache_dir,
              json_path=args.json)
    lat = doc["latency_s"]
    pts = doc["points"]
    print(f"  {doc['n_requests']} requests from {doc['clients']} clients "
          f"in {doc['storm_wall_s']}s ({doc['requests_per_s']} req/s)")
    print(f"  latency p50 {lat['p50']}s  p90 {lat['p90']}s  "
          f"p99 {lat['p99']}s  max {lat['max']}s")
    print(f"  points: {pts['requested']} requested -> {pts['evaluated']} "
          f"evaluated ({pts['unique_keys']} unique keys; "
          f"{pts['coalesced']} coalesced, {pts['memo_hits']} memo hits) "
          f"— dedup x{doc['dedup_ratio']}")
    warm = doc["warm_repeat"]
    print(f"  warm repeat: {warm['n_records']} records, "
          f"{warm['trace_builds']} trace builds, "
          f"{warm['evaluated']} evaluations")
    tr = doc["tracing"]
    if tr.get("enabled"):
        print(f"  traces: {tr['distinct_ids']} distinct ids over "
              f"{tr['n_requests']} requests; last tree "
              f"{tr['last_trace_spans']} spans via /v1/trace")
    else:
        print("  traces: daemon tracing disabled (record-only)")
    if args.json:
        print(f"  [json] {args.json}")
    if not args.no_check:
        failures = check(doc)
        for f in failures:
            print(f"  FAIL: {f}")
        if failures:
            return 1
        print("  gates: dedup > 1.5x, evaluated == unique, coalesced >= 1, "
              "warm repeat free — all passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
