"""Shared benchmark utilities: one DSE analysis cache + CSV/JSON emission.

Every benchmark module reproduces one paper table/figure and exposes
``run() -> list[dict]``; ``benchmarks.run`` executes all of them and tees
CSV artifacts under ``benchmarks/artifacts/``.

All trace-driven benchmarks share a single :class:`repro.dse.AnalysisCache`
(via :func:`engine` / :func:`cached_trace`), so across a full
``benchmarks.run`` each (workload, cache-config) pair is traced and
IDG-analyzed exactly once no matter how many figures price it.  Set
``EVA_CIM_CACHE_DIR=/some/dir`` to back that cache with a persistent
:class:`repro.dse.AnalysisStore`: a second ``benchmarks.run`` then skips
re-tracing entirely (the sweep reports print the store hit counters).
"""
from __future__ import annotations

import csv
import json
import os
import pathlib
from typing import Dict, List, Optional, Tuple

from repro.core.cache import CacheConfig
from repro.dse import AnalysisCache, CacheOption, DSEEngine

ART = pathlib.Path(__file__).resolve().parent / "artifacts"

# The nine Fig. 13–15 sweep benchmarks (paper's per-figure subset).
SWEEP_BENCHES = ("NB", "DT", "KM", "LCS", "BFS", "SSSP", "CCOMP", "hmmer",
                 "mcf")

_ENGINE: Optional[DSEEngine] = None


def engine() -> DSEEngine:
    """Process-wide sweep engine (one shared analysis cache; backed by a
    persistent store when ``EVA_CIM_CACHE_DIR`` is set)."""
    global _ENGINE
    if _ENGINE is None:
        _ENGINE = DSEEngine(store=os.environ.get("EVA_CIM_CACHE_DIR") or None)
    return _ENGINE


def cached_trace(name: str,
                 cache_levels: Optional[Tuple[CacheConfig, ...]] = None):
    """Memoized ``TraceResult`` for a workload (engine-backed)."""
    from repro.core.cache import L1_32K, L2_256K
    option = CacheOption.of(cache_levels if cache_levels is not None
                            else (L1_32K, L2_256K))
    return engine().analysis.trace(name, option)


def emit(name: str, rows: List[dict]) -> pathlib.Path:
    ART.mkdir(parents=True, exist_ok=True)
    path = ART / f"{name}.csv"
    if rows:
        fields = list(dict.fromkeys(k for r in rows for k in r))
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=fields, restval="")
            w.writeheader()
            w.writerows(rows)
    (ART / f"{name}.json").write_text(json.dumps(rows, indent=1, default=str))
    return path


def banner(title: str) -> None:
    print(f"\n=== {title} " + "=" * max(0, 66 - len(title)), flush=True)
