"""Shared benchmark utilities: cached workload traces + CSV/JSON emission.

Every benchmark module reproduces one paper table/figure and exposes
``run() -> list[dict]``; ``benchmarks.run`` executes all of them and tees
CSV artifacts under ``benchmarks/artifacts/``.
"""
from __future__ import annotations

import csv
import functools
import json
import pathlib
import time
from typing import Dict, List, Optional, Tuple

from repro.core import trace_program
from repro.core.cache import CacheConfig
from repro.workloads import build

ART = pathlib.Path(__file__).resolve().parent / "artifacts"

_TRACE_CACHE: Dict[Tuple, object] = {}


def cached_trace(name: str, cache_levels: Optional[Tuple[CacheConfig, ...]] = None):
    key = (name, cache_levels)
    if key not in _TRACE_CACHE:
        fn, args = build(name)
        kw = {} if cache_levels is None else {"cache_levels": cache_levels}
        _TRACE_CACHE[key] = trace_program(fn, *args, **kw)
    return _TRACE_CACHE[key]


def emit(name: str, rows: List[dict]) -> pathlib.Path:
    ART.mkdir(parents=True, exist_ok=True)
    path = ART / f"{name}.csv"
    if rows:
        fields = list(dict.fromkeys(k for r in rows for k in r))
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=fields, restval="")
            w.writeheader()
            w.writerows(rows)
    (ART / f"{name}.json").write_text(json.dumps(rows, indent=1, default=str))
    return path


def banner(title: str) -> None:
    print(f"\n=== {title} " + "=" * max(0, 66 - len(title)), flush=True)
