"""Fig. 12: offloaded-memory-access share on LCS vs [23].

The paper compares against STT-CiM's emulation platform (in-order core,
1 MB single-level SPM): Eva-CiM selects ~65% of memory accesses for
offloading, [23] reports ~58%.  We rebuild the [23]-like configuration
(single-level 1 MB cache, STT op set) and report our share alongside the
default two-level hierarchy."""
from __future__ import annotations

from repro.core import (CIM_SET_STT, OffloadConfig, SPM_1M,
                        select_candidates)
from benchmarks.common import banner, cached_trace, emit

PAPER_EVA = 0.65
PAPER_23 = 0.58


def run():
    rows = []
    # [23]-like: single-level 1 MB SPM/cache
    tr = cached_trace("LCS", (SPM_1M,))
    res = select_candidates(tr.trace, cfg=OffloadConfig(cim_set=CIM_SET_STT,
                                                        cim_levels=("L1",)))
    mb = res.macr_breakdown(tr.trace)
    rows.append({"config": "1MB SPM (as [23])", "offload_share": round(mb["macr"], 3),
                 "paper_eva_cim": PAPER_EVA, "paper_[23]": PAPER_23})
    # default hierarchy
    tr2 = cached_trace("LCS")
    res2 = select_candidates(tr2.trace, cfg=OffloadConfig(cim_set=CIM_SET_STT))
    mb2 = res2.macr_breakdown(tr2.trace)
    rows.append({"config": "32K L1 + 256K L2", "offload_share": round(mb2["macr"], 3),
                 "paper_eva_cim": PAPER_EVA, "paper_[23]": PAPER_23})
    return rows


def main():
    banner("Fig. 12: CiM-supported access share on LCS (vs [23])")
    rows = run()
    for r in rows:
        print(f"  {r['config']:22s} offloaded {r['offload_share']*100:5.1f}%  "
              f"(paper: Eva-CiM {r['paper_eva_cim']*100:.0f}%, "
              f"[23] {r['paper_[23]']*100:.0f}%)")
    emit("fig12_macr_validation", rows)
    return rows


if __name__ == "__main__":
    main()
