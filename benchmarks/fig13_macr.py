"""Fig. 13: MACR per benchmark (top) + breakdown into L1 / other-level
converted accesses (bottom), for all 17 applications."""
from __future__ import annotations

from repro.core import OffloadConfig, select_candidates
from repro.workloads import WORKLOADS
from benchmarks.common import banner, cached_trace, emit


def run():
    rows = []
    for name in WORKLOADS:
        tr = cached_trace(name)
        res = select_candidates(tr.trace, cfg=OffloadConfig())
        mb = res.macr_breakdown(tr.trace)
        rows.append({"benchmark": name, "macr": round(mb["macr"], 4),
                     "l1_share": round(mb["l1"], 4),
                     "other_share": round(mb["other"], 4),
                     "total_accesses": mb["total_accesses"],
                     "cim_favorable": mb["macr"] >= 0.5})
    return rows


def main():
    banner("Fig. 13: MACR breakdown per benchmark")
    rows = run()
    for r in rows:
        bar = "#" * int(r["macr"] * 40)
        print(f"  {r['benchmark']:8s} {r['macr']:6.3f} "
              f"(L1 {r['l1_share']:5.3f} / other {r['other_share']:5.3f}) {bar}")
    emit("fig13_macr", rows)
    return rows


if __name__ == "__main__":
    main()
