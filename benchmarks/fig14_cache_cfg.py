"""Fig. 14: energy improvement under three cache configurations
(32K/256K, 64K/256K, 64K/2M) — exercises the DESTINY-surrogate scaling and
the paper's finding that bigger arrays raise per-op CiM energy."""
from __future__ import annotations

from repro.core import L1_32K, L1_64K, L2_256K, L2_2M, profile_system
from benchmarks.common import banner, cached_trace, emit

BENCHES = ("NB", "DT", "KM", "LCS", "BFS", "SSSP", "CCOMP", "hmmer", "mcf")
CFGS = [("32K+256K", (L1_32K, L2_256K)),
        ("64K+256K", (L1_64K, L2_256K)),
        ("64K+2M", (L1_64K, L2_2M))]


def run():
    rows = []
    for name in BENCHES:
        row = {"benchmark": name}
        for cfg_name, levels in CFGS:
            tr = cached_trace(name, levels)
            rep = profile_system(tr)
            row[cfg_name] = round(rep.energy_improvement, 3)
        rows.append(row)
    return rows


def main():
    banner("Fig. 14: energy improvement vs cache configuration")
    rows = run()
    for r in rows:
        print(f"  {r['benchmark']:8s} " +
              " ".join(f"{n}={r[n]:5.2f}" for n, _ in CFGS))
    emit("fig14_cache_cfg", rows)
    return rows


if __name__ == "__main__":
    main()
