"""Fig. 14: energy improvement under three cache configurations
(32K/256K, 64K/256K, 64K/2M) — exercises the DESTINY-surrogate scaling and
the paper's finding that bigger arrays raise per-op CiM energy.

Runs as one :class:`repro.dse.SweepSpace` over (benchmark x cache config):
each benchmark is traced once per cache geometry and priced from the shared
analysis cache."""
from __future__ import annotations

from repro.dse import SweepSpace
from benchmarks.common import SWEEP_BENCHES, banner, emit, engine

CFG_NAMES = ("32K+256K", "64K+256K", "64K+2M")


def run():
    space = SweepSpace(workloads=SWEEP_BENCHES, caches=CFG_NAMES)
    results = engine().run(space)
    by_bench = results.group_by("workload")
    rows = []
    for name in SWEEP_BENCHES:
        row = {"benchmark": name}
        for rec in by_bench[name]:
            row[rec.cache] = round(rec.energy_improvement, 3)
        rows.append(row)
    return rows


def main():
    banner("Fig. 14: energy improvement vs cache configuration")
    rows = run()
    for r in rows:
        print(f"  {r['benchmark']:8s} " +
              " ".join(f"{n}={r[n]:5.2f}" for n in CFG_NAMES))
    emit("fig14_cache_cfg", rows)
    return rows


if __name__ == "__main__":
    main()
