"""Fig. 15: energy improvement with CiM in L1 only, L2 only, or both —
the paper's 'which level should host the CiM?' question.

One sweep over (benchmark x CiM level set); the trace/IDG analysis is
shared across all three level choices per benchmark (only candidate
selection re-runs), which is exactly the reuse the DSE engine memoizes."""
from __future__ import annotations

from repro.dse import SweepSpace
from benchmarks.common import SWEEP_BENCHES, banner, emit, engine

LEVEL_NAMES = ("L1_only", "L2_only", "both")
_COLUMN_OF = {"L1": "L1_only", "L2": "L2_only", "L1+L2": "both"}


def run():
    space = SweepSpace(workloads=SWEEP_BENCHES, cim_levels=LEVEL_NAMES)
    results = engine().run(space)
    by_bench = results.group_by("workload")
    rows = []
    for name in SWEEP_BENCHES:
        row = {"benchmark": name}
        for rec in by_bench[name]:
            row[_COLUMN_OF[rec.cim_levels]] = round(rec.energy_improvement, 3)
        row["l2_worst"] = row["L2_only"] <= min(row["L1_only"],
                                                row["both"]) + 1e-9
        rows.append(row)
    return rows


def main():
    banner("Fig. 15: energy improvement vs CiM level")
    rows = run()
    for r in rows:
        print(f"  {r['benchmark']:8s} L1 {r['L1_only']:5.2f}  "
              f"L2 {r['L2_only']:5.2f}  both {r['both']:5.2f}"
              f"{'   (L2-only lowest ok)' if r['l2_worst'] else ''}")
    emit("fig15_levels", rows)
    return rows


if __name__ == "__main__":
    main()
