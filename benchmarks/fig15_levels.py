"""Fig. 15: energy improvement with CiM in L1 only, L2 only, or both —
the paper's 'which level should host the CiM?' question."""
from __future__ import annotations

from repro.core import OffloadConfig, profile_system
from benchmarks.common import banner, cached_trace, emit

BENCHES = ("NB", "DT", "KM", "LCS", "BFS", "SSSP", "CCOMP", "hmmer", "mcf")
LEVELS = [("L1_only", ("L1",)), ("L2_only", ("L2",)), ("both", ("L1", "L2"))]


def run():
    rows = []
    for name in BENCHES:
        tr = cached_trace(name)
        row = {"benchmark": name}
        for lname, lv in LEVELS:
            rep = profile_system(tr, OffloadConfig(cim_levels=lv))
            row[lname] = round(rep.energy_improvement, 3)
        row["l2_worst"] = row["L2_only"] <= min(row["L1_only"], row["both"]) + 1e-9
        rows.append(row)
    return rows


def main():
    banner("Fig. 15: energy improvement vs CiM level")
    rows = run()
    for r in rows:
        print(f"  {r['benchmark']:8s} L1 {r['L1_only']:5.2f}  "
              f"L2 {r['L2_only']:5.2f}  both {r['both']:5.2f}"
              f"{'   (L2-only lowest ok)' if r['l2_worst'] else ''}")
    emit("fig15_levels", rows)
    return rows


if __name__ == "__main__":
    main()
