"""Fig. 16: SRAM vs FeFET CiM — energy improvement normalized to the SRAM
non-CiM baseline (the paper's normalization) + speedup comparison.

A pure technology sweep: per benchmark the engine re-prices the *same*
memoized trace + candidate set under each Table III device model, so the
whole figure costs one analysis pass per workload."""
from __future__ import annotations

from repro.dse import SweepSpace
from repro.workloads import WORKLOADS
from benchmarks.common import banner, emit, engine


def run():
    space = SweepSpace(workloads=tuple(WORKLOADS), techs=("sram", "fefet"))
    results = engine().run(space)
    by_bench = results.group_by("workload")
    rows = []
    for name in WORKLOADS:
        sram, fefet = by_bench[name]
        assert (sram.tech, fefet.tech) == ("sram", "fefet")
        base = sram.base_energy_pj                   # SRAM non-CiM baseline
        rows.append({
            "benchmark": name,
            "sram_improvement": round(base / sram.cim_energy_pj, 3),
            "fefet_improvement": round(base / fefet.cim_energy_pj, 3),
            "sram_speedup": round(sram.speedup, 3),
            "fefet_speedup": round(fefet.speedup, 3),
            "fefet_gain_pct": round(
                (base / fefet.cim_energy_pj)
                / (base / sram.cim_energy_pj) * 100 - 100, 1),
        })
    return rows


def main():
    banner("Fig. 16: SRAM vs FeFET (normalized to SRAM non-CiM baseline)")
    rows = run()
    for r in rows:
        print(f"  {r['benchmark']:8s} E-imp SRAM {r['sram_improvement']:5.2f} "
              f"FeFET {r['fefet_improvement']:5.2f} ({r['fefet_gain_pct']:+5.1f}%)  "
              f"spd {r['sram_speedup']:.2f}/{r['fefet_speedup']:.2f}")
    gains = [r["fefet_gain_pct"] for r in rows]
    print(f"  FeFET gain range: {min(gains):+.1f}% .. {max(gains):+.1f}% "
          f"(paper: +50-70%)")
    emit("fig16_tech", rows)
    return rows


if __name__ == "__main__":
    main()
