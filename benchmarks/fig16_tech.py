"""Fig. 16: SRAM vs FeFET CiM — energy improvement normalized to the SRAM
non-CiM baseline (the paper's normalization) + speedup comparison."""
from __future__ import annotations

from repro.core import profile_system
from repro.workloads import WORKLOADS
from benchmarks.common import banner, cached_trace, emit


def run():
    rows = []
    for name in WORKLOADS:
        tr = cached_trace(name)
        sram = profile_system(tr, tech="sram")
        fefet = profile_system(tr, tech="fefet")
        base = sram.base.total                       # SRAM non-CiM baseline
        rows.append({
            "benchmark": name,
            "sram_improvement": round(base / sram.cim.total, 3),
            "fefet_improvement": round(base / fefet.cim.total, 3),
            "sram_speedup": round(sram.speedup, 3),
            "fefet_speedup": round(fefet.speedup, 3),
            "fefet_gain_pct": round(
                (base / fefet.cim.total) / (base / sram.cim.total) * 100 - 100, 1),
        })
    return rows


def main():
    banner("Fig. 16: SRAM vs FeFET (normalized to SRAM non-CiM baseline)")
    rows = run()
    for r in rows:
        print(f"  {r['benchmark']:8s} E-imp SRAM {r['sram_improvement']:5.2f} "
              f"FeFET {r['fefet_improvement']:5.2f} ({r['fefet_gain_pct']:+5.1f}%)  "
              f"spd {r['sram_speedup']:.2f}/{r['fefet_speedup']:.2f}")
    gains = [r["fefet_gain_pct"] for r in rows]
    print(f"  FeFET gain range: {min(gains):+.1f}% .. {max(gains):+.1f}% "
          f"(paper: +50-70%)")
    emit("fig16_tech", rows)
    return rows


if __name__ == "__main__":
    main()
