"""Fig. 17 (repo extension): energy improvement and speedup vs host CPU —
the paper's §VI-D host/CiM-interaction question swept as a first-class axis.

One sweep over (benchmark x host preset).  The host model is pure
pricing-phase input, so the whole figure re-uses the trace/IDG analysis
*and* the candidate selection of every benchmark — the engine reports zero
additional analysis builds beyond the per-workload trace.  The expected
shape: a small in-order host leaves the most memory wall for CiM to remove
(largest energy win, but unhidden CiM op latency can cost speedup), while a
wide/fast OoO host hides miss latency itself and shrinks CiM's headroom.
"""
from __future__ import annotations

from repro.core.host_model import HOST_PRESETS
from repro.dse import SweepSpace
from benchmarks.common import SWEEP_BENCHES, banner, emit, engine

HOSTS = tuple(HOST_PRESETS)


def run():
    space = SweepSpace(workloads=SWEEP_BENCHES, hosts=HOSTS)
    results = engine().run(space)
    by_bench = results.group_by("workload")
    rows = []
    for name in SWEEP_BENCHES:
        row = {"benchmark": name}
        for rec in by_bench[name]:
            row[f"{rec.host}_improvement"] = round(rec.energy_improvement, 3)
            row[f"{rec.host}_speedup"] = round(rec.speedup, 3)
            # wall-clock, not cycles: the 2 GHz presets halve this even
            # where the cycle-count speedup barely moves
            row[f"{rec.host}_cim_ms"] = round(rec.cim_runtime_ms, 4)
        rows.append(row)
    return rows


def main():
    banner("Fig. 17: energy improvement / speedup vs host CPU model")
    rows = run()
    for r in rows:
        cells = "  ".join(f"{h} {r[f'{h}_improvement']:5.2f}x"
                          f"/{r[f'{h}_speedup']:4.2f}x" for h in HOSTS)
        print(f"  {r['benchmark']:8s} {cells}")
    emit("fig17_host", rows)
    return rows


if __name__ == "__main__":
    main()
