"""Adaptive vs exhaustive DSE: identical Pareto frontier, ≥3x fewer points.

The paper's §VI-D/E sweeps price full cross-products; this benchmark runs
the same 5-axis design space twice — once exhaustively, once with
:class:`repro.dse.AdaptiveDSE` (coarse seed → frontier → axis-neighborhood
refinement) — and checks two things per workload: the adaptive run's final
per-workload Pareto frontier is *identical* to the exhaustive one, and it
priced at least 3x fewer design points to get there.  The host axis is
declared in its physical order (increasing micro-architectural
aggressiveness), so "neighboring host" is a meaningful refinement move.
"""
from __future__ import annotations

from repro.core.cache import CacheConfig, L2_2M
from repro.dse import AdaptiveDSE, SweepSpace
from benchmarks.common import banner, emit, engine

WORKLOADS = ("KM", "BFS", "NB")
CACHES = ("32K+256K", "64K+256K", "64K+2M",
          (CacheConfig("L1", 128 * 1024, 4), L2_2M))   # small -> large
LEVELS = ("L1_only", "L2_only", "both")
TECHS = ("sram", "fefet")
HOSTS = ("inorder-1GHz", "A9-1GHz", "A9-2GHz", "big-OoO-2GHz")
OBJECTIVES = ("energy_improvement", "speedup")
MIN_SAVINGS = 3.0


def _ident(rec):
    return (rec.workload, rec.cache, rec.cim_levels, rec.tech, rec.cim_set,
            rec.host)


def run():
    full = SweepSpace(workloads=WORKLOADS, caches=CACHES, cim_levels=LEVELS,
                      techs=TECHS, hosts=HOSTS)
    eng = engine()
    exhaustive = eng.run(full)
    adaptive = AdaptiveDSE(full, engine=eng, objectives=OBJECTIVES).run()

    ex_front = {_ident(r) for r in exhaustive.pareto(OBJECTIVES)}
    ad_front = {_ident(r) for r in adaptive.frontier}
    per_workload = len(full) // len(WORKLOADS)

    rows = []
    for name in WORKLOADS:
        priced = sum(1 for r in adaptive.results if r.workload == name)
        exf = {i for i in ex_front if i[0] == name}
        adf = {i for i in ad_front if i[0] == name}
        rows.append({
            "benchmark": name,
            "full_points": per_workload,
            "adaptive_points": priced,
            "savings": round(per_workload / priced, 2),
            "frontier_size": len(exf),
            "frontier_identical": exf == adf,
        })
    rows.append({
        "benchmark": "ALL",
        "full_points": len(full),
        "adaptive_points": adaptive.n_priced,
        "savings": round(adaptive.savings, 2),
        "frontier_size": len(ex_front),
        "frontier_identical": ex_front == ad_front,
        "rounds": len(adaptive.rounds),
    })

    # the headline claims are assertions, not prose: CI catches regressions
    assert ex_front == ad_front, "adaptive frontier diverged from exhaustive"
    assert adaptive.savings >= MIN_SAVINGS, (
        f"adaptive priced {adaptive.n_priced}/{len(full)} points "
        f"({adaptive.savings:.2f}x), below the {MIN_SAVINGS}x target")
    return rows, adaptive


def main():
    banner("Adaptive DSE: frontier-driven refinement vs full cross-product")
    rows, adaptive = run()
    for r in rows:
        print(f"  {r['benchmark']:8s} {r['adaptive_points']:3d}/"
              f"{r['full_points']:3d} points ({r['savings']:5.2f}x fewer), "
              f"frontier {r['frontier_size']:2d} "
              f"{'identical' if r['frontier_identical'] else 'DIVERGED'}")
    print()
    for line in adaptive.summary().splitlines():
        print(f"  {line}")
    emit("fig_adaptive", rows)
    return rows


if __name__ == "__main__":
    main()
