"""TPU-mode DSE: chip x fusion-threshold x workload sweep through DSEEngine.

The Eva-CiM questions re-asked on the TPU memory hierarchy (DESIGN.md §3):
does this model step benefit from VMEM-resident fusion, on which chip, at
which aggressiveness?  One :class:`repro.dse.SweepSpace` over the arch
registry's reduced train steps with a :class:`repro.dse.TpuOption` axis
(every preset chip crossed with every ``min_saved_bytes`` threshold),
priced by :class:`repro.dse.TpuBackend` — jaxpr/HLO analysis exactly once
per workload (asserted from the engine's cache counters; with a warm
``--cache-dir`` store a repeat run does *zero* HLO analyses), fusion
selection once per (workload, threshold), roofline/energy pricing per
point.  Emits the full grid, the per-workload Pareto frontier, and a
markdown report under ``benchmarks/artifacts/``.
"""
from __future__ import annotations

import argparse
import os
import pathlib
import sys

from repro.dse import (DSEEngine, SweepSpace, TPU_PRESETS, TpuBackend,
                       TpuOption, parse_bytes)
from benchmarks.common import ART, banner, emit

WORKLOADS = ("qwen1.5-0.5b", "gemma3-1b", "xlstm-125m", "hymba-1.5b")
CHIPS = ("v5e", "v4", "v5p")                 # capability order (adjacency)
THRESHOLDS = ("16K", "64K", "256K")
OBJECTIVES = ("energy_improvement", "speedup")


def run(workloads=WORKLOADS, chips=CHIPS, thresholds=THRESHOLDS,
        cache_dir=None):
    # TpuOption.of gives unknown presets the curated "known: [...]" error
    tpus = [TpuOption(TpuOption.of(c).chip, parse_bytes(t))
            for c in chips for t in thresholds]
    space = SweepSpace(workloads=tuple(workloads), tpus=tuple(tpus))
    eng = DSEEngine(backend=TpuBackend(), store=cache_dir)
    results = eng.run(space)
    st = results.stats

    # the tentpole guarantee, asserted: layer-1 jaxpr/HLO analysis ran
    # exactly once per (workload, shape) — built here or loaded from a
    # warm store, never twice
    n_analyses = st["trace_builds"] + st.get("store_l1_hits", 0)
    assert n_analyses == len(workloads), (
        f"expected one HLO analysis per workload "
        f"({len(workloads)}), got {n_analyses} ({st})")

    front = {(r.workload, r.cache, r.cim_set)
             for r in results.pareto(OBJECTIVES)}
    rows = []
    for r in results:
        rows.append({
            "workload": r.workload, "chip": r.cache, "threshold": r.cim_set,
            "tpu_macr": round(r.macr, 4),
            "energy_improvement": round(r.energy_improvement, 3),
            "speedup": round(r.speedup, 3),
            "bound_ms": round(r.cim_runtime_ms, 5),
            "n_candidates": r.n_candidates,
            "fused_ops": r.n_cim_ops,
            "pareto": (r.workload, r.cache, r.cim_set) in front,
        })
    return rows, results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workloads", default=",".join(WORKLOADS),
                    help="comma-separated arch ids (repro.configs.registry)")
    ap.add_argument("--chips", default=",".join(CHIPS),
                    help=f"comma-separated chip presets "
                         f"(known: {','.join(TPU_PRESETS)})")
    ap.add_argument("--thresholds", default=",".join(THRESHOLDS),
                    help="comma-separated fusion min_saved_bytes (e.g. "
                         "16K,64K,1M)")
    ap.add_argument("--cache-dir",
                    default=os.environ.get("EVA_CIM_CACHE_DIR") or None,
                    help="persistent AnalysisStore dir: a second run does "
                         "zero jaxpr/HLO analyses")
    # benchmarks.run calls main() with no argv: parse pure defaults there,
    # the real command line only when __main__ passes it explicitly
    args = ap.parse_args(argv if argv is not None else [])

    workloads = tuple(args.workloads.split(","))
    chips = tuple(args.chips.split(","))
    thresholds = tuple(args.thresholds.split(","))
    banner(f"TPU-mode DSE: {len(chips)} chips x {len(thresholds)} "
           f"thresholds x {len(workloads)} workloads")
    rows, results = run(workloads, chips, thresholds, args.cache_dir)
    st = results.stats
    print(f"  {len(results)} design points, {st['trace_builds']} HLO "
          f"analyses built ({st.get('store_l1_hits', 0)} store hits), "
          f"{results.elapsed_s:.1f}s")
    for r in rows:
        mark = " *" if r["pareto"] else "  "
        print(f" {mark}{r['workload']:16s} {r['chip']:5s} "
              f"{r['threshold']:8s} macr {r['tpu_macr']:.3f} "
              f"E {r['energy_improvement']:6.2f}x spd {r['speedup']:5.2f}x")
    print("  (* = on the per-workload Pareto frontier)")
    emit("fig_tpu_dse", rows)
    report = ART / "fig_tpu_dse.md"
    report.write_text(results.to_markdown(
        columns=("workload", "cache", "cim_set", "macr",
                 "energy_improvement", "speedup"),
        pareto_objectives=OBJECTIVES))
    print(f"  [report] {report}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
