"""§Roofline: per-(arch x shape) three-term roofline table from the dry-run
artifacts (single-pod 16x16 mesh).  Requires ``repro.launch.dryrun`` to have
produced artifacts; prints whatever cells exist."""
from __future__ import annotations

from repro.core.roofline import full_table, markdown_table
from benchmarks.common import banner, emit


def run():
    return full_table("single")


def main():
    banner("Roofline: three terms per (arch x shape), single-pod 16x16")
    rows = run()
    if not rows:
        print("  (no dry-run artifacts yet — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --mesh single`)")
        return rows
    for r in rows:
        print(f"  {r['arch']:24s} {r['shape']:12s} "
              f"C {r['compute_s']:9.4f}s M {r['memory_s']:9.4f}s "
              f"X {r['collective_s']:9.4f}s -> {r['dominant']:10s} "
              f"useful {r['useful_compute_ratio']:6.3f} "
              f"frac {r['roofline_fraction']:.3f}")
    emit("roofline", rows)
    return rows


if __name__ == "__main__":
    main()
