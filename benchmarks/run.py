"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table6     # one artifact
    PYTHONPATH=src python -m benchmarks.run --list     # enumerate artifacts

    # per-stage analysis throughput (trace/IDG/selection/pricing), written
    # as JSON; --timing-workloads restricts to a subset (CI runs the
    # smallest workload only), and --timing-gate BASELINE fails the run if
    # selection+pricing throughput regresses >25% vs the committed,
    # calibration-scaled baseline:
    PYTHONPATH=src python -m benchmarks.run --timing-json BENCH_analysis.json
    PYTHONPATH=src python -m benchmarks.run --timing-json out.json \\
        --timing-workloads NB --timing-gate benchmarks/baselines/timing_nb.json
"""
from __future__ import annotations

import sys
import time

from benchmarks import (analysis_timing, fig12_macr_validation, fig13_macr,
                        fig14_cache_cfg, fig15_levels, fig16_tech,
                        fig17_host, fig_adaptive, fig_tpu_dse, roofline,
                        table3_energy, table5_validation, table6_speedup,
                        tpu_macr)

ALL = {
    "table3": table3_energy,
    "table5": table5_validation,
    "fig12": fig12_macr_validation,
    "fig13": fig13_macr,
    "table6": table6_speedup,
    "fig14": fig14_cache_cfg,
    "fig15": fig15_levels,
    "fig16": fig16_tech,
    "fig17": fig17_host,
    "fig_adaptive": fig_adaptive,
    "tpu_macr": tpu_macr,
    "fig_tpu_dse": fig_tpu_dse,
    "roofline": roofline,
}


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if "--list" in argv:
        for name, mod in ALL.items():
            doc = next(iter((mod.__doc__ or "").strip().splitlines()), "")
            print(f"{name:10s} {doc}")
        print(f"{'--timing-json PATH':18s} "
              f"{(analysis_timing.__doc__ or '').strip().splitlines()[0]}")
        return 0
    if "--timing-json" in argv:
        argv = list(argv)

        def take_value(flag: str):
            i = argv.index(flag)
            if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
                print(f"{flag} requires a value "
                      f"(e.g. {flag} BENCH_analysis.json)")
                raise SystemExit(2)
            value = argv[i + 1]
            del argv[i:i + 2]
            return value

        json_path = take_value("--timing-json")
        workloads = None
        if "--timing-workloads" in argv:
            workloads = tuple(take_value("--timing-workloads").split(","))
        gate_path = (take_value("--timing-gate")
                     if "--timing-gate" in argv else None)
        trace_path = (take_value("--trace")
                      if "--trace" in argv else None)
        doc = analysis_timing.main(workloads=workloads, json_path=json_path,
                                   gate_path=gate_path,
                                   trace_path=trace_path)
        if doc.get("gate", {}).get("failures"):
            return 1
        if not argv:                       # timing only, no named artifacts
            return 0
        # fall through: any remaining names run as usual after the timing
    picks = argv or list(ALL)
    t0 = time.time()
    for name in picks:
        if name not in ALL:
            print(f"unknown benchmark {name!r}; known: {sorted(ALL)}")
            return 1
        ALL[name].main()
    print(f"\n[benchmarks] done in {time.time() - t0:.1f}s "
          f"({len(picks)} artifacts under benchmarks/artifacts/)", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
