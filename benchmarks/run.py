"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table6     # one artifact
    PYTHONPATH=src python -m benchmarks.run --list     # enumerate artifacts
"""
from __future__ import annotations

import sys
import time

from benchmarks import (fig12_macr_validation, fig13_macr, fig14_cache_cfg,
                        fig15_levels, fig16_tech, fig17_host, fig_adaptive,
                        fig_tpu_dse, roofline, table3_energy,
                        table5_validation, table6_speedup, tpu_macr)

ALL = {
    "table3": table3_energy,
    "table5": table5_validation,
    "fig12": fig12_macr_validation,
    "fig13": fig13_macr,
    "table6": table6_speedup,
    "fig14": fig14_cache_cfg,
    "fig15": fig15_levels,
    "fig16": fig16_tech,
    "fig17": fig17_host,
    "fig_adaptive": fig_adaptive,
    "tpu_macr": tpu_macr,
    "fig_tpu_dse": fig_tpu_dse,
    "roofline": roofline,
}


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if "--list" in argv:
        for name, mod in ALL.items():
            doc = next(iter((mod.__doc__ or "").strip().splitlines()), "")
            print(f"{name:10s} {doc}")
        return 0
    picks = argv or list(ALL)
    t0 = time.time()
    for name in picks:
        if name not in ALL:
            print(f"unknown benchmark {name!r}; known: {sorted(ALL)}")
            return 1
        ALL[name].main()
    print(f"\n[benchmarks] done in {time.time() - t0:.1f}s "
          f"({len(picks)} artifacts under benchmarks/artifacts/)", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
