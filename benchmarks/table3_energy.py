"""Table III: per-operation cache energies (SRAM + FeFET, L1 + L2) from the
device model — must reproduce the published numbers at the anchor configs
and extrapolate for the Fig. 14 configurations."""
from __future__ import annotations

from repro.core import L1_32K, L1_64K, L2_256K, L2_2M, TECHS
from benchmarks.common import banner, emit

PAPER = {
    ("sram", "64kB/4w L1"): [61, 71, 72, 79, 79],
    ("sram", "256kB/8w L2"): [314, 341, 344, 365, 365],
    ("fefet", "64kB/4w L1"): [34, 35, 88, 105, 105],
    ("fefet", "256kB/8w L2"): [70, 72, 146, 205, 205],
}
OPS = ("read", "CiM-OR", "CiM-AND", "CiM-XOR", "CiM-ADD")
CFGS = [("32kB/4w L1", L1_32K), ("64kB/4w L1", L1_64K),
        ("256kB/8w L2", L2_256K), ("2MB/8w L2", L2_2M)]


def run():
    rows = []
    for tech_name, tech in TECHS.items():
        for cfg_name, cfg in CFGS:
            got = tech.table3_row(cfg)
            row = {"tech": tech_name, "config": cfg_name,
                   **{op: got[op] for op in OPS}}
            paper = PAPER.get((tech_name, cfg_name))
            if paper:
                row["max_dev_pct"] = round(max(
                    abs(got[o] - p) / p * 100 for o, p in zip(OPS, paper)), 2)
            rows.append(row)
    return rows


def main():
    banner("Table III: cache energy (pJ) per operation")
    rows = run()
    for r in rows:
        dev = f"  (max dev vs paper {r['max_dev_pct']}%)" if "max_dev_pct" in r else ""
        print(f"  {r['tech']:6s} {r['config']:13s} " +
              " ".join(f"{r[o]:7.1f}" for o in OPS) + dev)
    emit("table3_energy", rows)
    return rows


if __name__ == "__main__":
    main()
