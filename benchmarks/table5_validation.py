"""Table V: validation of the profiler's array-level pricing against the
DESTINY-style device model on an LCS instruction trace (paper: 3000-instr
LCS; CiM 455-565 nJ vs non-CiM 124-154 nJ, 24% deviation band).

We compare (a) the energy of the CiM instruction stream priced via the full
system profiler vs (b) the same operation counts priced directly from the
device model (the DESTINY surrogate) — the paper's "Eva-CiM vs DESTINY"
axis.  Deviation must stay inside the paper's ~24% band + margin."""
from __future__ import annotations

from repro.core import (CIM_SET_STT, OffloadConfig, Profiler, reshape,
                        select_candidates, TECHS)
from benchmarks.common import banner, cached_trace, emit


def run():
    tr = cached_trace("LCS")
    res = select_candidates(tr.trace, cfg=OffloadConfig(cim_set=CIM_SET_STT))
    rs = reshape(tr.trace, res)
    prof = Profiler(tuple(l.cfg for l in tr.cache.levels), tech="sram")
    _, _ = prof.price_baseline(tr.trace)
    cim_eb, _ = prof.price_cim(tr.trace, rs)

    # (a) profiler's CiM-array energy (interactions included)
    profiler_cim_nj = sum(cim_eb.cim.values()) / 1e3
    # (b) DESTINY-surrogate direct pricing of the same op counts
    tech = TECHS["sram"]
    levels = {l.cfg.name: l.cfg for l in tr.cache.levels}
    destiny_cim_nj = sum(
        tech.energy(cls, levels[g.level])
        for g in rs.cim_groups for cls in g.op_classes) / 1e3
    # same comparison for the regular (non-CiM) accesses they replace
    destiny_noncim_nj = sum(
        tech.energy("write" if tr.trace[s].is_store else "read",
                    levels.get(tr.trace[s].level, levels["L1"])
                    if tr.trace[s].level != "MEM" else levels["L2"])
        for c in res.candidates for s in c.load_seqs + c.store_seqs) / 1e3
    profiler_noncim_nj = destiny_noncim_nj  # identical pricing source
    dev = abs(profiler_cim_nj - destiny_cim_nj) / max(destiny_cim_nj, 1e-9)
    rows = [{
        "model": "DESTINY-surrogate", "cim_nj": round(destiny_cim_nj, 2),
        "non_cim_nj": round(destiny_noncim_nj, 2)},
        {"model": "Eva-CiM profiler", "cim_nj": round(profiler_cim_nj, 2),
         "non_cim_nj": round(profiler_noncim_nj, 2)},
        {"model": "deviation", "cim_nj": round(dev * 100, 1),
         "non_cim_nj": 0.0},
    ]
    # the paper's own Table V ratio: CiM energy ~3.7x non-CiM on this trace
    ratio = profiler_cim_nj / max(profiler_noncim_nj, 1e-9)
    rows.append({"model": "cim/non-cim ratio (paper ~3.7)",
                 "cim_nj": round(ratio, 2), "non_cim_nj": 0.0})
    return rows


def main():
    banner("Table V: Eva-CiM vs DESTINY-surrogate (LCS trace)")
    rows = run()
    for r in rows:
        print(f"  {r['model']:32s} CiM {r['cim_nj']:9.2f}  "
              f"non-CiM {r['non_cim_nj']:9.2f}")
    emit("table5_validation", rows)
    return rows


if __name__ == "__main__":
    main()
