"""Table VI: speedup, energy improvement, and the processor/cache
contribution breakdown for all 17 benchmarks (CiM vs non-CiM system)."""
from __future__ import annotations

from repro.core import OffloadConfig, profile_system
from repro.workloads import WORKLOADS
from benchmarks.common import banner, cached_trace, emit

PAPER = {  # benchmark: (speedup, energy improvement) from Table VI
    "NB": (1.51, 3.28), "DT": (1.52, 5.12), "SVM": (1.42, 2.83),
    "LiR": (1.24, 2.68), "KM": (1.30, 3.21), "LCS": (1.31, 4.31),
    "M2D": (1.34, 4.85), "BFS": (1.40, 2.33), "DFS": (1.55, 1.98),
    "BC": (0.99, 1.30), "SSSP": (1.34, 2.33), "CCOMP": (1.52, 3.46),
    "PRANK": (1.42, 4.54), "astar": (1.28, 5.26), "h264ref": (1.17, 2.05),
    "hmmer": (1.36, 2.87), "mcf": (1.27, 3.58),
}


def run():
    rows = []
    for name in WORKLOADS:
        tr = cached_trace(name)
        rep = profile_system(tr, OffloadConfig())
        p_spd, p_ei = PAPER[name]
        rows.append({
            "benchmark": name,
            "speedup": round(rep.speedup, 3),
            "energy_improvement": round(rep.energy_improvement, 3),
            "processor_ratio": round(rep.processor_ratio, 3),
            "cache_ratio": round(rep.cache_ratio, 3),
            "macr": round(rep.macr, 4),
            "paper_speedup": p_spd, "paper_energy_improvement": p_ei,
            "in_speedup_band": 0.95 <= rep.speedup <= 1.6,
        })
    return rows


def main():
    banner("Table VI: speedup + energy improvement (SRAM CiM)")
    rows = run()
    print(f"  {'bench':8s} {'spd':>6s} {'(paper)':>8s} {'E-imp':>7s} "
          f"{'(paper)':>8s} {'proc':>6s} {'cache':>6s}")
    for r in rows:
        print(f"  {r['benchmark']:8s} {r['speedup']:6.2f} "
              f"({r['paper_speedup']:5.2f}) {r['energy_improvement']:7.2f} "
              f"({r['paper_energy_improvement']:5.2f}) "
              f"{r['processor_ratio']:6.2f} {r['cache_ratio']:6.2f}")
    spd = [r["speedup"] for r in rows]
    ei = [r["energy_improvement"] for r in rows]
    print(f"  ranges: speedup {min(spd):.2f}-{max(spd):.2f} "
          f"(paper 0.99-1.55), E-imp {min(ei):.2f}-{max(ei):.2f} "
          f"(paper 1.30-5.26)")
    emit("table6_speedup", rows)
    return rows


if __name__ == "__main__":
    main()
