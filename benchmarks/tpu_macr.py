"""TPU-mode Eva-CiM: fusion-candidate analysis (the TPU-MACR) over every
assigned architecture's reduced train step — 'is this model step
CiM/fusion-favorable on the TPU memory hierarchy?' (DESIGN.md §3)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCHS, reduced_config
from repro.core.hlo import fusion_candidates
from repro.models import inputs as minputs
from repro.train import steps as steps_mod
from benchmarks.common import banner, emit


def run():
    rows = []
    rng = jax.random.PRNGKey(0)
    for arch in sorted(ARCHS):
        cfg = reduced_config(arch)
        state = jax.eval_shape(lambda r: steps_mod.init_train_state(r, cfg), rng)
        batch = minputs.make_train_batch(rng, cfg, batch=2, seq_len=32)
        step = steps_mod.make_train_step(cfg, TrainConfig())
        jx = jax.make_jaxpr(step)(
            jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), state),
            batch)
        rep = fusion_candidates(jx)
        big = max(rep.candidates, key=lambda c: c.saved_bytes, default=None)
        rows.append({
            "arch": arch,
            "n_candidates": len(rep.candidates),
            "total_mb": round(rep.total_bytes / 1e6, 2),
            "saved_mb": round(rep.saved_bytes / 1e6, 2),
            "tpu_macr": round(rep.tpu_macr, 4),
            "biggest_chain_ops": big.n_ops if big else 0,
        })
    return rows


def main():
    banner("TPU-mode MACR: VMEM-fusion candidates per arch (reduced step)")
    rows = run()
    for r in rows:
        print(f"  {r['arch']:24s} cands {r['n_candidates']:4d} "
              f"traffic {r['total_mb']:8.2f}MB eliminable {r['saved_mb']:8.2f}MB "
              f"tpu_macr {r['tpu_macr']:.3f} (max chain {r['biggest_chain_ops']})")
    emit("tpu_macr", rows)
    return rows


if __name__ == "__main__":
    main()
