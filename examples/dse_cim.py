"""Design-space exploration (the paper's three questions, §VI-D/E):

  1. Is this program CiM-favorable?          -> MACR + improvement
  2. Which cache level should host the CiM?  -> L1-only vs L2-only vs both
  3. Which technology?                       -> SRAM vs FeFET

    PYTHONPATH=src python examples/dse_cim.py --workload KM
"""
import argparse
import sys

from repro.core import (CIM_SET_STT, L1_32K, L1_64K, L2_256K, L2_2M,
                        OffloadConfig, profile_system, trace_program)
from repro.workloads import WORKLOADS, build


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="KM", choices=sorted(WORKLOADS))
    args = ap.parse_args(argv)

    fn, wargs = build(args.workload)

    print(f"== {args.workload}: cache-configuration sweep (Fig. 14) ==")
    for name, levels in (("32K/4w L1 + 256K/8w L2", (L1_32K, L2_256K)),
                         ("64K/4w L1 + 256K/8w L2", (L1_64K, L2_256K)),
                         ("64K/4w L1 + 2M/8w L2", (L1_64K, L2_2M))):
        tr = trace_program(fn, *wargs, cache_levels=levels)
        rep = profile_system(tr)
        print(f"  {name:26s} E-impr {rep.energy_improvement:5.2f}x "
              f"speedup {rep.speedup:5.2f}x macr {rep.macr:.3f}")

    print("== CiM level (Fig. 15) ==")
    tr = trace_program(fn, *wargs)
    for name, lv in (("L1 only", ("L1",)), ("L2 only", ("L2",)),
                     ("L1 + L2", ("L1", "L2"))):
        rep = profile_system(tr, OffloadConfig(cim_set=CIM_SET_STT,
                                               cim_levels=lv))
        print(f"  {name:10s} E-impr {rep.energy_improvement:5.2f}x "
              f"speedup {rep.speedup:5.2f}x")

    print("== technology (Fig. 16) ==")
    base_sram = profile_system(tr, tech="sram")
    for tech in ("sram", "fefet"):
        rep = profile_system(tr, tech=tech)
        # paper normalizes to the SRAM non-CiM baseline
        cross = base_sram.base.total / rep.cim.total
        print(f"  {tech:6s} E-impr vs SRAM-baseline {cross:5.2f}x "
              f"speedup {rep.speedup:5.2f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
