"""Design-space exploration with `repro.dse` (the paper's §VI-D/E questions).

Quickstart
==========
A sweep is a typed cross-product over the paper's design axes (workload,
cache geometry, CiM level set, device technology, host CPU); the engine
memoizes the expensive trace/IDG analysis per (workload, cache) and fans
the cheap pricing phase out over a worker pool::

    from repro.dse import DSEEngine, SweepSpace

    space = SweepSpace(
        workloads=("KM", "BFS"),                 # Table IV programs
        caches=("32K+256K", "64K+256K", "64K+2M"),   # Fig. 14 axis
        cim_levels=("L1_only", "L2_only", "both"),   # Fig. 15 axis
        techs=("sram", "fefet"),                     # Fig. 16 axis
    )
    results = DSEEngine().run(space)             # 36 points, 6 analyses

    best = results.best("energy_improvement", workload="KM")
    front = results.pareto(("energy_improvement", "speedup"))
    print(results.to_markdown())                 # report w/ Pareto frontier
    results.to_json("sweep.json")                # structured records

Run this module for a guided tour over one workload::

    PYTHONPATH=src python examples/dse_cim.py --workload KM
    PYTHONPATH=src python examples/dse_cim.py --workload KM --report sweep.md

``--cache-dir DIR`` persists every analysis artifact; a second invocation
with the same directory performs zero trace builds.  ``--hosts`` adds the
host-CPU axis (named presets from ``repro.core.host_model.HOST_PRESETS``)::

    PYTHONPATH=src python examples/dse_cim.py --workload KM \\
        --cache-dir ~/.cache/eva-cim --hosts A9-1GHz,inorder-1GHz,A9-2GHz

``--adaptive`` swaps the exhaustive cross-product for frontier-driven
refinement (``repro.dse.AdaptiveDSE``): price a coarse seed, then only the
axis neighborhoods of non-dominated points, round by round, until the
frontier is stable — same frontier, a fraction of the points priced::

    PYTHONPATH=src python examples/dse_cim.py --workload KM --adaptive

``--backend tpu`` runs the *same* CLI surface through the TPU-mode
pipeline (``repro.dse.TpuBackend``): workloads are arch ids from
``repro.configs.registry``, the swept axis is chip preset x fusion
threshold (``repro.dse.TpuOption``), and every flag above — executor,
cache dir, adaptive refinement, reports — behaves identically::

    PYTHONPATH=src python examples/dse_cim.py --backend tpu \\
        --workload qwen1.5-0.5b --chips v5e,v4,v5p --thresholds 16K,64K,256K
"""
import argparse
import sys

from repro import obs
from repro.core.sampling import SamplingSpec
from repro.dse import (AdaptiveDSE, CimBackend, DSEEngine, HOST_PRESETS,
                       StoreFormatError, SweepSpace, TPU_PRESETS, TpuBackend,
                       TpuOption, parse_bytes)
from repro.workloads import WORKLOADS


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="cim", choices=["cim", "tpu"],
                    help="analysis pipeline: the paper's CiM trace/IDG "
                         "path, or the TPU-mode jaxpr/HLO fusion path")
    ap.add_argument("--workload", default=None,
                    help="CiM: a Table-IV program (default KM); TPU: an "
                         "arch id from repro.configs.registry (default "
                         "qwen1.5-0.5b)")
    ap.add_argument("--executor", default="thread",
                    choices=["thread", "process", "serial"])
    ap.add_argument("--cache-dir", default=None,
                    help="persistent AnalysisStore directory: repeated "
                         "invocations load artifacts instead of re-tracing")
    ap.add_argument("--hosts", default=None,
                    help="comma-separated host presets to sweep "
                         f"(known: {','.join(HOST_PRESETS)}; CiM backend)")
    ap.add_argument("--chips", default=None,
                    help="comma-separated TPU chip presets "
                         f"(known: {','.join(TPU_PRESETS)}; TPU backend "
                         "only, default v5e,v4,v5p)")
    ap.add_argument("--thresholds", default=None,
                    help="comma-separated fusion min_saved_bytes values "
                         "(TPU backend only, default 16K,64K,256K)")
    ap.add_argument("--report", default=None,
                    help="write the markdown sweep report here")
    ap.add_argument("--json", default=None,
                    help="write structured sweep records here")
    ap.add_argument("--adaptive", action="store_true",
                    help="frontier-driven refinement instead of the "
                         "exhaustive cross-product (same frontier, fewer "
                         "points priced)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable span tracing and write a Chrome "
                         "trace-event file here (open in ui.perfetto.dev)")
    ap.add_argument("--trace-report", action="store_true",
                    help="enable span tracing and print the per-stage "
                         "attribution table after the run")
    ap.add_argument("--sample", default=None, metavar="MODE[:k=v,...]",
                    help="statistical sampling instead of exact analysis "
                         "(CiM backend): 'stratified' or 'phase', with "
                         "optional knobs, e.g. "
                         "phase:interval=2048,budget=32. Sampled records "
                         "carry bootstrap CI columns, and --workload "
                         "accepts loop-scaled 'name@scale' variants")
    args = ap.parse_args(argv)

    # each backend owns some axes; mixing them is a mistake worth stopping
    # at the door rather than silently ignoring the flag (exit code 2)
    if args.backend == "tpu" and args.hosts is not None:
        ap.error("--hosts sweeps host CPUs, a CiM-backend axis; the TPU "
                 "pipeline has no host axis. Drop --hosts or use "
                 "--backend cim.")
    if args.backend == "tpu" and args.sample is not None:
        ap.error("--sample draws windows from the CiM instruction trace; "
                 "the TPU jaxpr/HLO pipeline has no trace to sample. Drop "
                 "--sample or use --backend cim.")
    if args.backend == "cim":
        tpu_only = [flag for flag, val in (("--chips", args.chips),
                                           ("--thresholds", args.thresholds))
                    if val is not None]
        if tpu_only:
            ap.error(f"{'/'.join(tpu_only)} select TPU chip presets and "
                     f"fusion thresholds, TPU-backend axes; the CiM "
                     f"pipeline sweeps caches/levels/techs instead. Drop "
                     f"{'/'.join(tpu_only)} or use --backend tpu.")

    args.tracing = bool(args.trace or args.trace_report)
    if args.tracing:
        # self-time attribution only telescopes to the run's wall-clock
        # when stages don't overlap; honor an explicit --executor, but
        # default a traced run to serial so the report sums to ~100%
        if "--executor" not in (argv if argv is not None else sys.argv[1:]):
            args.executor = "serial"
        obs.enable(obs.Tracer())

    if args.backend == "tpu":
        return _tpu_main(args)

    sampling = SamplingSpec()
    if args.sample:
        try:
            sampling = SamplingSpec.parse(args.sample)
        except ValueError as exc:
            ap.error(f"bad --sample: {exc}")
    args.workload = args.workload or "KM"
    base_workload = args.workload.partition("@")[0]
    if base_workload not in WORKLOADS:
        ap.error(f"unknown workload {args.workload!r}; "
                 f"known: {sorted(WORKLOADS)}")
    if "@" in args.workload and sampling.is_exact:
        ap.error(f"loop-scaled workload {args.workload!r} needs --sample "
                 f"(exact analysis only prices registry-sized workloads)")
    try:
        engine = DSEEngine(executor=args.executor, store=args.cache_dir,
                           backend=CimBackend(sampling=sampling))
    except StoreFormatError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    hosts = tuple(args.hosts.split(",")) if args.hosts else (None,)
    space = SweepSpace(workloads=(args.workload,),
                       caches=("32K+256K", "64K+256K", "64K+2M"),
                       cim_levels=("L1_only", "L2_only", "both"),
                       techs=("sram", "fefet"),
                       hosts=hosts)
    print(f"== {args.workload}: {len(space)} design points, "
          f"{space.n_analyses()} trace analyses ==")
    if not sampling.is_exact:
        print(f"   sampling: {sampling.key()} "
              f"(metrics are estimates ± bootstrap CI)")
    if args.adaptive:
        adaptive = AdaptiveDSE(space, engine=engine).run()
        for line in adaptive.summary().splitlines():
            print(f"   {line}")
        results = adaptive.results
    else:
        results = engine.run(space)
    st = results.stats
    print(f"   done in {results.elapsed_s:.1f}s "
          f"(trace builds {st.get('trace_builds')}, "
          f"selection builds {st.get('offload_builds')})")
    if args.cache_dir:
        print(f"   store: {st.get('store_l1_hits', 0)} trace hits / "
              f"{st.get('store_l2_hits', 0)} selection hits / "
              f"{st.get('store_writes', 0)} writes / "
              f"{st.get('store_corrupt_drops', 0)} corrupt drops "
              f"under {args.cache_dir}")
        _print_store_bytes(st)

    # the fixed Fig. 14/15/16 slices assume the full grid was priced —
    # an adaptive run skips dominated regions, so go straight to the front
    if args.adaptive:
        print("== Pareto frontier (identical to the exhaustive sweep's) ==")
        for r in adaptive.frontier:
            print(f"  {r.config_label:34s} E {r.energy_improvement:5.2f}x "
                  f"spd {r.speedup:5.2f}x  (round {r.round})")
        if args.report:
            with open(args.report, "w") as f:
                f.write(adaptive.to_markdown())
            print(f"[report] {args.report}")
        if args.json:
            results.to_json(args.json)
            print(f"[json] {args.json}")
        _finish_trace(args)
        return 0

    # the Fig. 14/15/16 slices fix the host axis at its first value
    host0 = results.records[0].host

    print(f"== cache-configuration slice (Fig. 14, CiM@L1+L2, SRAM) ==")
    for r in results:
        if r.cim_levels == "L1+L2" and r.tech == "sram" and r.host == host0:
            print(f"  {r.cache:10s} E-impr {r.energy_improvement:5.2f}x "
                  f"speedup {r.speedup:5.2f}x macr {r.macr:.3f}")

    print("== CiM level slice (Fig. 15, 32K+256K, SRAM) ==")
    for r in results:
        if r.cache == "32K+256K" and r.tech == "sram" and r.host == host0:
            print(f"  {r.cim_levels:6s} E-impr {r.energy_improvement:5.2f}x "
                  f"speedup {r.speedup:5.2f}x")

    print("== technology slice (Fig. 16, 32K+256K, CiM@L1+L2) ==")
    sram_base = next(r.base_energy_pj for r in results
                     if r.cache == "32K+256K" and r.cim_levels == "L1+L2"
                     and r.tech == "sram" and r.host == host0)
    for r in results:
        if (r.cache == "32K+256K" and r.cim_levels == "L1+L2"
                and r.host == host0):
            # paper normalizes to the SRAM non-CiM baseline
            print(f"  {r.tech:6s} E-impr vs SRAM-baseline "
                  f"{sram_base / r.cim_energy_pj:5.2f}x "
                  f"speedup {r.speedup:5.2f}x")

    if args.hosts:
        print("== host-model slice (32K+256K, CiM@L1+L2, SRAM) ==")
        for r in results:
            if (r.cache == "32K+256K" and r.cim_levels == "L1+L2"
                    and r.tech == "sram"):
                print(f"  {r.host:14s} E-impr {r.energy_improvement:5.2f}x "
                      f"speedup {r.speedup:5.2f}x")

    front = results.pareto(("energy_improvement", "speedup"))
    print(f"== Pareto frontier (energy improvement vs speedup) ==")
    for r in front:
        ci = (f" ±{r.energy_improvement_ci:.2f}" if r.sampling != "exact"
              else "")
        print(f"  {r.config_label:34s} E {r.energy_improvement:5.2f}x{ci} "
              f"spd {r.speedup:5.2f}x")

    if args.report:
        with open(args.report, "w") as f:
            f.write(results.to_markdown())
        print(f"[report] {args.report}")
    if args.json:
        results.to_json(args.json)
        print(f"[json] {args.json}")
    _finish_trace(args)
    return 0


def _finish_trace(args) -> None:
    """Export/report the run's spans (``--trace`` / ``--trace-report``)."""
    if not getattr(args, "tracing", False):
        return
    t = obs.tracer()
    if args.trace:
        n = t.export_chrome(args.trace)
        print(f"[trace] {args.trace}: {n} events "
              f"(load in ui.perfetto.dev)")
    if args.trace_report:
        print(obs.attribution_markdown(t.stage_attribution()))
    obs.disable()


def _print_store_bytes(st: dict) -> None:
    """Per-layer / per-backend on-disk footprint (AnalysisStore.stats())."""
    total = st.get("store_bytes_total")
    if not total:
        return
    def mb(n):
        return f"{n / 1e6:.2f} MB" if n >= 1e5 else f"{n / 1e3:.1f} KB"
    backends = ", ".join(
        f"{k.split('store_bytes_')[1]} {mb(v)}"
        for k, v in sorted(st.items())
        if k.startswith("store_bytes_")
        and k not in ("store_bytes_total", "store_bytes_layer1",
                      "store_bytes_layer2"))
    print(f"   store size: {mb(total)} on disk "
          f"(layer1 {mb(st.get('store_bytes_layer1', 0))} / "
          f"layer2 {mb(st.get('store_bytes_layer2', 0))}; {backends})")


def _tpu_main(args) -> int:
    """The TPU-mode half of the CLI: same flags, same flow, TpuBackend."""
    from repro.configs.registry import ARCHS
    workload = args.workload or "qwen1.5-0.5b"
    if workload not in ARCHS:
        print(f"unknown arch {workload!r}; known: {sorted(ARCHS)}")
        return 1
    chips = tuple((args.chips or "v5e,v4,v5p").split(","))
    for c in chips:
        if c not in TPU_PRESETS:
            print(f"unknown TPU chip preset {c!r}; "
                  f"known: {sorted(TPU_PRESETS)}")
            return 1
    raw_thresholds = args.thresholds or "16K,64K,256K"
    try:
        thresholds = tuple(parse_bytes(t) for t in raw_thresholds.split(","))
    except ValueError:
        print(f"bad --thresholds {raw_thresholds!r}; expected "
              f"comma-separated byte counts like 16K,64K,1M")
        return 1
    tpus = [TpuOption(TPU_PRESETS[c], t) for c in chips for t in thresholds]
    try:
        engine = DSEEngine(executor=args.executor, store=args.cache_dir,
                           backend=TpuBackend())
    except StoreFormatError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    space = SweepSpace(workloads=(workload,), tpus=tuple(tpus))
    print(f"== {workload}: {len(space)} design points, "
          f"1 jaxpr/HLO analysis ==")
    if args.adaptive:
        adaptive = AdaptiveDSE(space, engine=engine).run()
        for line in adaptive.summary().splitlines():
            print(f"   {line}")
        results = adaptive.results
    else:
        results = engine.run(space)
    st = results.stats
    print(f"   done in {results.elapsed_s:.1f}s "
          f"(HLO analyses {st.get('trace_builds')}, "
          f"fusion selections {st.get('offload_builds')})")
    if args.cache_dir:
        print(f"   store: {st.get('store_l1_hits', 0)} analysis hits / "
              f"{st.get('store_writes', 0)} writes / "
              f"{st.get('store_corrupt_drops', 0)} corrupt drops "
              f"under {args.cache_dir}")
        _print_store_bytes(st)

    if not args.adaptive:
        chip0, thr0 = results.records[0].cache, results.records[0].cim_set
        print(f"== chip slice (threshold {thr0}) ==")
        for r in results:
            if r.cim_set == thr0:
                print(f"  {r.cache:6s} E-impr {r.energy_improvement:5.2f}x "
                      f"speedup {r.speedup:5.2f}x bound "
                      f"{r.cim_runtime_ms:.4f}ms")
        print(f"== fusion-threshold slice (chip {chip0}) ==")
        for r in results:
            if r.cache == chip0:
                print(f"  {r.cim_set:8s} tpu_macr {r.macr:.3f} "
                      f"E-impr {r.energy_improvement:5.2f}x "
                      f"speedup {r.speedup:5.2f}x")

    front = (adaptive.frontier if args.adaptive
             else results.pareto(("energy_improvement", "speedup")))
    print("== Pareto frontier (energy improvement vs speedup) ==")
    for r in front:
        print(f"  {r.workload}/{r.cache}/{r.cim_set:8s} "
              f"E {r.energy_improvement:5.2f}x spd {r.speedup:5.2f}x")

    if args.report:
        text = (adaptive.to_markdown() if args.adaptive
                else results.to_markdown(
                    columns=("workload", "cache", "cim_set", "macr",
                             "energy_improvement", "speedup")))
        with open(args.report, "w") as f:
            f.write(text)
        print(f"[report] {args.report}")
    if args.json:
        results.to_json(args.json)
        print(f"[json] {args.json}")
    _finish_trace(args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
