"""Serve + query the DSE daemon (`repro.dse.service`).

The engine as a resident service: one warm analysis cache answers many
clients' sweep/adaptive queries over HTTP/JSON, coalescing duplicate
work.  This example runs the whole loop in one process — start an
in-process daemon, query it like a remote client would, and read the
coalescing evidence off ``/metrics``::

    PYTHONPATH=src python examples/dse_service.py
    PYTHONPATH=src python examples/dse_service.py --cache-dir /tmp/eva-store

Against a real daemon the client half is identical — start one with::

    PYTHONPATH=src python -m repro.dse.service --port 8321

and point :class:`repro.dse.service.ServiceClient` at
``http://127.0.0.1:8321``.
"""
import argparse
import sys
import threading

from repro.dse.service import ServiceClient, running_server


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="NB",
                    help="a Table-IV program (default NB, the smallest)")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent AnalysisStore dir shared with the CLI")
    args = ap.parse_args(argv)

    with running_server(cache_dir=args.cache_dir) as (url, _service):
        client = ServiceClient(url)
        print(f"== daemon up at {url}: {client.healthz()['status']} ==")

        # -- exhaustive sweep --------------------------------------------
        reply = client.sweep([args.workload],
                             caches=["32K+256K", "64K+256K", "64K+2M"],
                             cim_levels=["L1_only", "L2_only", "both"],
                             techs=["sram", "fefet"])
        print(f"== sweep: {len(reply.records)} records, "
              f"{reply.stats.get('trace_builds')} trace builds ==")
        for rec in reply.frontier:
            print(f"   frontier {rec['cache']}/cim@{rec['cim_levels']}"
                  f"/{rec['tech']}: E {rec['energy_improvement']:.2f}x "
                  f"spd {rec['speedup']:.2f}x")

        # -- adaptive, streamed round by round ---------------------------
        print("== adaptive (rounds stream as they complete) ==")
        for event in client.adaptive_events(
                [args.workload],
                caches=["32K+256K", "64K+256K", "64K+2M"],
                cim_levels=["L1_only", "L2_only", "both"],
                techs=["sram", "fefet"]):
            if event["event"] == "round":
                print(f"   round {event['round']}: {event['n_priced']} new "
                      f"points, frontier {event['frontier_size']}"
                      + (" [stable]" if event["stable"] else ""))
            elif event["event"] == "result":
                print(f"   result: {event['n_records']} points priced total")

        # -- two overlapping clients: the daemon computes each key once --
        spaces = (["sram", "fefet"], ["fefet"])        # overlapping techs
        threads = [threading.Thread(
            target=lambda t=t: client.sweep([args.workload], techs=t))
            for t in spaces]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        metrics = client.metrics()
        pts = metrics["service"]["points"]
        print(f"== metrics: {pts['requested']} points requested, "
              f"{pts['evaluated']} evaluated "
              f"({pts['coalesced']} coalesced in flight, "
              f"{pts['memo_hits']} memo hits) — "
              f"dedup {metrics['dedup_ratio']}x ==")
        if args.cache_dir:
            store = metrics.get("store", {})
            print(f"   store: {store.get('store_l1_hits', 0)} l1 hits / "
                  f"{store.get('store_writes', 0)} writes / "
                  f"{store.get('store_corrupt_drops', 0)} corrupt drops "
                  f"under {args.cache_dir}")
    print("== daemon shut down cleanly ==")
    return 0


if __name__ == "__main__":
    sys.exit(main())
