"""Table VI end-to-end: trace + analyze all 17 benchmark applications and
print speedup / energy improvement / MACR / breakdown per program.

    PYTHONPATH=src python examples/evaluate_workloads.py [--tech fefet]
"""
import argparse
import sys
import time

from repro.core import (CIM_SET_FULL, CIM_SET_STT, OffloadConfig,
                        profile_system, trace_program)
from repro.workloads import CATEGORY, WORKLOADS, build


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tech", default="sram", choices=["sram", "fefet"])
    ap.add_argument("--cim-set", default="stt", choices=["stt", "full"])
    args = ap.parse_args(argv)
    cim_set = CIM_SET_STT if args.cim_set == "stt" else CIM_SET_FULL

    print(f"{'bench':9s} {'cat':7s} {'instrs':>8s} {'MACR':>6s} {'E-impr':>7s} "
          f"{'speedup':>8s} {'proc':>6s} {'cache':>6s} {'verdict'}")
    for name in WORKLOADS:
        t0 = time.time()
        fn, wargs = build(name)
        tr = trace_program(fn, *wargs)
        rep = profile_system(tr, OffloadConfig(cim_set=cim_set),
                             tech=args.tech)
        verdict = "favorable" if rep.cim_favorable else "unfavorable"
        print(f"{name:9s} {CATEGORY[name]:7s} {tr.n_instructions:8d} "
              f"{rep.macr:6.3f} {rep.energy_improvement:7.2f} "
              f"{rep.speedup:8.2f} {rep.processor_ratio:6.2f} "
              f"{rep.cache_ratio:6.2f} {verdict}  ({time.time()-t0:.1f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
