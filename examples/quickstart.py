"""Quickstart: evaluate one program on a CiM system in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py

Traces the paper's LCS validation workload through the Eva-CiM pipeline
(GEM5-analogue VM -> IDG offload analysis -> reshaping -> McPAT-analogue
profiler) and prints the system-level verdict for SRAM and FeFET CiM.
"""
import sys

from repro.core import (CIM_SET_STT, OffloadConfig, profile_system,
                        trace_program)
from repro.workloads import build


def main() -> int:
    fn, args = build("LCS")
    print("tracing LCS through the Eva-CiM VM ...")
    tr = trace_program(fn, *args)
    print(f"  committed instructions : {tr.n_instructions}")
    print(f"  memory accesses        : {tr.mem_accesses()}")
    print(f"  cache stats            : {tr.cache.stats()}")

    for tech in ("sram", "fefet"):
        rep = profile_system(tr, OffloadConfig(cim_set=CIM_SET_STT), tech=tech)
        s = rep.summary()
        print(f"\n[{tech.upper()}] CiM in L1+L2:")
        print(f"  MACR                : {s['macr']:.3f} "
              f"({'CiM-favorable' if rep.cim_favorable else 'CiM-unfavorable'})")
        print(f"  energy improvement  : {s['energy_improvement']:.2f}x "
              f"({s['base_energy_nj']:.0f} nJ -> {s['cim_energy_nj']:.0f} nJ)")
        print(f"  speedup             : {s['speedup']:.2f}x")
        print(f"  delta from processor: {s['processor_ratio']:+.2f}, "
              f"caches: {s['cache_ratio']:+.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
