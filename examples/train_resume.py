"""Fault-tolerant training demo: train, inject a node failure, auto-resume,
verify the loss trajectory is seamless.

    PYTHONPATH=src python examples/train_resume.py
"""
import shutil
import sys
import tempfile

from repro.launch import train as train_mod


def main() -> int:
    d = tempfile.mkdtemp(prefix="repro_ft_")
    try:
        print("== run 1: fails (injected) at step 17, recovers in-process ==")
        train_mod.main(["--arch", "xlstm-125m", "--steps", "30",
                        "--batch", "4", "--seq-len", "64",
                        "--save-every", "10", "--fail-at", "17",
                        "--ckpt-dir", d, "--log-every", "10"])
        print("== run 2: fresh process auto-resumes from the last snapshot ==")
        train_mod.main(["--arch", "xlstm-125m", "--steps", "40",
                        "--batch", "4", "--seq-len", "64",
                        "--save-every", "10", "--ckpt-dir", d,
                        "--log-every", "10"])
    finally:
        shutil.rmtree(d, ignore_errors=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
