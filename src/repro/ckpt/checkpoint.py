"""Checkpointing: atomic-rename npz snapshots, async save, auto-resume.

Crash-safety contract: a checkpoint directory only ever contains complete
snapshots — writes go to ``<step>.npz.tmp`` and are os.rename'd (atomic on
POSIX) once fsync'd, so a preempted save never corrupts restart state.
``CheckpointManager`` keeps the newest ``keep`` snapshots, saves on a
background thread (training continues through I/O), and ``restore_latest``
implements auto-resume after node failure.
"""
from __future__ import annotations

import concurrent.futures
import os
import pathlib
import re
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^(\d+)\.npz$")


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":        # ml_dtypes (bf16/f8): npz-unsafe —
            arr = arr.astype(np.float32)  # widen losslessly, cast on restore
        out[key] = arr
    return out


def _unflatten(template, arrays: Dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        arr = arrays[key]
        leaves.append(np.asarray(arr).astype(leaf.dtype).reshape(leaf.shape)
                      if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str, step: int, state: Any) -> str:
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    final = d / f"{step}.npz"
    tmp = d / f"{step}.npz.tmp"
    arrays = _flatten(state)
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)                                 # atomic publish
    return str(final)


def load_checkpoint(path: str, template: Any) -> Any:
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    return _unflatten(template, arrays)


def latest_step(directory: str) -> Optional[int]:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = [int(m.group(1)) for p in d.iterdir()
             if (m := _STEP_RE.match(p.name))]
    return max(steps) if steps else None


class CheckpointManager:
    """Async, bounded-retention checkpointing with auto-resume."""

    def __init__(self, directory: str, keep: int = 3, every: int = 50):
        self.dir = pathlib.Path(directory)
        self.keep = keep
        self.every = every
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._lock = threading.Lock()
        self._pending: Optional[concurrent.futures.Future] = None  # lint: guarded-by(_lock)

    # -------------------------------------------------------------- save
    def maybe_save(self, step: int, state: Any, *, force: bool = False) -> bool:
        if not force and (self.every <= 0 or step % self.every != 0):
            return False
        host_state = jax.tree_util.tree_map(np.asarray, state)  # snapshot now
        self.wait()                                       # one in flight max
        with self._lock:
            self._pending = self._pool.submit(self._save_and_gc, step,
                                              host_state)
        return True

    def _save_and_gc(self, step: int, state: Any) -> None:
        save_checkpoint(str(self.dir), step, state)
        with self._lock:
            steps = sorted(int(m.group(1)) for p in self.dir.iterdir()
                           if (m := _STEP_RE.match(p.name)))
            for s in steps[:-self.keep]:
                (self.dir / f"{s}.npz").unlink(missing_ok=True)

    def wait(self) -> None:
        # take the future under the lock, but block on it outside:
        # _save_and_gc acquires the same lock on the pool thread, so
        # holding it across .result() would deadlock
        with self._lock:
            pending, self._pending = self._pending, None
        if pending is not None:
            pending.result()

    # ------------------------------------------------------------ resume
    def restore_latest(self, template: Any) -> Tuple[Optional[int], Any]:
        """(step, state) of the newest snapshot, or (None, template)."""
        step = latest_step(str(self.dir))
        if step is None:
            return None, template
        state = load_checkpoint(str(self.dir / f"{step}.npz"), template)
        return step, state
