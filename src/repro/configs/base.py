"""Model / run configuration dataclasses.

Frozen + hashable so configs can be closed over by ``jax.jit`` and used as
static arguments. One ``ModelConfig`` instance per assigned architecture
lives in ``src/repro/configs/<arch>.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 1
    expert_d_ff: int = 0
    n_shared_experts: int = 0          # shared (always-on) experts, 0 = none
    capacity_factor: float = 1.25
    impl: str = "gather"               # "gather" (argsort dispatch) | "einsum" (one-hot dispatch)
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "none"                 # "xlstm" | "mamba2"
    d_state: int = 16
    n_heads: int = 0                   # SSM heads (hymba: same count as attn heads)
    head_dim: int = 0
    chunk: int = 128                   # chunked-scan block length
    conv_dim: int = 4                  # short causal conv width (mamba)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                        # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0            # 0 = full attention
    global_every: int = 0              # gemma3: every k-th layer is global (window=0)
    attn_logit_softcap: float = 0.0
    # --- block composition ---
    norm: str = "rms"                  # "rms" | "ln"
    tie_embeddings: bool = True
    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()
    # --- enc-dec / multimodal frontends (stubs provide embeddings) ---
    n_enc_layers: int = 0              # encdec only
    enc_len_ratio: int = 4             # encoder frames = seq_len // ratio (audio subsampling)
    n_prefix_embeds_ratio: int = 0     # vlm: patches = seq_len // ratio (prefix of the sequence)
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    # --- bookkeeping ---
    source: str = ""
    notes: str = ""

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 (embedding shard/MXU alignment)."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def is_recurrent(self) -> bool:
        return self.family == "ssm"

    @property
    def has_ssm_state(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def supports_long_decode(self) -> bool:
        """Sub-quadratic context path exists (SSM / sliding-window / local:global)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (unpadded vocab), used for 6·N·D model FLOPs."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d
        if not self.tie_embeddings:
            emb *= 2
        per_layer = 0
        # attention
        per_layer += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            per_layer += self.q_dim + 2 * self.kv_dim
        # ffn
        if self.moe.n_experts:
            e = self.moe
            per_layer += d * e.n_experts                       # router
            per_layer += 3 * d * e.expert_d_ff * (e.n_experts + e.n_shared_experts)
        elif self.d_ff:
            per_layer += 3 * d * self.d_ff                      # SwiGLU
        # ssm side (hybrid) / xlstm extras are small; approximate where present
        if self.ssm.kind == "mamba2":
            di = self.ssm.n_heads * self.ssm.head_dim
            per_layer += d * 2 * di + di * d + di * (2 * self.ssm.d_state)
        if self.family == "ssm":
            # mLSTM blocks: up-proj 2x + qkv + gates + down-proj (dominates)
            per_layer += 2 * (d * 2 * d) + 3 * d * d // 2
        per_layer += 2 * d                                      # norms
        dec_layers = L
        total = emb + dec_layers * per_layer
        if self.n_enc_layers:
            enc_per = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d + 3 * d * self.d_ff + 2 * d
            # decoder cross-attention adds one more attention block per layer
            total += self.n_enc_layers * enc_per + L * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared experts)."""
        if not self.moe.n_experts:
            return self.param_count()
        d, L, e = self.d_model, self.n_layers, self.moe
        total = self.param_count()
        all_experts = 3 * d * e.expert_d_ff * (e.n_experts + e.n_shared_experts) * L
        active = 3 * d * e.expert_d_ff * (e.top_k + e.n_shared_experts) * L
        return total - all_experts + active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # "train" | "prefill" | "decode"


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    # distributed-optimization knobs
    remat: str = "block"               # "none" | "block" | "full"
    grad_compression: str = "none"     # "none" | "bf16" | "int8_ef"
    microbatches: int = 1              # gradient accumulation
    zero1: bool = True                 # shard optimizer state over the data axis
