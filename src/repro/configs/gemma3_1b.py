"""gemma3-1b — dense, 5:1 local:global attention, 128k-class context.

[hf:google/gemma-3-1b-pt; unverified]
26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144, head_dim=256,
sliding_window=512 on local layers, every 6th layer global.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    sliding_window=512,
    global_every=6,
    rope_theta=1_000_000.0,
    attn_logit_softcap=0.0,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt (unverified)",
    notes=("long_500k runs: 5 of 6 layers are windowed; global layers keep "
           "full KV (kv=1 head, sequence-sharded) — see DESIGN.md."),
)
