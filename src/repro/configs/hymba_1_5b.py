"""hymba-1.5b — parallel attention + mamba heads in every block.

[arXiv:2411.13676; hf]
32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Attention is sliding-window (Hymba uses SWA in all but 3 layers; we use SWA
everywhere + the SSM path for global reach — noted reduction).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    sliding_window=1024,
    tie_embeddings=True,
    ssm=SSMConfig(kind="mamba2", d_state=16, n_heads=25, head_dim=64, chunk=128),
    source="arXiv:2411.13676",
    notes=("Meta tokens omitted (stub-level feature). vocab 32001 padded to "
           "32128 in the embedding for shard/MXU alignment."),
)
