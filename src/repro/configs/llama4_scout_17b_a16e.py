"""llama4-scout-17b-a16e — MoE, 16 experts top-1, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    rope_theta=500_000.0,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=16, top_k=1, expert_d_ff=8192,
                  n_shared_experts=0, capacity_factor=1.25, impl="einsum"),
    source="hf:meta-llama/Llama-4-Scout-17B-16E (unverified)",
    notes=("Assignment lists 16e top-1 only; the HF release also has a shared "
           "expert + interleaved dense layers which we omit to match the "
           "assigned spec exactly. Baseline MoE dispatch is one-hot einsum "
           "(GShard-style) — the beyond-paper hillclimb switches to gather."),
)
