"""moonshot-v1-16b-a3b — kimi/moonlight, MoE 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]
48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163_840,
    rope_theta=50_000.0,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=64, top_k=6, expert_d_ff=1408,
                  n_shared_experts=0, capacity_factor=1.3, impl="gather"),
    source="hf:moonshotai/Moonlight-16B-A3B",
    notes=("Moonlight additionally uses 2 shared experts and a dense first "
           "layer; assignment lists 64e top-6 only, which we follow. "
           "k=6 makes one-hot dispatch tensors prohibitive -> gather impl."),
)
