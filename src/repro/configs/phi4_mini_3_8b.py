"""phi4-mini-3.8b — dense, RoPE SwiGLU GQA. [arXiv:2412.08905; hf]

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200_064,
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="arXiv:2412.08905",
)
