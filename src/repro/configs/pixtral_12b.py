"""pixtral-12b — pixtral-ViT frontend + mistral-nemo backbone.

[hf:mistralai/Pixtral-12B-2409; unverified]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128.
Backbone only: the ViT frontend is a STUB — ``input_specs()`` provides
precomputed patch embeddings occupying the first seq_len//4 positions.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=131_072,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    n_prefix_embeds_ratio=4,
    source="hf:mistralai/Pixtral-12B-2409 (unverified)",
)
