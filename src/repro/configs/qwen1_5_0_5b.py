"""qwen1.5-0.5b — dense, QKV bias. [hf:Qwen/Qwen1.5-0.5B]

24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)
