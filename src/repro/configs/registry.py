"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig
from repro.configs import (
    llama4_scout_17b_a16e, moonshot_v1_16b_a3b, xlstm_125m, hymba_1_5b,
    qwen1_5_0_5b, gemma3_1b, yi_34b, phi4_mini_3_8b, seamless_m4t_large_v2,
    pixtral_12b,
)

_MODULES = (
    llama4_scout_17b_a16e, moonshot_v1_16b_a3b, xlstm_125m, hymba_1_5b,
    qwen1_5_0_5b, gemma3_1b, yi_34b, phi4_mini_3_8b, seamless_m4t_large_v2,
    pixtral_12b,
)

ARCHS: Dict[str, ModelConfig] = {m.CONFIG.arch_id: m.CONFIG for m in _MODULES}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def reduced_config(arch_id: str) -> ModelConfig:
    """Family-preserving reduced config for CPU smoke tests.

    Small layers/width/experts/vocab — same block structure, same code paths.
    """
    cfg = get_config(arch_id)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    # keep GQA grouping valid: heads must be a multiple of kv heads
    n_heads = (n_heads // n_kv) * n_kv or n_kv
    small = dict(
        n_layers=2 if cfg.family != "ssm" else 2,   # ssm: one mLSTM + one sLSTM
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        global_every=cfg.global_every if cfg.global_every else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
    )
    if cfg.moe.n_experts:
        small["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), expert_d_ff=64)
    if cfg.ssm.kind != "none":
        small["ssm"] = dataclasses.replace(
            cfg.ssm, n_heads=n_heads, head_dim=16, d_state=8, chunk=16)
    return dataclasses.replace(cfg, **small)
