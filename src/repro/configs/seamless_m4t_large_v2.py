"""seamless-m4t-large-v2 — encoder-decoder, multimodal. [arXiv:2308.11596; hf]

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.
Transformer backbone only: the speech frontend is a STUB — ``input_specs()``
provides precomputed frame embeddings (B, seq//4, d) to the 24L encoder;
the 24L decoder consumes text tokens with cross-attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    norm="ln",
    tie_embeddings=True,
    n_enc_layers=24,
    enc_len_ratio=4,
    source="arXiv:2308.11596",
    notes="vocab padded to 256256 for shard alignment.",
)
