"""Assigned input-shape set (same four shapes for every LM arch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache / recurrent state of length ``seq_len``); ``train_4k`` lowers
``train_step``; ``prefill_32k`` lowers the prefill ``serve_step``.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig

TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def cell_status(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """'run' or 'skip:<reason>' for an (arch x shape) cell.

    long_500k needs a sub-quadratic context path (SSM / hybrid / sliding
    window); pure full-attention archs skip it (recorded in DESIGN.md).
    """
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return "skip:full-attention arch has no sub-quadratic 500k path"
    return "run"


def runnable_cells(cfg: ModelConfig):
    return [s for s in ALL_SHAPES if cell_status(cfg, s) == "run"]
