"""xlstm-125m — sLSTM + mLSTM blocks (attention-free). [arXiv:2405.04517]

12L d_model=768 4H d_ff=0 vocab=50304. Alternating mLSTM/sLSTM pairs
(6x[mLSTM, sLSTM]); mLSTM blocks carry the up-projection (d_ff=0 means no
separate FFN, as in the paper's block design).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50_304,
    tie_embeddings=True,
    ssm=SSMConfig(kind="xlstm", n_heads=4, head_dim=192, chunk=128),
    source="arXiv:2405.04517 (unverified)",
    notes="O(1)-state decode: long_500k runs on the recurrent path.",
)
