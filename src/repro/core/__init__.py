"""Eva-CiM core: the paper's contribution as a composable library.

Pipeline (paper Fig. 1):

    trace_program (GEM5+probes)  ->  select_candidates (IDG, Alg. 1+2)
        ->  reshape (SIV-C)  ->  profile_system (modified McPAT)

plus the TPU-mode adaptation (``hlo_analysis`` / ``tpu_model`` /
``roofline``) that applies the same dependency-graph offload analysis to
compiled XLA programs — see DESIGN.md S3.
"""
from repro.core.cache import (CacheConfig, CacheHierarchy, L1_32K, L1_64K,
                              L2_256K, L2_2M, SPM_1M)
from repro.core.columnar import ColumnarTrace
from repro.core.device_model import FEFET, SRAM, TECHS, TechModel
from repro.core.host_model import DEFAULT_HOST, HostModel
from repro.core.idg import (FlowIndex, IDGBuilder, IDGNode, build_flow_index,
                            build_rut_iht)
from repro.core.isa import (CIM_SET_FULL, CIM_SET_LOGIC, CIM_SET_STT, Inst,
                            Trace)
from repro.core.offload import (Candidate, OffloadConfig, OffloadResult,
                                TraceAnalysis, analyze_trace,
                                select_candidates)
from repro.core.profiler import Profiler, SystemReport, profile_system
from repro.core.reshape import ReshapedTrace, reshape
from repro.core.trace import (Machine, StructuralTrace, TraceResult,
                              attach_cache_results, trace_program,
                              trace_structural)

__all__ = [
    "CacheConfig", "CacheHierarchy", "L1_32K", "L1_64K", "L2_256K", "L2_2M",
    "SPM_1M", "ColumnarTrace", "FEFET", "SRAM", "TECHS", "TechModel",
    "DEFAULT_HOST", "HostModel", "FlowIndex", "IDGBuilder", "IDGNode",
    "build_flow_index", "build_rut_iht", "CIM_SET_FULL",
    "CIM_SET_LOGIC", "CIM_SET_STT", "Inst", "Trace", "Candidate",
    "OffloadConfig", "OffloadResult", "TraceAnalysis", "analyze_trace",
    "select_candidates", "Profiler",
    "SystemReport", "profile_system", "ReshapedTrace", "reshape", "Machine",
    "StructuralTrace", "TraceResult", "attach_cache_results",
    "trace_program", "trace_structural",
]
