"""Accelerator-resident analysis hot loops (``EVA_CIM_ACCEL={numpy,jax}``).

The two numpy hot loops of the analysis pipeline — the per-geometry cache
replay (:meth:`repro.core.cache.CacheHierarchy.replay`) and the vectorized
placement half of Algorithm 1 (:func:`repro.core.offload._place`) — have
jax twins in this package:

  * :mod:`repro.core.accel.replay` — one jitted ``lax.scan`` over the
    structural access stream, ``vmap``-ped across every cache geometry of
    a sweep, reproducing the LRU/MSHR/writeback state machine bit-exactly
    (columns *and* counters);
  * :mod:`repro.core.accel.place` — the reduceat/bincount segment
    reductions of placement as jitted ``segment_max``/``segment_sum`` +
    sort-based unique counting, with optional Pallas kernels
    (:mod:`repro.core.accel.pallas_ops`) for the segment-reduce steps.

The numpy implementations stay in place as the reference oracle: the jax
path is *differentially tested* against them (``tests/test_accel.py``)
and every consumer falls back to numpy silently when jax is unavailable
or the trace exceeds the int32 address budget.

Backend selection
-----------------
``backend()`` reads the ``EVA_CIM_ACCEL`` environment variable ("numpy"
by default); :func:`set_backend` / :func:`use_backend` override it
in-process (tests, benchmarks).  Everything downstream —
``attach_cache_results``, ``_place``, ``AnalysisCache.replay_group``, the
engine/service warm paths — consults this one switch, so
``EVA_CIM_ACCEL=jax`` flips the whole pipeline at once while keeping
every artifact byte-identical.

Compile accounting
------------------
Every jitted entry point registers itself here; :func:`jit_compiles`
reports the total number of compiled specializations (the sum of the jit
caches' sizes).  The DSE service exposes it as the ``accel.jit_compiles``
metric so "a repeated sweep triggers zero recompilations" is observable.
"""
from __future__ import annotations

import contextlib
import os
from typing import List, Optional

BACKENDS = ("numpy", "jax")
ENV_VAR = "EVA_CIM_ACCEL"

_override: Optional[str] = None
_JITTED: List[object] = []                 # jitted fns, for compile counting


def backend() -> str:
    """The active analysis backend: the in-process override if one is set,
    else ``$EVA_CIM_ACCEL``, else ``"numpy"``."""
    name = _override or os.environ.get(ENV_VAR, "numpy") or "numpy"
    if name not in BACKENDS:
        raise ValueError(f"unknown {ENV_VAR} backend {name!r}; "
                         f"known: {BACKENDS}")
    return name


def enabled() -> bool:
    """True when the jax path should be attempted."""
    return backend() == "jax"


def set_backend(name: Optional[str]) -> None:
    """Override the env switch in-process (``None`` restores env lookup)."""
    global _override
    if name is not None and name not in BACKENDS:
        raise ValueError(f"unknown accel backend {name!r}; known: {BACKENDS}")
    _override = name


@contextlib.contextmanager
def use_backend(name: str):
    """Scoped backend override — the differential tests run both sides."""
    prev = _override
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


def register_jitted(fn):
    """Track a jitted callable for :func:`jit_compiles` accounting."""
    _JITTED.append(fn)
    return fn


def jit_compiles() -> int:
    """Total compiled specializations across the accel jit entry points.

    A repeated sweep over the same workloads/geometries must leave this
    number unchanged — the service's warm-path test asserts exactly that
    through the ``accel.jit_compiles`` metric."""
    total = 0
    for fn in _JITTED:
        try:
            total += int(fn._cache_size())
        except Exception:  # noqa: BLE001 — older jax without _cache_size
            pass
    return total


def replay_columns(addrs, is_writes, geometries):
    """Batched replay under the active backend; ``None`` means "use the
    numpy oracle" (backend is numpy, jax missing, or address overflow)."""
    if not enabled():
        return None
    try:
        from repro.core.accel.replay import replay_columns_batch
    except ImportError:
        return None
    from repro import obs
    if obs.tracer() is None:               # keep the untraced launch bare
        return replay_columns_batch(addrs, is_writes, geometries)
    before = jit_compiles()
    with obs.span("accel.replay_batch", cat="jit",
                  n_geometries=len(geometries),
                  n_accesses=int(len(addrs))) as sp:
        out = replay_columns_batch(addrs, is_writes, geometries)
        sp.set(jit_compiles=jit_compiles() - before)
        return out


def place_candidates(part, ct, cfg):
    """Jax placement under the active backend; ``None`` → numpy oracle."""
    if not enabled():
        return None
    try:
        from repro.core.accel.place import place_candidates_jax
    except ImportError:
        return None
    from repro import obs
    if obs.tracer() is None:               # hot per-config path: one read
        return place_candidates_jax(part, ct, cfg)
    before = jit_compiles()
    with obs.span("accel.place", cat="jit") as sp:
        out = place_candidates_jax(part, ct, cfg)
        sp.set(jit_compiles=jit_compiles() - before)
        return out
