"""Pallas segment-reduce kernels for the placement stage.

XLA lowers ``jax.ops.segment_sum``/``segment_max`` to scatter-adds whose
fusion is poor on TPU (serialized updates through HBM); placement's
reductions are tiny per segment but numerous, so they are exactly the
"scatter/segment-reduce steps where XLA fusion falls short" the ROADMAP
names.  These kernels recast the scatter as a dense one-hot contraction:

  * inputs are reshaped to ``(rows, 128)`` lanes and walked in
    ``(8, 128)`` blocks (the float32 TPU tile);
  * the grid is ``(segment_blocks, row_blocks)`` with the row dimension
    fastest, so each ``(8, 128)``-segment output block is revisited
    consecutively and accumulated in place (zero/-inf init on the first
    row block via ``pl.when``);
  * a block's contribution is ``(vals[:, :, None] * onehot).sum(1)`` —
    an (8,128)x(128,128) contraction that maps onto the MXU instead of
    a scatter.

On CPU the kernels run in interpret mode — numerically identical,
useful only for testing — so the placement kernel enables them when a
TPU is present or ``EVA_CIM_PALLAS=1`` forces them (the differential
tests do the latter).  Counts and depths fit int32 exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128
_SUBLANES = 8
_BLOCK = _LANES * _SUBLANES
_NEG = jnp.iinfo(jnp.int32).min


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _seg_kernel(is_max: bool):
    def kernel(vals_ref, ids_ref, out_ref):
        j = pl.program_id(0)               # segment block (output column)

        @pl.when(pl.program_id(1) == 0)
        def _init():
            out_ref[...] = jnp.full_like(out_ref, _NEG if is_max else 0)

        v = vals_ref[...].astype(jnp.int32)          # (8, 128)
        s = ids_ref[...]                             # (8, 128)
        seg = j * _LANES + jax.lax.broadcasted_iota(jnp.int32, (1, 1, _LANES),
                                                    2)
        match = s[:, :, None] == seg                 # (8, 128, 128)
        if is_max:
            contrib = jnp.where(match, v[:, :, None], _NEG).max(axis=1)
            out_ref[...] = jnp.maximum(out_ref[...], contrib)
        else:
            out_ref[...] += (v[:, :, None] * match).sum(axis=1)
    return kernel


def _segment_reduce(vals, ids, n_segments: int, is_max: bool):
    n = vals.shape[0]
    rows = -(-max(n, 1) // _BLOCK) * _SUBLANES
    seg_pad = -(-n_segments // _LANES) * _LANES
    pad = rows * _LANES - n
    fill = _NEG if is_max else 0
    v = jnp.pad(vals.astype(jnp.int32), (0, pad),
                constant_values=fill).reshape(rows, _LANES)
    s = jnp.pad(ids.astype(jnp.int32), (0, pad),
                constant_values=0).reshape(rows, _LANES)
    out = pl.pallas_call(
        _seg_kernel(is_max),
        out_shape=jax.ShapeDtypeStruct((_SUBLANES, seg_pad), jnp.int32),
        grid=(seg_pad // _LANES, rows // _SUBLANES),
        in_specs=[pl.BlockSpec((_SUBLANES, _LANES), lambda j, i: (i, 0)),
                  pl.BlockSpec((_SUBLANES, _LANES), lambda j, i: (i, 0))],
        out_specs=pl.BlockSpec((_SUBLANES, _LANES), lambda j, i: (0, j)),
        interpret=_interpret(),
    )(v, s)
    if is_max:
        return out.max(axis=0)[:n_segments]
    return out.sum(axis=0)[:n_segments]


# lint: numpy-twin(jax.ops.segment_sum)
def segment_sum(vals, ids, n_segments: int):
    """``jax.ops.segment_sum`` as a one-hot Pallas contraction.

    Padding lanes carry value 0 into segment 0, so they cancel."""
    return _segment_reduce(vals, ids, n_segments, is_max=False)


# lint: numpy-twin(jax.ops.segment_max)
def segment_max(vals, ids, n_segments: int):
    """``jax.ops.segment_max`` as a one-hot Pallas contraction.

    Empty segments come back as INT32_MIN, matching the XLA op's
    identity; padding lanes carry INT32_MIN into segment 0."""
    return _segment_reduce(vals, ids, n_segments, is_max=True)
