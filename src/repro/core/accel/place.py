"""Jax placement: the vectorized half of Algorithm 1 as segment reductions.

The numpy ``_place`` (``repro.core.offload``) computes, per structural
proto-candidate, four placement quantities against one geometry's
level/bank columns: the target CiM level (a segment-max over leaf
depths, lifted to the shallowest enabled level), the operand move count
(a segment-sum of leaves shallower than the target), the DRAM fill
count (unique ``(proto, line)`` pairs among MEM-served accesses), and
the home bank.  This module runs the same math as one jitted kernel:

  * the *structural* flat arrays (leaf/access sequence ids + proto ids,
    padded to powers of two with a sentinel segment) are built once per
    (structural trace, partition key) and memoized on the trace's shared
    ``_struct`` dict — geometry variants reuse them;
  * per geometry only the gathered ``level``/``addr`` values change, so
    repeated sweep points hit one compiled specialization (the proto
    count rides along as a traced scalar);
  * the numpy ``pid * 2**40 + line`` unique-key trick needs 64-bit ints
    the accelerator path doesn't have — uniqueness is counted instead
    via ``lexsort`` + adjacent-difference, which is exact in int32;
  * the segment reductions run through :mod:`jax.ops` by default, or the
    Pallas kernels of :mod:`repro.core.accel.pallas_ops` on TPU (or when
    ``EVA_CIM_PALLAS=1`` forces them — interpret mode on CPU).

``place_candidates_jax`` returns ``None`` whenever the trace exceeds the
int32 budget; the caller then falls back to the numpy oracle.
"""
from __future__ import annotations

import functools
import os
from typing import List, Optional, Tuple

import numpy as np

try:
    import jax
    import jax.numpy as jnp
except ImportError:                        # pragma: no cover - jax is baked in
    jax = None

from repro.core.accel import register_jitted
from repro.core.isa import LEVEL_MEM

_I32_LIM = 2 ** 31 - 1


def _pow2(n: int) -> int:
    return 1 << (max(1, int(n)) - 1).bit_length()


def _use_pallas() -> bool:
    return (jax.default_backend() == "tpu"
            or os.environ.get("EVA_CIM_PALLAS") == "1")


@functools.lru_cache(maxsize=None)
def _build(n_leaf: int, n_acc: int, n_seg_pad: int,
           enabled: Tuple[int, ...], depth_cap: int, use_pallas: bool):
    """Jitted placement kernel for one padded problem shape."""
    enabled_arr = jnp.asarray(enabled, jnp.int32)

    if use_pallas:
        from repro.core.accel import pallas_ops

        def seg_sum(v, i):
            return pallas_ops.segment_sum(v, i, n_seg_pad)

        def seg_max(v, i):
            return pallas_ops.segment_max(v, i, n_seg_pad)
    else:
        def seg_sum(v, i):
            return jax.ops.segment_sum(v, i, num_segments=n_seg_pad)

        def seg_max(v, i):
            return jax.ops.segment_max(v, i, num_segments=n_seg_pad)

    def kernel(leaf_level, leaf_pid, acc_level, acc_line, acc_pid, n_seg):
        # target level: deepest leaf (DRAM clamped to the cap), lifted to
        # the shallowest enabled depth; empty segments place at depth 0,
        # exactly like the numpy path's zero-filled max_depth
        depth = jnp.minimum(leaf_level - 1, depth_cap)
        max_depth = jnp.maximum(seg_max(depth, leaf_pid), 0)
        tpos = jnp.minimum(jnp.searchsorted(enabled_arr, max_depth),
                           len(enabled) - 1)
        target = enabled_arr[tpos]

        # moves: leaves resident shallower than the target level
        shallower = (depth < target[leaf_pid]).astype(jnp.int32)
        moves = seg_sum(shallower, leaf_pid)

        # DRAM fills: unique (proto, line) pairs among MEM-served accesses;
        # sort by (proto, line) and count group heads (sentinel-segment
        # entries — non-MEM accesses and padding — are masked out)
        pid_k = jnp.where(acc_level == LEVEL_MEM, acc_pid, n_seg)
        order = jnp.lexsort((acc_line, pid_k))
        sp = pid_k[order]
        sl = acc_line[order]
        head = jnp.concatenate([jnp.ones(1, bool),
                                (sp[1:] != sp[:-1]) | (sl[1:] != sl[:-1])])
        fills = seg_sum((head & (sp < n_seg)).astype(jnp.int32), sp)
        return target, moves, fills

    return register_jitted(jax.jit(kernel))


def _flat_arrays(part, ct, cfg):
    """Structural flat views of the partition, memoized per partition key
    on the trace's shared ``_struct`` dict (one build serves every
    geometry of a sweep)."""
    memo = ct._struct.setdefault("place_flat", {})
    key = cfg.partition_key()
    flat = memo.get(key)
    if flat is not None:
        return flat
    protos = part.protos
    n_seg = len(protos)
    leaf_counts = np.asarray([len(p.leaf_src) for p in protos], np.int64)
    acc_counts = np.asarray([len(p.load_seqs) + len(p.store_seqs)
                             for p in protos], np.int64)
    all_leaf = np.concatenate([np.asarray(p.leaf_src, np.int64)
                               for p in protos]) if leaf_counts.sum() \
        else np.empty(0, np.int64)
    acc_seqs = np.concatenate([np.asarray(p.load_seqs + p.store_seqs,
                                          np.int64)
                               for p in protos]) if acc_counts.sum() \
        else np.empty(0, np.int64)

    def pad(seqs, counts):
        n_pad = _pow2(len(seqs))
        seq_p = np.zeros(n_pad, np.int64)
        pid_p = np.full(n_pad, n_seg, np.int32)      # sentinel segment
        seq_p[:len(seqs)] = seqs
        pid_p[:len(seqs)] = np.repeat(np.arange(n_seg, dtype=np.int32),
                                      counts)
        return seq_p, pid_p

    flat = pad(all_leaf, leaf_counts) + pad(acc_seqs, acc_counts)
    memo[key] = flat
    return flat


# lint: numpy-twin(repro.core.offload:_place)
def place_candidates_jax(part, ct, cfg) -> Optional[List]:
    """``_place`` on the jax backend; ``None`` -> use the numpy oracle."""
    from repro.core.offload import _DEPTH_LEVEL, _LEVEL_DEPTH, Candidate

    if jax is None:
        return None
    protos = part.protos
    if not protos:
        return []
    leaf_seq, leaf_pid, acc_seq, acc_pid = _flat_arrays(part, ct, cfg)
    n_seg = len(protos)
    acc_addr = ct.addr[acc_seq]
    # int32 budget guard over the *real* access rows only: padding rows
    # carry the sentinel pid and gather ct.addr[0], which is -1 whenever
    # seq 0 is not a memory access — the kernel masks them out, so they
    # must not veto the jax path
    real_addr = acc_addr[acc_pid < n_seg]
    if len(real_addr) and (real_addr.min() < 0
                           or real_addr.max() // 64 >= _I32_LIM):
        return None
    depth_cap = max(_LEVEL_DEPTH[l] for l in cfg.cim_levels)
    enabled = tuple(sorted(_LEVEL_DEPTH[l] for l in cfg.cim_levels))
    fn = _build(len(leaf_seq), len(acc_seq), _pow2(n_seg + 1),
                enabled, depth_cap, _use_pallas())
    target, moves, fills = fn(
        ct.level[leaf_seq].astype(np.int32), leaf_pid,
        ct.level[acc_seq].astype(np.int32),
        (acc_addr // 64).astype(np.int32), acc_pid, np.int32(n_seg))
    target = np.asarray(target)[:n_seg]
    moves = np.asarray(moves)[:n_seg]
    fills = np.asarray(fills)[:n_seg]

    bank_col = ct.bank
    level_of = [_DEPTH_LEVEL[int(d)] for d in target]
    out = []
    for i, p in enumerate(protos):
        out.append(Candidate(
            root_seq=p.root_seq, op_seqs=p.op_seqs, op_classes=p.op_classes,
            load_seqs=p.load_seqs, store_seqs=p.store_seqs,
            level=level_of[i],
            bank=int(bank_col[p.load_seqs[0]]) if p.load_seqs else None,
            moves=int(moves[i]), internal_edges=p.internal_edges,
            added_loads=p.added_loads, memval_leaves=p.memval_leaves,
            dram_fills=int(fills[i])))
    return out
