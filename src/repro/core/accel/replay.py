"""Batched cache replay: ``CacheHierarchy.replay`` as a jitted jax scan.

One call evaluates *all* cache geometries of a sweep against the shared
structural access stream: the per-geometry LRU/MSHR/writeback state
machine runs as a single ``lax.scan`` over the stream, ``vmap``-ped
across the geometry batch, so N geometries cost one kernel launch
instead of N python replays.

Bit-exactness with the :class:`~repro.core.cache.CacheHierarchy` oracle
is the contract (the differential suite in ``tests/test_accel.py``
fuzzes it).  The OrderedDict semantics map onto arrays as follows:

  * **LRU order** — each resident way carries a monotonically increasing
    stamp ``t * K + slot``; ``t`` is the access index, ``slot`` numbers
    the python-side touch points of one access in their exact execution
    order (probes first, then the demand-fill/cascade-writeback chain of
    :meth:`CacheHierarchy._access`).  ``move_to_end`` is a fresh stamp;
    the eviction victim is the min-stamp resident way.  Same-set
    collisions inside one access (a cascade landing in the set a demand
    fill is about to evict from) resolve exactly like the dict, because
    the cascade's slot precedes the next demand fill's slot.
  * **MSHR file** — a per-level ``(M,)`` line array with insertion
    stamps; FIFO retirement evicts the min-stamp entry.  A merge
    (line already outstanding) bumps the count in python — which is
    never read and does not reorder — so it is a pure membership test.
  * **mark_dirty** — a dict value assignment: dirty bit only, no stamp.

Counters (hits/misses/writebacks/mem traffic) are derived from the
service levels plus two scanned accumulators, matching
:meth:`CacheHierarchy.counters` key-for-key so a fresh hierarchy can be
rehydrated with :meth:`~CacheHierarchy.restore_counters` (the same
counters-only contract the on-disk store already relies on).

Shapes are padded to powers of two (stream length, sets, ways, MSHRs,
batch) so repeated sweeps and fuzzed geometry batches reuse jit cache
entries; every jitted entry point is registered with
:func:`repro.core.accel.register_jitted` for compile accounting.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

try:
    import jax
    import jax.numpy as jnp
    from jax import lax
except ImportError:                        # pragma: no cover - jax is baked in
    jax = None

from repro.core.accel import register_jitted
from repro.core.cache import LINE, CacheConfig
from repro.core.isa import LEVEL_CODE, LEVEL_MEM

_I32_LIM = 2 ** 31 - 1


def _pow2(n: int) -> int:
    """Smallest power of two >= max(n, 1)."""
    return 1 << (max(1, int(n)) - 1).bit_length()


def _slots(n_levels: int):
    """Stamp slot ids for the touch points of one access, in the exact
    python execution order of ``CacheHierarchy._access``: probes for each
    level, then per demand-filled level its fill followed by the cascade
    writeback chain into the deeper levels."""
    lookup = list(range(n_levels))
    slot = n_levels
    demand = [0] * n_levels
    cascade = [[0] * n_levels for _ in range(n_levels)]
    for i in range(n_levels):
        demand[i] = slot
        slot += 1
        for m in range(i + 1, n_levels):
            cascade[i][m] = slot
            slot += 1
    return lookup, demand, cascade, slot    # slot == stamps per access


@functools.lru_cache(maxsize=None)
def _build(L: int, S: int, A: int, M: int):
    """Jitted, geometry-vmapped replay for L-level hierarchies padded to
    (S sets, A ways, M MSHR entries).  Cached per padded shape so every
    sweep over same-depth geometries shares one compilation."""
    lookup_slot, demand_slot, cascade_slot, K = _slots(L)
    BIG = jnp.int32(_I32_LIM)

    def geom(n_sets, assoc, banks, mshrs, lines, is_w, valid):
        ways = jnp.arange(A, dtype=jnp.int32)
        mslots = jnp.arange(M, dtype=jnp.int32)

        def fill(tags, dirty, stamp, l, set_l, line, dirty_in, en, stamp_val):
            """``_Level.fill`` at level ``l``: present -> refresh stamp and
            OR the dirty bit; absent -> insert (LRU-evicting when full),
            returning the dirty-victim flag + line for the cascade."""
            row_t, row_d, row_s = tags[l, set_l], dirty[l, set_l], stamp[l, set_l]
            present_vec = row_t == line
            present = present_vec.any()
            occ = row_t >= 0
            full = occ.sum() >= assoc[l]
            free_way = jnp.argmax(~occ & (ways < assoc[l]))
            lru_way = jnp.argmin(jnp.where(occ, row_s, BIG))
            ins_way = jnp.where(full, lru_way, free_way)
            way = jnp.where(present, jnp.argmax(present_vec), ins_way)
            victim = en & ~present & full & row_d[ins_way]
            victim_line = jnp.where(victim, row_t[ins_way], 0)
            new_d = jnp.where(present, row_d[way] | dirty_in, dirty_in)
            tags = tags.at[l, set_l, way].set(
                jnp.where(en, line, row_t[way]))
            dirty = dirty.at[l, set_l, way].set(
                jnp.where(en, new_d, row_d[way]))
            stamp = stamp.at[l, set_l, way].set(
                jnp.where(en, stamp_val, row_s[way]))
            return victim, victim_line, tags, dirty, stamp

        def step(carry, x):
            tags, dirty, stamp, mlines, mstamp, wbs, memw, t = carry
            line, wr, ok = x
            base = t * K
            set_l = [line % n_sets[l] for l in range(L)]

            # probe phase: first hit breaks the walk; every missed level
            # also probes its MSHR file
            found = jnp.bool_(False)
            merged = jnp.bool_(False)
            service = jnp.int32(L + 1)
            for l in range(L):
                row = tags[l, set_l[l]]
                probe = ok & ~found
                hit_vec = row == line
                hit = probe & hit_vec.any()
                way = jnp.argmax(hit_vec)
                stamp = stamp.at[l, set_l[l], way].set(          # move_to_end
                    jnp.where(hit, base + lookup_slot[l],
                              stamp[l, set_l[l], way]))
                miss = probe & ~hit
                mrow = mlines[l]
                in_flight = (mrow == line).any()
                merged = merged | (miss & in_flight)
                m_occ = mrow >= 0
                m_full = m_occ.sum() >= mshrs[l]
                m_free = jnp.argmax(~m_occ & (mslots < mshrs[l]))
                m_fifo = jnp.argmin(jnp.where(m_occ, mstamp[l], BIG))
                m_ins = jnp.where(m_full, m_fifo, m_free)
                insert = miss & ~in_flight
                mlines = mlines.at[l, m_ins].set(
                    jnp.where(insert, line, mrow[m_ins]))
                mstamp = mstamp.at[l, m_ins].set(
                    jnp.where(insert, t, mstamp[l, m_ins]))
                service = jnp.where(hit, jnp.int32(l + 1), service)
                found = found | hit

            # fill phase: allocate in every level above the service point;
            # each fill's dirty victim cascades into the next level down,
            # falling off the last level as a DRAM write
            for i in range(L):
                en = ok & (service >= jnp.int32(i + 2))
                flag, vline, tags, dirty, stamp = fill(
                    tags, dirty, stamp, i, set_l[i], line,
                    jnp.bool_(False), en, base + demand_slot[i])
                wbs = wbs.at[i].add(flag.astype(jnp.int32))
                for m in range(i + 1, L):
                    flag, vline, tags, dirty, stamp = fill(
                        tags, dirty, stamp, m, vline % n_sets[m], vline,
                        jnp.bool_(True), flag, base + cascade_slot[i][m])
                    wbs = wbs.at[m].add(flag.astype(jnp.int32))
                memw = memw + flag.astype(jnp.int32)

            # write-allocate: dirty the line in L1 (no LRU reorder)
            row0 = tags[0, set_l[0]]
            dirty = dirty.at[0, set_l[0]].set(
                dirty[0, set_l[0]] | ((row0 == line) & ok & wr))

            bank = line % banks[jnp.minimum(service, jnp.int32(L)) - 1]
            return ((tags, dirty, stamp, mlines, mstamp, wbs, memw, t + 1),
                    (service, merged, bank))

        init = (jnp.full((L, S, A), -1, jnp.int32),
                jnp.zeros((L, S, A), jnp.bool_),
                jnp.zeros((L, S, A), jnp.int32),
                jnp.full((L, M), -1, jnp.int32),
                jnp.zeros((L, M), jnp.int32),
                jnp.zeros((L,), jnp.int32),
                jnp.int32(0), jnp.int32(0))
        carry, (service, merged, bank) = lax.scan(
            step, init, (lines, is_w, valid))
        wbs, memw = carry[5], carry[6]
        lvl = jnp.arange(1, L + 1, dtype=jnp.int32)
        hits = (valid[None, :] & (service[None, :] == lvl[:, None])).sum(1)
        misses = (valid[None, :] & (service[None, :] > lvl[:, None])).sum(1)
        mem_reads = (valid & (service == L + 1)).sum()
        return service, merged, bank, hits, misses, wbs, mem_reads, memw

    fn = jax.jit(jax.vmap(geom, in_axes=(0, 0, 0, 0, None, None, None)))
    return register_jitted(fn)


# lint: numpy-twin(repro.core.cache:CacheHierarchy.replay, batched)
def replay_columns_batch(addrs, is_writes,
                         geometries: Sequence[Tuple[CacheConfig, ...]]
                         ) -> Optional[List[tuple]]:
    """Replay one access stream under every geometry in one batched call.

    Returns, per geometry, ``(level, hit, bank, mshr, counters)`` — the
    four columns of :meth:`CacheHierarchy.replay` (same dtypes) plus the
    :meth:`CacheHierarchy.counters` dict.  Returns ``None`` when jax is
    unavailable or the stream exceeds the int32 budget of the kernel
    (the caller falls back to the numpy oracle)."""
    if jax is None or not geometries:
        return None
    addrs = np.asarray(addrs, np.int64)
    n = addrs.shape[0]
    lines = addrs // LINE
    n_pad = _pow2(max(n, 64))
    if n and (lines.min() < 0 or lines.max() >= _I32_LIM):
        return None
    if n_pad * _slots(max(len(g) for g in geometries))[3] >= _I32_LIM:
        return None                        # LRU stamps would overflow int32

    lines_p = np.zeros(n_pad, np.int32)
    lines_p[:n] = lines
    wr_p = np.zeros(n_pad, bool)
    wr_p[:n] = np.asarray(is_writes, bool)
    valid = np.zeros(n_pad, bool)
    valid[:n] = True

    results: List[Optional[tuple]] = [None] * len(geometries)
    by_depth: Dict[int, List[int]] = {}
    for gi, levels in enumerate(geometries):
        by_depth.setdefault(len(levels), []).append(gi)
    for L, idxs in sorted(by_depth.items()):
        g_pad = _pow2(len(idxs))
        rows = idxs + [idxs[-1]] * (g_pad - len(idxs))   # pad with a repeat
        params = np.empty((4, g_pad, L), np.int32)
        for r, gi in enumerate(rows):
            for li, cfg in enumerate(geometries[gi]):
                params[:, r, li] = (cfg.n_sets, cfg.assoc, cfg.banks,
                                    cfg.mshrs)
        fn = _build(L, _pow2(params[0].max()), _pow2(params[1].max()),
                    _pow2(params[3].max()))
        out = fn(params[0], params[1], params[2], params[3],
                 lines_p, wr_p, valid)
        service, merged, bank, hits, misses, wbs, memr, memw = \
            [np.asarray(o) for o in out]
        for r, gi in enumerate(idxs):
            levels = geometries[gi]
            codes = np.asarray([LEVEL_CODE[c.name] for c in levels]
                               + [LEVEL_MEM], np.int8)
            sv = service[r, :n]
            counters = {"mem_reads": int(memr[r]), "mem_writes": int(memw[r])}
            for li, c in enumerate(levels):
                counters[f"{c.name}_hits"] = int(hits[r, li])
                counters[f"{c.name}_misses"] = int(misses[r, li])
                counters[f"{c.name}_writebacks"] = int(wbs[r, li])
            results[gi] = (codes[sv - 1], (sv == 1).astype(np.int8),
                           bank[r, :n].astype(np.int16),
                           merged[r, :n].astype(bool), counters)
    return results
