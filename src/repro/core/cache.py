"""Multi-level set-associative cache simulator (the trace VM's memory system).

Mirrors the slice of GEM5 the paper's Request/Access probes observe: every
load/store walks L1 -> L2 -> MEM with LRU replacement, write-back +
write-allocate, per-level banking, and a small MSHR file whose state is
recorded on each access (Table I "response from slave").

The simulator answers the question Eva-CiM's analysis stage needs per access:
*which level currently holds the line* (data locality for offload selection),
plus hit/miss statistics for the profiler.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

LINE = 64                                # bytes per cache line


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    name: str                            # "L1" | "L2"
    size: int                            # bytes
    assoc: int
    banks: int = 4
    mshrs: int = 8

    @property
    def n_sets(self) -> int:
        return max(1, self.size // (LINE * self.assoc))


# Paper §VI setup: 32KB/4-way L1 + 256KB/8-way L2 (validation), with
# 64KB/4-way and 2MB/8-way variants for the Fig. 14 DSE.
L1_32K = CacheConfig("L1", 32 * 1024, 4)
L1_64K = CacheConfig("L1", 64 * 1024, 4)
L2_256K = CacheConfig("L2", 256 * 1024, 8)
L2_2M = CacheConfig("L2", 2 * 1024 * 1024, 8)
SPM_1M = CacheConfig("L1", 1024 * 1024, 8)    # [23]-style single-level SPM


class _Level:
    __slots__ = ("cfg", "sets", "hits", "misses", "writebacks", "mshr")

    def __init__(self, cfg: CacheConfig):
        self.cfg = cfg
        # set index -> OrderedDict(tag -> dirty); LRU order = insertion order
        self.sets: List[OrderedDict] = [OrderedDict() for _ in range(cfg.n_sets)]
        self.hits = 0
        self.misses = 0
        self.writebacks = 0
        self.mshr: OrderedDict = OrderedDict()   # line -> outstanding count

    def lookup(self, line: int) -> bool:
        s = self.sets[line % self.cfg.n_sets]
        if line in s:
            s.move_to_end(line)
            self.hits += 1
            return True
        self.misses += 1
        return False

    def fill(self, line: int, dirty: bool = False) -> Optional[int]:
        """Insert line; returns evicted dirty line (writeback victim) or None."""
        s = self.sets[line % self.cfg.n_sets]
        victim = None
        if line in s:
            s[line] = s[line] or dirty
            s.move_to_end(line)
            return None
        if len(s) >= self.cfg.assoc:
            v_line, v_dirty = s.popitem(last=False)
            if v_dirty:
                self.writebacks += 1
                victim = v_line
        s[line] = dirty
        return victim

    def mark_dirty(self, line: int) -> None:
        s = self.sets[line % self.cfg.n_sets]
        if line in s:
            s[line] = True

    def mshr_probe(self, line: int) -> bool:
        """True if this miss merges into an in-flight MSHR entry."""
        if line in self.mshr:
            self.mshr[line] += 1
            return True
        if len(self.mshr) >= self.cfg.mshrs:
            self.mshr.popitem(last=False)            # oldest entry retires
        self.mshr[line] = 1
        return False

    def bank_of(self, addr: int) -> int:
        return (addr // LINE) % self.cfg.banks


@dataclasses.dataclass
class AccessResult:
    level: str                            # "L1" | "L2" | "MEM" (service level)
    hit: bool                             # hit at the *first* level probed
    bank: int                             # bank id at the service level
    mshr: bool                            # merged into an outstanding miss
    line: int


class CacheHierarchy:
    """L1 + optional L2 in front of main memory (inclusive, write-allocate).

    Two recording APIs drive the same simulator state:

      * :meth:`access` — one :class:`AccessResult` per call (the original
        probe-style interface, kept for tests and interactive use);
      * :meth:`replay` — batched access recording: an entire address
        stream in one call, returning the four memory-response *columns*
        (level/hit/bank/mshr codes) the columnar trace stores.  The trace
        VM emits the structural instruction stream once per workload and
        replays it here once per cache geometry.
    """

    def __init__(self, levels: Tuple[CacheConfig, ...] = (L1_32K, L2_256K)):
        self.levels = [_Level(c) for c in levels]
        self.mem_reads = 0
        self.mem_writes = 0

    # -- probes ----------------------------------------------------------
    def _access(self, addr: int, is_write: bool) -> Tuple[int, bool, int, bool]:
        """One access; returns (service-level index+1 [len+1 => MEM],
        first-level hit, bank, mshr-merged).  Lean core shared by
        :meth:`access` and :meth:`replay`."""
        line = addr // LINE
        levels = self.levels
        mshr_merged = False
        service = len(levels) + 1                     # sentinel: DRAM
        for i, lv in enumerate(levels):
            if lv.lookup(line):
                service = i + 1
                break
            mshr_merged = lv.mshr_probe(line) or mshr_merged
        else:
            self.mem_reads += 1                       # line fill from DRAM

        # allocate the line in every level above the service point
        for i in range(min(service - 1, len(levels))):
            lv = levels[i]
            victim = lv.fill(line)
            if victim is not None:
                self._writeback(victim, below=lv.cfg.name)
        if is_write:
            levels[0].mark_dirty(line)

        bank_level = levels[service - 1] if service <= len(levels) \
            else levels[-1]
        return service, service == 1, bank_level.bank_of(addr), mshr_merged

    def access(self, addr: int, is_write: bool) -> AccessResult:
        service, first_hit, bank, mshr = self._access(addr, is_write)
        name = (self.levels[service - 1].cfg.name
                if service <= len(self.levels) else "MEM")
        return AccessResult(name, first_hit, bank, mshr, addr // LINE)

    def replay(self, addrs, is_writes):
        """Batched access recording over a whole trace's memory stream.

        ``addrs`` / ``is_writes`` are parallel arrays (one entry per
        load/store in commit order).  Returns ``(level, hit, bank, mshr)``
        int8/int8/int16/bool numpy columns using the
        :data:`repro.core.isa.LEVELS` level codes.  State evolution is
        identical to calling :meth:`access` element-by-element — the
        batched form only removes per-access Python/object overhead.
        """
        import numpy as np
        from repro.core.isa import LEVEL_CODE, LEVEL_MEM

        n = len(addrs)
        level = np.empty(n, np.int8)
        hit = np.empty(n, np.int8)
        bank = np.empty(n, np.int16)
        mshr = np.empty(n, bool)
        addr_l = addrs.tolist() if hasattr(addrs, "tolist") else list(addrs)
        wr_l = (is_writes.tolist() if hasattr(is_writes, "tolist")
                else list(is_writes))
        access = self._access
        codes = [LEVEL_CODE[lv.cfg.name] for lv in self.levels] + [LEVEL_MEM]
        # L1-hit fast path (the overwhelmingly common case), inlined with
        # the level's internals bound to locals; every miss falls back to
        # the shared `_access` core, so state evolution stays identical.
        l1 = self.levels[0]
        l1_sets = l1.sets
        l1_nsets = l1.cfg.n_sets
        l1_banks = l1.cfg.banks
        l1_code = codes[0]
        l1_hits = 0
        for i in range(n):
            addr = addr_l[i]
            line = addr // LINE
            s = l1_sets[line % l1_nsets]
            if line in s:
                s.move_to_end(line)
                l1_hits += 1
                if wr_l[i]:
                    s[line] = True               # mark_dirty (line present)
                level[i] = l1_code
                hit[i] = True
                bank[i] = line % l1_banks
                mshr[i] = False
                continue
            l1.hits += l1_hits                   # flush before shared core
            l1_hits = 0
            service, first_hit, b, m = access(addr, wr_l[i])
            level[i] = codes[service - 1] if service - 1 < len(codes) \
                else LEVEL_MEM
            hit[i] = first_hit
            bank[i] = b
            mshr[i] = m
        l1.hits += l1_hits
        return level, hit, bank, mshr

    # -- counter snapshot (store rehydration) -----------------------------
    def counters(self) -> Dict[str, int]:
        out = {"mem_reads": self.mem_reads, "mem_writes": self.mem_writes}
        for lv in self.levels:
            out[f"{lv.cfg.name}_hits"] = lv.hits
            out[f"{lv.cfg.name}_misses"] = lv.misses
            out[f"{lv.cfg.name}_writebacks"] = lv.writebacks
        return out

    def restore_counters(self, counters: Dict[str, int]) -> None:
        """Restore hit/miss statistics (the persisted layer-1 .npz keeps
        counters, not the full LRU set state — nothing downstream of a
        finished trace reads the sets)."""
        self.mem_reads = int(counters.get("mem_reads", 0))
        self.mem_writes = int(counters.get("mem_writes", 0))
        for lv in self.levels:
            lv.hits = int(counters.get(f"{lv.cfg.name}_hits", 0))
            lv.misses = int(counters.get(f"{lv.cfg.name}_misses", 0))
            lv.writebacks = int(counters.get(f"{lv.cfg.name}_writebacks", 0))

    def _writeback(self, line: int, below: str) -> None:
        """Victim from `below` written into the next level (or DRAM)."""
        seen = False
        for lv in self.levels:
            if seen:
                victim = lv.fill(line, dirty=True)
                if victim is not None:
                    self._writeback(victim, below=lv.cfg.name)
                return
            seen = lv.cfg.name == below
        self.mem_writes += 1

    # -- residency query used by offload selection ------------------------
    def residency(self, addr: int) -> str:
        line = addr // LINE
        for lv in self.levels:
            if line in lv.sets[line % lv.cfg.n_sets]:
                return lv.cfg.name
        return "MEM"

    def bank_of(self, addr: int, level: str) -> int:
        for lv in self.levels:
            if lv.cfg.name == level:
                return lv.bank_of(addr)
        return 0

    # -- stats -------------------------------------------------------------
    def stats(self) -> Dict[str, Dict[str, int]]:
        out = {}
        for lv in self.levels:
            out[lv.cfg.name] = {"hits": lv.hits, "misses": lv.misses,
                                "writebacks": lv.writebacks,
                                "size": lv.cfg.size, "assoc": lv.cfg.assoc}
        out["MEM"] = {"reads": self.mem_reads, "writes": self.mem_writes}
        return out
