"""Multi-level set-associative cache simulator (the trace VM's memory system).

Mirrors the slice of GEM5 the paper's Request/Access probes observe: every
load/store walks L1 -> L2 -> MEM with LRU replacement, write-back +
write-allocate, per-level banking, and a small MSHR file whose state is
recorded on each access (Table I "response from slave").

The simulator answers the question Eva-CiM's analysis stage needs per access:
*which level currently holds the line* (data locality for offload selection),
plus hit/miss statistics for the profiler.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

LINE = 64                                # bytes per cache line


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    name: str                            # "L1" | "L2"
    size: int                            # bytes
    assoc: int
    banks: int = 4
    mshrs: int = 8

    @property
    def n_sets(self) -> int:
        return max(1, self.size // (LINE * self.assoc))


# Paper §VI setup: 32KB/4-way L1 + 256KB/8-way L2 (validation), with
# 64KB/4-way and 2MB/8-way variants for the Fig. 14 DSE.
L1_32K = CacheConfig("L1", 32 * 1024, 4)
L1_64K = CacheConfig("L1", 64 * 1024, 4)
L2_256K = CacheConfig("L2", 256 * 1024, 8)
L2_2M = CacheConfig("L2", 2 * 1024 * 1024, 8)
SPM_1M = CacheConfig("L1", 1024 * 1024, 8)    # [23]-style single-level SPM


class _Level:
    __slots__ = ("cfg", "sets", "hits", "misses", "writebacks", "mshr")

    def __init__(self, cfg: CacheConfig):
        self.cfg = cfg
        # set index -> OrderedDict(tag -> dirty); LRU order = insertion order
        self.sets: List[OrderedDict] = [OrderedDict() for _ in range(cfg.n_sets)]
        self.hits = 0
        self.misses = 0
        self.writebacks = 0
        self.mshr: OrderedDict = OrderedDict()   # line -> outstanding count

    def lookup(self, line: int) -> bool:
        s = self.sets[line % self.cfg.n_sets]
        if line in s:
            s.move_to_end(line)
            self.hits += 1
            return True
        self.misses += 1
        return False

    def fill(self, line: int, dirty: bool = False) -> Optional[int]:
        """Insert line; returns evicted dirty line (writeback victim) or None."""
        s = self.sets[line % self.cfg.n_sets]
        victim = None
        if line in s:
            s[line] = s[line] or dirty
            s.move_to_end(line)
            return None
        if len(s) >= self.cfg.assoc:
            v_line, v_dirty = s.popitem(last=False)
            if v_dirty:
                self.writebacks += 1
                victim = v_line
        s[line] = dirty
        return victim

    def mark_dirty(self, line: int) -> None:
        s = self.sets[line % self.cfg.n_sets]
        if line in s:
            s[line] = True

    def mshr_probe(self, line: int) -> bool:
        """True if this miss merges into an in-flight MSHR entry."""
        if line in self.mshr:
            self.mshr[line] += 1
            return True
        if len(self.mshr) >= self.cfg.mshrs:
            self.mshr.popitem(last=False)            # oldest entry retires
        self.mshr[line] = 1
        return False

    def bank_of(self, addr: int) -> int:
        return (addr // LINE) % self.cfg.banks


@dataclasses.dataclass
class AccessResult:
    level: str                            # "L1" | "L2" | "MEM" (service level)
    hit: bool                             # hit at the *first* level probed
    bank: int                             # bank id at the service level
    mshr: bool                            # merged into an outstanding miss
    line: int


class CacheHierarchy:
    """L1 + optional L2 in front of main memory (inclusive, write-allocate)."""

    def __init__(self, levels: Tuple[CacheConfig, ...] = (L1_32K, L2_256K)):
        self.levels = [_Level(c) for c in levels]
        self.mem_reads = 0
        self.mem_writes = 0

    # -- probes ----------------------------------------------------------
    def access(self, addr: int, is_write: bool) -> AccessResult:
        line = addr // LINE
        service_level = "MEM"
        first_hit = False
        mshr_merged = False
        for i, lv in enumerate(self.levels):
            if lv.lookup(line):
                service_level = lv.cfg.name
                first_hit = i == 0
                break
            mshr_merged = lv.mshr_probe(line) or mshr_merged
        else:
            self.mem_reads += 1                       # line fill from DRAM

        # allocate the line in every level above the service point
        for lv in self.levels:
            if lv.cfg.name == service_level:
                break
            victim = lv.fill(line)
            if victim is not None:
                self._writeback(victim, below=lv.cfg.name)
        if is_write:
            self.levels[0].mark_dirty(line)

        bank_level = self.levels[0] if service_level == "L1" else (
            self.levels[1] if len(self.levels) > 1 and service_level == "L2"
            else self.levels[-1])
        return AccessResult(service_level, first_hit, bank_level.bank_of(addr),
                            mshr_merged, line)

    def _writeback(self, line: int, below: str) -> None:
        """Victim from `below` written into the next level (or DRAM)."""
        seen = False
        for lv in self.levels:
            if seen:
                victim = lv.fill(line, dirty=True)
                if victim is not None:
                    self._writeback(victim, below=lv.cfg.name)
                return
            seen = lv.cfg.name == below
        self.mem_writes += 1

    # -- residency query used by offload selection ------------------------
    def residency(self, addr: int) -> str:
        line = addr // LINE
        for lv in self.levels:
            if line in lv.sets[line % lv.cfg.n_sets]:
                return lv.cfg.name
        return "MEM"

    def bank_of(self, addr: int, level: str) -> int:
        for lv in self.levels:
            if lv.cfg.name == level:
                return lv.bank_of(addr)
        return 0

    # -- stats -------------------------------------------------------------
    def stats(self) -> Dict[str, Dict[str, int]]:
        out = {}
        for lv in self.levels:
            out[lv.cfg.name] = {"hits": lv.hits, "misses": lv.misses,
                                "writebacks": lv.writebacks,
                                "size": lv.cfg.size, "assoc": lv.cfg.assoc}
        out["MEM"] = {"reads": self.mem_reads, "writes": self.mem_writes}
        return out
