"""Columnar (struct-of-arrays) trace core.

The paper's probes stream ~10^4–10^6 committed instructions per workload;
holding each as a Python :class:`~repro.core.isa.Inst` makes every
downstream stage (IDG construction, candidate selection, energy pricing)
an object-at-a-time walk.  This module stores the committed instruction
queue as one numpy array per I-state field instead:

  ====================  ======================================== =========
  column                meaning (Table I field)                  dtype
  ====================  ======================================== =========
  ``op``                mnemonic code (``isa.OPS``)              int16
  ``unit``              triggered functional unit (``UNITS``)    int8
  ``dtype``             operand class, ``i``/``f``               int8
  ``dst``               destination register (−1 = none)         int32
  ``addr``              memory address (−1 = not a mem access)   int64
  ``size``              access bytes                             int16
  ``level``             serving cache level (``LEVELS``)         int8
  ``hit``               first-level hit (−1 unset / 0 / 1)       int8
  ``bank``              bank id at ``level`` (−1 unset)          int16
  ``mshr``              merged into an in-flight MSHR            bool
  ``src_off/tag/val``   CSR-encoded operand list per instruction
  ====================  ======================================== =========

``seq`` is implicit (the row index).  The structural columns (everything
except ``level``/``hit``/``bank``/``mshr``) depend only on the traced
program — never on the cache geometry — so one structural trace is shared
across every cache configuration of a sweep and only the four
memory-response columns are re-derived per geometry
(:meth:`ColumnarTrace.with_mem_results`, fed by
:meth:`repro.core.cache.CacheHierarchy.replay`).

:class:`ColumnarTrace` is also a ``Sequence[Inst]``: ``trace[seq]``
materializes a plain :class:`~repro.core.isa.Inst` row view on demand
(cached), so tree walks, reports, and hand-written analysis code keep
working unchanged while the hot paths (``core.idg``, ``core.offload``,
``core.profiler``) consume the columns directly.
"""
from __future__ import annotations

from collections.abc import Sequence
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.isa import (DTYPE_TAGS, IMM_BOOL, IMM_FLOAT, IMM_INT, LEVELS,
                            OPS, OP_LOAD, OP_STORE, SRC_IMM, SRC_REG, UNITS,
                            Inst)

_MEM_OPS = (OP_LOAD, OP_STORE)

# ColumnarBuilder bit-packs (op | unit<<5 | dtype<<9 | (dst+1)<<10 |
# size<<18) into one smallint per instruction — fail loudly at import time
# if a vocabulary ever outgrows its field instead of silently corrupting
# every decoded trace.
assert len(OPS) <= 32, "OPS outgrew the 5-bit op field: widen the packing"
assert len(UNITS) <= 16, "UNITS outgrew the 4-bit unit field"
#: largest register id the packed ``dst`` field (8 bits, +1 offset) holds
MAX_REG_ID = 254


def _imm_kind(v) -> int:
    if isinstance(v, bool) or isinstance(v, np.bool_):
        return IMM_BOOL
    if isinstance(v, (int, np.integer)):
        return IMM_INT
    return IMM_FLOAT


def decode_imm(val: float, kind: int):
    """float64 storage -> the Python scalar the emitter recorded."""
    if kind == IMM_INT:
        return int(val)
    if kind == IMM_BOOL:
        return bool(val)
    return float(val)


class ColumnarBuilder:
    """Append-only column accumulator the trace VM emits into.

    One ``add()`` call per committed instruction — a handful of
    plain-scalar list appends, no per-instruction object construction.
    The narrow fields are bit-packed into one Python smallint per
    instruction (and one per operand) at emission time and unpacked
    *vectorized* in ``finish()``:

      ``meta``  =  op | unit<<5 | dtype<<9 | (dst+1)<<10 | size<<18
      ``src``   =  tag | kind<<1   (plus the float64 value list)
    """

    __slots__ = ("n", "meta", "addr", "src_n", "src_meta", "src_val")

    def __init__(self):
        self.n = 0
        self.meta: List[int] = []
        self.addr: List[int] = []
        self.src_n: List[int] = []
        self.src_meta: List[int] = []
        self.src_val: List[float] = []

    def add(self, op: int, unit: int, dt: int, dst: int, addr: int,
            size: int, srcs: Tuple[Tuple[int, object], ...]) -> int:
        """Commit one instruction; returns its sequence index."""
        seq = self.n
        self.n = seq + 1
        self.meta.append(op | unit << 5 | dt << 9 | (dst + 1) << 10
                         | size << 18)
        self.addr.append(addr)
        self.src_n.append(len(srcs))
        meta_l, val_l = self.src_meta, self.src_val
        for tag, val in srcs:
            if tag == SRC_REG:
                meta_l.append(SRC_REG)
                val_l.append(val)
            else:
                t = type(val)
                kind = (IMM_INT if t is int else
                        IMM_FLOAT if t is float else _imm_kind(val))
                meta_l.append(SRC_IMM | kind << 1)
                val_l.append(float(val))
        return seq

    def finish(self, n_regs: int) -> "ColumnarTrace":
        src_off = np.zeros(self.n + 1, np.int64)
        np.cumsum(self.src_n, out=src_off[1:])
        n = self.n
        meta = np.asarray(self.meta, np.int64)
        src_meta = np.asarray(self.src_meta, np.uint8)
        return ColumnarTrace(
            n=n,
            op=(meta & 31).astype(np.int16),
            unit=((meta >> 5) & 15).astype(np.int8),
            dtype=((meta >> 9) & 1).astype(np.int8),
            dst=(((meta >> 10) & 255) - 1).astype(np.int32),
            addr=np.asarray(self.addr, np.int64),
            size=(meta >> 18).astype(np.int16),
            level=np.zeros(n, np.int8),
            hit=np.full(n, -1, np.int8),
            bank=np.full(n, -1, np.int16),
            mshr=np.zeros(n, bool),
            src_off=src_off,
            src_tag=(src_meta & 1),
            src_val=np.asarray(self.src_val, np.float64),
            src_kind=(src_meta >> 1).astype(np.int8),
            n_regs=n_regs,
        )


#: names of the persistable array columns, in a stable order (the on-disk
#: .npz encoding in repro.dse.store writes exactly these, prefixed "col_")
COLUMNS = ("op", "unit", "dtype", "dst", "addr", "size", "level", "hit",
           "bank", "mshr", "src_off", "src_tag", "src_val", "src_kind")
_STRUCTURAL = tuple(c for c in COLUMNS
                    if c not in ("level", "hit", "bank", "mshr"))


class ColumnarTrace(Sequence):
    """The committed instruction queue as struct-of-arrays (see module doc).

    Sequence protocol: ``len(trace)``, ``trace[seq]`` and iteration yield
    lazily materialized :class:`~repro.core.isa.Inst` row views, so the
    columnar trace is a drop-in replacement for the old ``List[Inst]``.

    ``_struct`` is a memo dictionary *shared between geometry variants* of
    one structural trace (``with_mem_results`` keeps the structural arrays
    and this dict by reference): derived structural artifacts — the
    vectorized RUT/IHT tables, producer indices, flow index, selection
    partitions — are computed once per traced program however many cache
    configurations a sweep prices.
    """

    __slots__ = ("n", "op", "unit", "dtype", "dst", "addr", "size", "level",
                 "hit", "bank", "mshr", "src_off", "src_tag", "src_val",
                 "src_kind", "n_regs", "_rows", "_lists", "_struct")

    def __init__(self, n, op, unit, dtype, dst, addr, size, level, hit,
                 bank, mshr, src_off, src_tag, src_val, src_kind,
                 n_regs: int, struct_cache: Optional[dict] = None):
        self.n = int(n)
        self.op = op
        self.unit = unit
        self.dtype = dtype
        self.dst = dst
        self.addr = addr
        self.size = size
        self.level = level
        self.hit = hit
        self.bank = bank
        self.mshr = mshr
        self.src_off = src_off
        self.src_tag = src_tag
        self.src_val = src_val
        self.src_kind = src_kind
        self.n_regs = int(n_regs)
        self._rows: Dict[int, Inst] = {}
        self._lists = None
        self._struct = struct_cache if struct_cache is not None else {}

    # ------------------------------------------------------- construction
    def with_mem_results(self, level: np.ndarray, hit: np.ndarray,
                         bank: np.ndarray, mshr: np.ndarray
                         ) -> "ColumnarTrace":
        """A geometry variant: same structural columns (by reference, and
        the same ``_struct`` memo), new memory-response columns."""
        return ColumnarTrace(
            self.n, self.op, self.unit, self.dtype, self.dst, self.addr,
            self.size, level, hit, bank, mshr, self.src_off, self.src_tag,
            self.src_val, self.src_kind, self.n_regs,
            struct_cache=self._struct)

    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Column dict for .npz persistence (repro.dse.store layer 1)."""
        out = {f"col_{name}": getattr(self, name) for name in COLUMNS}
        out["meta_n_regs"] = np.asarray([self.n_regs], np.int64)
        return out

    @classmethod
    def from_arrays(cls, arrays: Dict[str, np.ndarray]) -> "ColumnarTrace":
        cols = {name: arrays[f"col_{name}"] for name in COLUMNS}
        n = len(cols["op"])
        return cls(n=n, n_regs=int(arrays["meta_n_regs"][0]), **cols)

    # ------------------------------------------------------ sequence view
    def __len__(self) -> int:
        return self.n

    def _col_lists(self):
        """Python-list mirrors of the row-relevant columns (lazy, one-time):
        scalar list indexing is ~10x cheaper than numpy scalar indexing
        when materializing many row views."""
        if self._lists is None:
            self._lists = tuple(
                getattr(self, c).tolist()
                for c in ("op", "unit", "dtype", "dst", "addr", "size",
                          "level", "hit", "bank", "mshr", "src_off",
                          "src_tag", "src_val", "src_kind"))
        return self._lists

    def row(self, seq: int) -> Inst:
        """Materialize (and cache) the ``Inst`` view of one committed row."""
        inst = self._rows.get(seq)
        if inst is not None:
            return inst
        (op, unit, dt, dst, addr, size, level, hit, bank, mshr,
         src_off, src_tag, src_val, src_kind) = self._col_lists()
        lo, hi = src_off[seq], src_off[seq + 1]
        srcs = tuple(
            (SRC_REG, int(src_val[j])) if src_tag[j] == SRC_REG
            else (SRC_IMM, decode_imm(src_val[j], src_kind[j]))
            for j in range(lo, hi))
        d = dst[seq]
        a = addr[seq]
        inst = Inst(seq, OPS[op[seq]], UNITS[unit[seq]], DTYPE_TAGS[dt[seq]],
                    None if d < 0 else d, srcs,
                    addr=None if a < 0 else a, size=size[seq])
        lv = level[seq]
        inst.level = LEVELS[lv]
        h = hit[seq]
        inst.hit = None if h < 0 else bool(h)
        b = bank[seq]
        inst.bank = None if b < 0 else b
        inst.mshr = bool(mshr[seq])
        self._rows[seq] = inst
        return inst

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self.row(s) for s in range(*i.indices(self.n))]
        if i < 0:
            i += self.n
        if not 0 <= i < self.n:
            raise IndexError(i)
        return self.row(i)

    def __iter__(self) -> Iterator[Inst]:
        for seq in range(self.n):
            yield self.row(seq)

    # --------------------------------------------------- vectorized views
    @property
    def mem_mask(self) -> np.ndarray:
        m = self._struct.get("mem_mask")
        if m is None:
            m = self._struct["mem_mask"] = np.isin(self.op, _MEM_OPS)
        return m

    def mem_accesses(self) -> int:
        return int(self.mem_mask.sum())

    @property
    def nbytes(self) -> int:
        return sum(getattr(self, c).nbytes for c in COLUMNS)

    # ------------------------------------------- legacy dict-table views
    # The incremental RUT/IHT of the paper's probes (Fig. 6) are now
    # *derived* tables, reconstructed vectorized in core/idg.py; these
    # properties expose them in the exact dict shapes the object-based
    # pipeline (and hand-written tests) always used.
    @property
    def rut(self) -> Dict[int, List[int]]:
        tables = self._struct.get("rut_iht")
        if tables is None:
            from repro.core.idg import build_rut_iht
            tables = self._struct["rut_iht"] = build_rut_iht(self)
        return tables[0]

    @property
    def iht(self) -> Dict[int, List[Tuple[int, int]]]:
        tables = self._struct.get("rut_iht")
        if tables is None:
            from repro.core.idg import build_rut_iht
            tables = self._struct["rut_iht"] = build_rut_iht(self)
        return tables[1]

    def __repr__(self) -> str:
        return (f"<ColumnarTrace n={self.n} mem={self.mem_accesses()} "
                f"bytes={self.nbytes}>")
