"""Device/CiM-array model — the paper's Table III + Fig. 11, with a
DESTINY-like analytic scaling surrogate for other cache configurations.

The paper obtains per-operation energies from HSPICE device models fed
into a modified DESTINY.  Neither tool runs here, so we (i) embed the
published Table III numbers verbatim as calibration anchors, and
(ii) derive a two-parameter scaling law per (technology, operation):

    E(size, assoc) = E_L1 * (size / 64 KiB)^alpha * (assoc / 4)^beta

with ``beta`` fixed at 0.20 (associativity widens the way-select/compare
path sub-linearly) and ``alpha`` solved per operation so the law passes
*exactly* through both published points (64 KiB/4-way L1 and 256 KiB/8-way
L2).  This reproduces Table III by construction and extrapolates
monotonically for the Fig. 14 design-space sweep (32 KiB L1 … 2 MiB L2) —
including the paper's finding that larger arrays raise per-op CiM energy.

Latencies follow Fig. 11: SRAM CiM logic ops ≈ non-CiM read latency
(difference "almost negligible"), CiM ADD ≈ read + 4 cycles; FeFET CiM is
faster than SRAM CiM at every operation.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

from repro.core.cache import CacheConfig

KB = 1024

# ---------------------------------------------------------------- Table III
# energies in pJ per operation; anchors: (64kB, 4-way) and (256kB, 8-way)
_TABLE3: Dict[str, Dict[str, Tuple[float, float]]] = {
    # op             (L1 anchor, L2 anchor)
    "sram": {
        "read":     (61.0, 314.0),
        "CiM-OR":   (71.0, 341.0),
        "CiM-AND":  (72.0, 344.0),
        "CiM-XOR":  (79.0, 365.0),
        "CiM-ADD":  (79.0, 365.0),
    },
    "fefet": {
        "read":     (34.0, 70.0),
        "CiM-OR":   (35.0, 72.0),
        "CiM-AND":  (88.0, 146.0),
        "CiM-XOR":  (105.0, 205.0),
        "CiM-ADD":  (105.0, 205.0),
    },
}
_ANCHOR_L1 = (64 * KB, 4)
_ANCHOR_L2 = (256 * KB, 8)
_BETA = 0.20

# ------------------------------------------------------- Fig. 11 latencies
# access cycles at 1 GHz; {tech: {op: (L1 cycles, L2 cycles)}}
_LATENCY: Dict[str, Dict[str, Tuple[int, int]]] = {
    "sram": {
        "read":    (2, 8),
        "CiM-OR":  (2, 8),       # logic ops ~= read ("almost negligible")
        "CiM-AND": (2, 8),
        "CiM-XOR": (2, 8),
        "CiM-ADD": (6, 12),      # "almost four more cycles than non-CiM read"
    },
    "fefet": {
        "read":    (2, 6),
        "CiM-OR":  (2, 6),
        "CiM-AND": (2, 6),
        "CiM-XOR": (2, 6),
        "CiM-ADD": (4, 9),       # FeFET CiM outperforms SRAM CiM (Fig. 11/16)
    },
}

# write energy relative to read (array write + precharge; both techs'
# cache-level write path is read-comparable at 45 nm — documented surrogate)
WRITE_FACTOR = 1.15
# bit-serial in-memory multiply surrogate (CIM_SET_FULL only): priced as a
# small multiple of ADD — documented in DESIGN.md §Assumption-changes.
MUL_FACTOR = 4.0


@dataclasses.dataclass(frozen=True)
class TechModel:
    """Per-technology CiM array model with DESTINY-like scaling."""
    tech: str                           # "sram" | "fefet"

    def _alpha(self, op: str) -> float:
        e1, e2 = _TABLE3[self.tech][op]
        s1, a1 = _ANCHOR_L1
        s2, a2 = _ANCHOR_L2
        # solve e2 = e1 * (s2/s1)^alpha * (a2/a1)^beta
        return (math.log(e2 / e1) - _BETA * math.log(a2 / a1)) / math.log(s2 / s1)

    def energy(self, op: str, cache: CacheConfig) -> float:
        """pJ per operation for an arbitrary cache configuration."""
        if op == "write":
            return self.energy("read", cache) * WRITE_FACTOR
        if op == "CiM-MUL":
            return self.energy("CiM-ADD", cache) * MUL_FACTOR
        e1 = _TABLE3[self.tech][op][0]
        s1, a1 = _ANCHOR_L1
        return (e1 * (cache.size / s1) ** self._alpha(op)
                * (cache.assoc / a1) ** _BETA)

    def latency(self, op: str, level: str) -> int:
        """access cycles (1 GHz clock) at cache level 'L1'|'L2'."""
        if op == "write":
            op = "read"
        if op == "CiM-MUL":
            # analog-assisted in-array multiply surrogate (PRIME-class MVM
            # arrays do a multiply per access): ADD latency + 2 cycles.
            base = _LATENCY[self.tech]["CiM-ADD"]
            return (base[0] if level == "L1" else base[1]) + 2
        row = _LATENCY[self.tech].get(op, _LATENCY[self.tech]["read"])
        return row[0] if level == "L1" else row[1]

    # convenience: reproduce Table III verbatim (used by the validation bench)
    def table3_row(self, cache: CacheConfig) -> Dict[str, float]:
        return {op: round(self.energy(op, cache), 1)
                for op in ("read", "CiM-OR", "CiM-AND", "CiM-XOR", "CiM-ADD")}


SRAM = TechModel("sram")
FEFET = TechModel("fefet")
TECHS = {"sram": SRAM, "fefet": FEFET}

# ------------------------------------------------------------------ DRAM
DRAM_ACCESS_PJ = 15_000.0      # pJ per 64 B line activation+transfer (LPDDR-class)
DRAM_LATENCY_CYCLES = 60       # @1 GHz host clock
