"""HLO/jaxpr analysis — Eva-CiM's IDG offload analysis adapted to XLA.

Two analyses (DESIGN.md §3):

1. ``collective_bytes(hlo_text)`` — per-device bytes moved by each
   collective kind, parsed from post-SPMD HLO (the §Roofline collective
   term; ``cost_analysis`` does not expose it).

2. ``fusion_candidates(jaxpr)`` — the paper's offload-candidate selection
   re-targeted at the TPU memory wall: nodes are jaxpr equations, an
   "offloading candidate" is a chain of elementwise/reduction ops whose
   intermediate tensors can stay in VMEM (one HBM round-trip instead of
   many) — i.e., what a fused Pallas kernel (kernels/) realizes.  The
   TPU-MACR is the fraction of HBM traffic eliminable by such fusion.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

# ======================================================================
# 1. collective parsing (post-SPMD HLO text)
# ======================================================================
_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:\([^)]*\))|(?:\S+))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b")
_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1}


def shape_bytes(type_str: str) -> int:
    """Total bytes of every typed shape in an HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        b = _DTYPE_BYTES.get(dt, 1 if dt.startswith("f8") else 4)
        total += n * b
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes by collective kind, from result shapes."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        nbytes = shape_bytes(m.group(2))
        out[kind] = out.get(kind, 0) + nbytes
        out[kind + "_count"] = out.get(kind + "_count", 0) + 1
    out["total"] = sum(v for k, v in out.items() if not k.endswith("_count"))
    return out


def scan_trip_counts(hlo_text: str) -> List[int]:
    return [int(x) for x in re.findall(r"trip_count=(\d+)", hlo_text)]


# ======================================================================
# 2. jaxpr fusion-candidate analysis (the TPU IDG)
# ======================================================================
# op classes for the dataflow walk
_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "and", "or", "xor", "not",
    "neg", "abs", "exp", "log", "tanh", "logistic", "sqrt", "rsqrt",
    "select_n", "clamp", "lt", "le", "gt", "ge", "eq", "ne", "sign",
    "floor", "ceil", "round", "convert_element_type", "integer_pow",
    "erf", "sin", "cos", "pow", "square", "cbrt", "is_finite", "rem",
}
_REDUCTION = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
              "argmax", "argmin", "reduce_and", "reduce_or", "cumsum",
              "cummax", "cummin", "cumlogsumexp"}
_MATMUL = {"dot_general", "conv_general_dilated"}
_VIEW = {"reshape", "squeeze", "expand_dims", "broadcast_in_dim",
         "transpose", "slice", "rev", "stop_gradient", "copy", "bitcast",
         "convert_element_type"}


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # noqa: BLE001 — abstract tokens etc.
        return 0


@dataclasses.dataclass
class FusionCandidate:
    """A chain of elementwise/reduction eqns whose intermediates stay in
    VMEM when fused — the TPU analogue of one CiM offloading candidate."""
    eqn_ids: List[int]
    ops: List[str]
    input_bytes: int                   # HBM reads the fused kernel still does
    output_bytes: int                  # HBM writes it still does
    saved_bytes: int                   # intermediate HBM round-trips removed

    @property
    def n_ops(self) -> int:
        return len(self.eqn_ids)


@dataclasses.dataclass
class FusionReport:
    candidates: List[FusionCandidate]
    total_bytes: int                   # all tensor traffic if nothing fuses
    saved_bytes: int

    @property
    def tpu_macr(self) -> float:
        """Fraction of HBM traffic eliminable by VMEM-resident fusion —
        the TPU-mode MACR (DESIGN.md §3)."""
        return self.saved_bytes / self.total_bytes if self.total_bytes else 0.0


def fusion_candidates(jaxpr, min_bytes: int = 1 << 12) -> FusionReport:
    """Walk a (closed) jaxpr's dataflow and greedily group connected
    elementwise(+terminal reduction) eqns, exactly like Algorithm 1 walks
    the IDG: chains rooted at a fusable op, leaves = HBM-resident tensors.

    ``min_bytes``: tensors smaller than this are considered register/SMEM
    resident (scalars, small params) and are not counted as traffic.
    """
    jx = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    eqns = list(jx.eqns)
    # def/use maps over vars
    producer: Dict[Any, int] = {}
    consumers: Dict[Any, List[int]] = {}
    def is_var(v) -> bool:
        return type(v).__name__ not in ("Literal",)

    for i, eqn in enumerate(eqns):
        for v in eqn.outvars:
            if is_var(v):
                producer[v] = i
        for v in eqn.invars:
            if is_var(v) and hasattr(v, "aval"):
                consumers.setdefault(v, []).append(i)

    def klass(eqn) -> str:
        n = eqn.primitive.name
        if n in _MATMUL:
            return "matmul"
        if n in _REDUCTION:
            return "reduction"
        if n in _VIEW:
            return "view"
        if n in _ELEMENTWISE:
            return "elementwise"
        return "other"

    total_bytes = 0
    for eqn in eqns:
        if klass(eqn) == "view":
            continue
        for v in list(eqn.invars) + list(eqn.outvars):
            if hasattr(v, "aval"):
                b = _aval_bytes(v.aval)
                total_bytes += b if b >= min_bytes else 0

    claimed: Set[int] = set()
    cands: List[FusionCandidate] = []
    # reverse walk: consumers first => maximal chains (same as offload.py)
    for i in range(len(eqns) - 1, -1, -1):
        if i in claimed or klass(eqns[i]) not in ("elementwise", "reduction"):
            continue
        group = []
        stack = [i]
        while stack:
            j = stack.pop()
            if j in claimed:
                continue
            kj = klass(eqns[j])
            if kj not in ("elementwise", "reduction", "view"):
                continue
            claimed.add(j)
            group.append(j)
            for v in eqns[j].invars:
                if not is_var(v):
                    continue
                p = producer.get(v)
                if p is None or p in claimed:
                    continue
                # only fuse through single-consumer intermediates (XLA's
                # duplication heuristic aside — conservative)
                if len(consumers.get(v, ())) == 1 and \
                        klass(eqns[p]) in ("elementwise", "view"):
                    stack.append(p)
        real = [j for j in group if klass(eqns[j]) != "view"]
        if len(real) < 2:
            for j in group:
                claimed.discard(j)
            continue
        gset = set(group)
        in_b = out_b = saved = 0
        for j in group:
            for v in eqns[j].invars:
                if not is_var(v) or not hasattr(v, "aval"):
                    continue
                b = _aval_bytes(v.aval)
                if b < min_bytes:
                    continue
                p = producer.get(v)
                if p in gset:
                    saved += 2 * b              # intermediate: store+load gone
                else:
                    in_b += b
            for v in eqns[j].outvars:
                if not is_var(v):
                    continue
                b = _aval_bytes(v.aval)
                if b < min_bytes:
                    continue
                outside = [c for c in consumers.get(v, ()) if c not in gset]
                if outside or producer.get(v) == group[-1]:
                    out_b += b
        cands.append(FusionCandidate(sorted(group),
                                     [eqns[j].primitive.name for j in sorted(group)],
                                     in_b, out_b, saved))
    saved_total = sum(c.saved_bytes for c in cands)
    return FusionReport(cands, total_bytes, saved_total)
