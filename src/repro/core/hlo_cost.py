"""Trip-count-aware static cost analysis over post-SPMD HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE — under
``lax.scan``-over-layers (our models) that undercounts FLOPs/bytes by the
layer count and hides per-layer collectives.  This analyzer parses the HLO
module, builds the computation call graph (while bodies x their
``known_trip_count``, fusions, conditionals), and accumulates:

  * ``flops``            — 2 * |out| * K for every dot (contracting size K
                           resolved from the lhs operand's recorded shape);
  * ``bytes``            — operand + result footprints of top-level ops in
                           executable regions (fusion-internal temporaries
                           excluded: they live in registers/VMEM);
  * ``collective_bytes`` — per-kind result bytes of all-reduce/all-gather/
                           reduce-scatter/all-to-all/collective-permute.

Everything is multiplied along the call chain by loop trip counts, so a
48-layer scanned transformer reports 48x its body, not 1x.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.core.hlo import _COLL_RE, shape_bytes

_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_PARAM_RE = re.compile(
    r"([\w.\-]+)\s*:\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))")
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|\S+)\s+([\w\-]+)\(")
_DOT_ARGS_RE = re.compile(r"\bdot\(([^)]*)\)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_B_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_TRIP_RE = re.compile(r'known_trip_count["\s:{]+n["\s:]+\"?(\d+)')
_TRIP_RE2 = re.compile(r"trip_count=(\d+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_SHAPE_DIMS_RE = re.compile(r"[a-z0-9]+\[([\d,]*)\]")
_REF_RE = re.compile(r"%([\w.\-]+)")


def _dims(type_str: str) -> List[int]:
    m = _SHAPE_DIMS_RE.search(type_str)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


@dataclasses.dataclass
class _Comp:
    name: str
    shapes: Dict[str, str]                       # instr/param name -> type str
    local_flops: float = 0.0
    local_bytes: float = 0.0
    local_coll: Dict[str, float] = dataclasses.field(default_factory=dict)
    # (child computation name, multiplier)
    children: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    is_fusion_like: bool = False                 # bytes counted by caller
    dots: List[Tuple[float, str]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    collectives: Dict[str, float]
    dot_profile: List[Tuple[float, str]] = dataclasses.field(default_factory=list)

    @property
    def collective_total(self) -> float:
        return sum(v for k, v in self.collectives.items()
                   if not k.endswith("_count"))

    def top_dots(self, n: int = 12) -> List[Tuple[float, str]]:
        """The dominant matmuls (effective FLOPs = per-execution x trips)."""
        return sorted(self.dot_profile, reverse=True)[:n]


def _parse_computations(text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        if cur is None:
            m = _HDR_RE.match(raw)
            if m and raw.rstrip().endswith("{"):
                cur = _Comp(m.group(1), {})
                if raw.lstrip().startswith("ENTRY"):
                    entry = cur.name
                for pname, ptype in _PARAM_RE.findall(m.group(2)):
                    cur.shapes[pname] = ptype
            continue
        if raw.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        mi = _INST_RE.match(raw)
        if not mi:
            continue
        iname, itype, opcode = mi.groups()
        cur.shapes[iname] = itype

        if opcode == "dot":
            out_elems = 1
            for d in _dims(itype):
                out_elems *= d
            k = 1
            margs = _DOT_ARGS_RE.search(raw)
            mc = _LHS_C_RE.search(raw)
            if margs and mc and mc.group(1):
                refs = _REF_RE.findall(margs.group(1))
                if refs:
                    lhs_shape = _dims(cur.shapes.get(refs[0], ""))
                    for ci in mc.group(1).split(","):
                        ci = int(ci)
                        if ci < len(lhs_shape):
                            k *= lhs_shape[ci]
            cur.local_flops += 2.0 * out_elems * k
            meta = raw.split("metadata=")
            tag = meta[1][:120] if len(meta) > 1 else raw.strip()[:120]
            cur.dots.append((2.0 * out_elems * k, f"{itype} {tag}"))

        mcoll = _COLL_RE.search(raw)
        if mcoll:
            kind = mcoll.group(3)
            nb = shape_bytes(mcoll.group(2))
            cur.local_coll[kind] = cur.local_coll.get(kind, 0.0) + nb
            cur.local_coll[kind + "_count"] = \
                cur.local_coll.get(kind + "_count", 0.0) + 1

        # ---- call edges -------------------------------------------------
        if opcode == "while":
            trip = 1.0
            mt = _TRIP_RE.search(raw) or _TRIP_RE2.search(raw)
            if mt:
                trip = float(mt.group(1))
            mb = _BODY_RE.search(raw)
            if mb:
                cur.children.append((mb.group(1), trip))
            mc2 = _COND_RE.search(raw)
            if mc2:
                cur.children.append((mc2.group(1), trip + 1))
        elif opcode == "fusion":
            mf = _CALLS_RE.search(raw)
            if mf:
                cur.children.append((mf.group(1), 1.0))
        elif opcode == "conditional":
            mb2 = _BRANCHES_RE.search(raw)
            if mb2:
                for ref in _REF_RE.findall(mb2.group(1)):
                    cur.children.append((ref, 1.0))
        elif opcode in ("call", "custom-call", "async-start"):
            mf = _APPLY_RE.search(raw) or _CALLS_RE.search(raw)
            if mf:
                cur.children.append((mf.group(1), 1.0))
        elif opcode in ("reduce", "sort", "map", "scatter", "select-and-scatter",
                        "reduce-window", "all-reduce", "reduce-scatter"):
            pass                                   # to_apply bodies negligible

        # ---- byte footprint (top-level ops only; operands + result) ------
        if opcode not in ("parameter", "constant", "tuple", "get-tuple-element",
                          "bitcast", "while", "conditional"):
            b = shape_bytes(itype)
            for ref in _REF_RE.findall(raw.split("metadata")[0])[1:6]:
                if ref in cur.shapes:
                    b += shape_bytes(cur.shapes[ref])
            cur.local_bytes += b
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def analyze_hlo(text: str, details: bool = False) -> HloCost:
    comps, entry = _parse_computations(text)
    memo: Dict[str, Tuple] = {}

    def total(name: str, stack=()) -> Tuple:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return (0.0, 0.0, {}, [])
        c = comps[name]
        f, b = c.local_flops, c.local_bytes
        coll = dict(c.local_coll)
        dots = list(c.dots) if details else []
        for child, mult in c.children:
            cf, cb, cc, cd = total(child, stack + (name,))
            f += mult * cf
            # fusion-internal temporaries excluded from bytes
            if not child.startswith(("wrapped_", "fused_")):
                b += mult * cb
            for k, v in cc.items():
                coll[k] = coll.get(k, 0.0) + mult * v
            if details:
                dots.extend((mult * df, dl) for df, dl in cd)
        memo[name] = (f, b, coll, dots)
        return memo[name]

    roots = [entry] if entry else list(comps)
    f, b, coll, dots = total(roots[0]) if roots else (0.0, 0.0, {}, [])
    return HloCost(flops=f, bytes=b, collectives=coll, dot_profile=dots)
