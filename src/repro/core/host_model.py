"""Host-CPU energy/latency model — the McPAT analogue (paper §V-C).

McPAT prices each committed instruction from per-component performance
counters; our trace VM produces exactly those counters (instruction class,
triggered functional unit, cache level per access).  The default constants
model an ARM Cortex-A9-class out-of-order core at 45 nm / 1 GHz — the
paper's experimental platform (§VI).  They are calibration surrogates for
McPAT output, sized so that core power at IPC ~1 lands in the A9's
published 0.5–1 W envelope; the validation benchmark (Table V) checks the
resulting CiM/non-CiM energy *ratios* against the paper.

:data:`HOST_PRESETS` names the host-CPU design points the DSE sweeps
(``SweepSpace(hosts=...)``): the paper varies the host to quantify how much
of CiM's benefit depends on what it is attached to — a small in-order core
leaves more of the memory wall for CiM to remove, while a wide/fast OoO
core hides miss latency itself (and pays for it in pipeline energy).
Frequency variants keep the micro-architecture but re-express the fixed
DRAM/L2 nanosecond latencies in (more) core cycles and dilute per-cycle
static energy, which shifts both the speedup and the static-energy term.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping

from repro.core.isa import (U_BRANCH, U_FP_ALU, U_FP_DIV, U_FP_MUL,
                            U_FP_SPECIAL, U_INT_ALU, U_INT_DIV, U_INT_MUL,
                            U_MEM_RD, U_MEM_WR, U_SIMD, Inst)


class FrozenUnitMap(dict):
    """Immutable, hashable unit->pJ mapping.

    :class:`HostModel` is a frozen dataclass, but a plain ``dict`` field
    defeats its generated ``__hash__`` — and sweep-point dedup (adaptive
    refinement, set membership of :class:`~repro.dse.space.SweepPoint`)
    needs host-carrying points to hash.  This keeps the full read-side dict
    API (``.get``, iteration, ``==`` against plain dicts, and therefore the
    ``HOST_PRESETS`` equality lookup in ``HostOption.of``) while rejecting
    mutation and hashing by value.
    """

    def _frozen(self, *args, **kwargs):
        raise TypeError("HostModel.unit_pj is immutable; build a new "
                        "HostModel to change unit energies")

    __setitem__ = __delitem__ = __ior__ = _frozen
    clear = pop = popitem = setdefault = update = _frozen

    def __hash__(self):
        return hash(frozenset(self.items()))

    def __reduce__(self):
        # default dict-subclass pickling repopulates via the (blocked)
        # __setitem__; rebuild through the C-level dict constructor instead
        return (self.__class__, (dict(self),))


@dataclasses.dataclass(frozen=True)
class HostModel:
    # --- energy (pJ) ------------------------------------------------------
    # front-end + rename + IQ/ROB + regfile + bypass + commit, per instruction
    pipeline_pj: float = 180.0
    # static + clock-tree power burned per cycle regardless of activity
    # (~30% of A9 package power at 45 nm) — McPAT's P_static * T term, which
    # couples runtime reduction into the energy improvement
    static_pj_per_cycle: float = 150.0
    unit_pj: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: FrozenUnitMap({
            U_INT_ALU: 15.0, U_INT_MUL: 40.0, U_INT_DIV: 90.0,
            U_FP_ALU: 40.0, U_FP_MUL: 60.0, U_FP_DIV: 140.0,
            U_FP_SPECIAL: 160.0,
            U_MEM_RD: 20.0, U_MEM_WR: 20.0,    # LSQ/AGU (cache array priced
            U_BRANCH: 12.0, U_SIMD: 30.0,      #  separately via Table III)
        }))
    # --- timing (cycles @ 1 GHz) -------------------------------------------
    # A9 is dual-issue OoO: sustained ~1.5 instructions/cycle on these
    # kernels => effective CPI ~0.65 for pipelined instructions.
    base_cpi: float = 0.65
    # additional stall beyond the pipelined L1 path, scaled by an OoO
    # overlap factor (the window hides part of the miss latency)
    l2_stall: float = 8.0
    mem_stall: float = 60.0
    overlap: float = 0.4
    # CiM array-op timing: each array op in a macro-instruction occupies the
    # bank for ~1 pipelined slot; latency beyond an L1 read is partly hidden
    # by the OoO window (§V-C2: CiM ADD's +4 cycles "may result in severe
    # pipeline stall" — cim_overlap is the unhidden fraction)
    cim_occupancy: float = 0.35
    cim_overlap: float = 0.2
    # --- identity -----------------------------------------------------------
    # preset name (sweep axis label) + clock, appended last so positional
    # construction of the pricing constants above stays source-compatible
    name: str = "A9-1GHz"
    freq_ghz: float = 1.0

    def __post_init__(self):
        # accept plain dicts at construction but store the frozen mapping,
        # so every HostModel (and anything carrying one) is hashable
        if not isinstance(self.unit_pj, FrozenUnitMap):
            object.__setattr__(self, "unit_pj", FrozenUnitMap(self.unit_pj))

    def inst_energy_pj(self, inst: Inst) -> float:
        return self.pipeline_pj + self.unit_pj.get(inst.unit, 15.0)

    def inst_cycles(self, inst: Inst) -> float:
        c = self.base_cpi
        if inst.is_mem:
            if inst.level == "L2":
                c += self.l2_stall * self.overlap
            elif inst.level == "MEM":
                c += self.mem_stall * self.overlap
        return c

    def runtime_ms(self, cycles: float) -> float:
        return cycles / (self.freq_ghz * 1e9) * 1e3


DEFAULT_HOST = HostModel()

# ---------------------------------------------------------------------------
# Named host design points for the DSE host axis (SweepSpace(hosts=...)).
# All pricing constants are surrogates in the same calibration family as the
# A9 baseline; what matters for the sweep is the *relative* movement of the
# pipeline-energy / static-energy / stall-hiding trade-off across presets.
# ---------------------------------------------------------------------------
HOST_PRESETS: Dict[str, HostModel] = {
    # the paper's §VI platform: dual-issue OoO A9 @ 1 GHz (== DEFAULT_HOST)
    "A9-1GHz": DEFAULT_HOST,
    # Cortex-A7-class in-order single-issue core: no rename/ROB (cheap
    # pipeline, low leakage) but almost no miss-latency hiding, so stalls —
    # and the CiM op latency beyond an L1 read — land nearly in full
    "inorder-1GHz": HostModel(
        pipeline_pj=80.0, static_pj_per_cycle=60.0,
        base_cpi=1.15, l2_stall=8.0, mem_stall=60.0, overlap=0.9,
        cim_occupancy=0.5, cim_overlap=0.65,
        name="inorder-1GHz", freq_ghz=1.0),
    # the same A9 micro-architecture clocked at 2 GHz: fixed-ns L2/DRAM
    # latencies double in cycles (the memory wall bites harder) while the
    # fixed leakage *power* spreads over twice as many cycles per second
    "A9-2GHz": HostModel(
        static_pj_per_cycle=75.0,
        l2_stall=16.0, mem_stall=120.0,
        name="A9-2GHz", freq_ghz=2.0),
    # A15/"big"-class 3-wide OoO @ 2 GHz: a deep window hides most of the
    # miss (and CiM) latency itself, at a steep pipeline + leakage premium —
    # the host that gives CiM the least performance headroom
    "big-OoO-2GHz": HostModel(
        pipeline_pj=300.0, static_pj_per_cycle=260.0,
        base_cpi=0.4, l2_stall=16.0, mem_stall=120.0, overlap=0.2,
        cim_occupancy=0.3, cim_overlap=0.08,
        name="big-OoO-2GHz", freq_ghz=2.0),
}
