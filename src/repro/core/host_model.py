"""Host-CPU energy/latency model — the McPAT analogue (paper §V-C).

McPAT prices each committed instruction from per-component performance
counters; our trace VM produces exactly those counters (instruction class,
triggered functional unit, cache level per access).  The constants below
model an ARM Cortex-A9-class out-of-order core at 45 nm / 1 GHz — the
paper's experimental platform (§VI).  They are calibration surrogates for
McPAT output, sized so that core power at IPC ~1 lands in the A9's
published 0.5–1 W envelope; the validation benchmark (Table V) checks the
resulting CiM/non-CiM energy *ratios* against the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.isa import (U_BRANCH, U_FP_ALU, U_FP_DIV, U_FP_MUL,
                            U_FP_SPECIAL, U_INT_ALU, U_INT_DIV, U_INT_MUL,
                            U_MEM_RD, U_MEM_WR, U_SIMD, Inst)


@dataclasses.dataclass(frozen=True)
class HostModel:
    # --- energy (pJ) ------------------------------------------------------
    # front-end + rename + IQ/ROB + regfile + bypass + commit, per instruction
    pipeline_pj: float = 180.0
    # static + clock-tree power burned per cycle regardless of activity
    # (~30% of A9 package power at 45 nm) — McPAT's P_static * T term, which
    # couples runtime reduction into the energy improvement
    static_pj_per_cycle: float = 150.0
    unit_pj: Dict[str, float] = dataclasses.field(default_factory=lambda: {
        U_INT_ALU: 15.0, U_INT_MUL: 40.0, U_INT_DIV: 90.0,
        U_FP_ALU: 40.0, U_FP_MUL: 60.0, U_FP_DIV: 140.0, U_FP_SPECIAL: 160.0,
        U_MEM_RD: 20.0, U_MEM_WR: 20.0,        # LSQ/AGU (cache array priced
        U_BRANCH: 12.0, U_SIMD: 30.0,          #  separately via Table III)
    })
    # --- timing (cycles @ 1 GHz) -------------------------------------------
    # A9 is dual-issue OoO: sustained ~1.5 instructions/cycle on these
    # kernels => effective CPI ~0.65 for pipelined instructions.
    base_cpi: float = 0.65
    # additional stall beyond the pipelined L1 path, scaled by an OoO
    # overlap factor (the window hides part of the miss latency)
    l2_stall: float = 8.0
    mem_stall: float = 60.0
    overlap: float = 0.4
    # CiM array-op timing: each array op in a macro-instruction occupies the
    # bank for ~1 pipelined slot; latency beyond an L1 read is partly hidden
    # by the OoO window (§V-C2: CiM ADD's +4 cycles "may result in severe
    # pipeline stall" — cim_overlap is the unhidden fraction)
    cim_occupancy: float = 0.35
    cim_overlap: float = 0.2

    def inst_energy_pj(self, inst: Inst) -> float:
        return self.pipeline_pj + self.unit_pj.get(inst.unit, 15.0)

    def inst_cycles(self, inst: Inst) -> float:
        c = self.base_cpi
        if inst.is_mem:
            if inst.level == "L2":
                c += self.l2_stall * self.overlap
            elif inst.level == "MEM":
                c += self.mem_stall * self.overlap
        return c


DEFAULT_HOST = HostModel()
