"""Instruction Dependency Graph — the paper's Algorithm 2 (Fig. 6).

An IDG is a forest of *flipped trees*: the root of each tree is a
CiM-supported OP instruction, edges point from an instruction to the
instructions that produced its source operands, and leaves are loads or
immediates.  Construction is O(N) because producers are found with two
tables that the trace VM maintains while committing instructions:

  RUT (register usage table)   reg -> [seq of instructions that wrote reg]
  IHT (index hash table)       seq -> [(src reg, RUT position at commit)]

``producer_of`` resolves one IHT entry to the defining instruction — the
paper's "lookup RUT with [j]" (Algorithm 2 lines 11-12).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Iterator, List, Optional, Tuple

from repro.core.isa import SRC_IMM, SRC_REG, Inst, Trace

# leaf kinds
LEAF_LOAD = "load"            # Algorithm 2's LEAF_TRUE
LEAF_IMM = "imm"              # Fig. 4(b) variant
LEAF_MEMVAL = "memval"        # value produced by a non-CiM op, resident in
                              # memory via its store (Fig. 4(c) boundary)


@dataclasses.dataclass
class IDGNode:
    """One node of an IDG tree.  ``children`` holds (kind, payload) where
    payload is an Inst for load leaves / op nodes, or the immediate value."""
    inst: Inst
    children: List[Tuple[str, object]] = dataclasses.field(default_factory=list)

    @property
    def left(self):          # the paper's binary view (Algorithm 2)
        return self.children[0] if self.children else None

    @property
    def right(self):
        return self.children[1] if len(self.children) > 1 else None

    def iter_nodes(self) -> Iterator["IDGNode"]:
        yield self
        for kind, payload in self.children:
            if kind == "node":
                yield from payload.iter_nodes()

    def load_leaves(self) -> List[Inst]:
        out = []
        for kind, payload in self.children:
            if kind == LEAF_LOAD:
                out.append(payload)
            elif kind == "node":
                out.extend(payload.load_leaves())
        return out

    def size_ops(self) -> int:
        return sum(1 for _ in self.iter_nodes())


class IDGBuilder:
    """Resolves producers over (trace, RUT, IHT) and builds trees."""

    def __init__(self, trace: Trace, rut: Dict[int, List[int]],
                 iht: Dict[int, List[Tuple[int, int]]]):
        self.trace = trace
        self.rut = rut
        self.iht = iht

    # ------------------------------------------------------------ lookups
    def producer_of(self, seq: int, src_slot: int) -> Optional[Inst]:
        """Defining instruction of the ``src_slot``-th *register* source."""
        entries = self.iht.get(seq, ())
        if src_slot >= len(entries):
            return None
        reg, pos = entries[src_slot]
        writes = self.rut.get(reg, ())
        if 0 <= pos < len(writes):
            return self.trace[writes[pos]]
        return None

    def producers(self, inst: Inst) -> List[Tuple[str, object]]:
        """All source operands of ``inst`` resolved to (kind, payload).

        kind: "imm" for immediates, "inst" for register operands (payload =
        producing Inst), "unknown" when the register has no recorded writer
        (pre-existing machine state).
        """
        out: List[Tuple[str, object]] = []
        reg_slot = 0
        for tag, val in inst.srcs:
            if tag == SRC_IMM:
                out.append(("imm", val))
            else:
                p = self.producer_of(inst.seq, reg_slot)
                reg_slot += 1
                out.append(("inst", p) if p is not None else ("unknown", val))
        return out

    # ------------------------------------------------------- tree building
    def create_tree(self, root: Inst, cim_set: FrozenSet[str],
                    claimed: Optional[set] = None,
                    max_ops: int = 64) -> Optional[IDGNode]:
        """Algorithm 2's create_tree: recursive producer expansion.

        Recurses through CiM-supported producers (composite patterns),
        terminates at load leaves / immediates, and cuts at non-CiM
        producers (their value is memory-resident via its store ->
        LEAF_MEMVAL).  ``claimed`` marks instructions already owned by an
        accepted candidate — the partition step's bookkeeping.
        """
        if root.op not in cim_set:
            return None
        budget = [max_ops]

        def build(inst: Inst) -> Optional[IDGNode]:
            if budget[0] <= 0:
                return None
            budget[0] -= 1
            node = IDGNode(inst)
            for kind, payload in self.producers(inst):
                if kind == "imm":
                    node.children.append((LEAF_IMM, payload))
                elif kind == "unknown":
                    node.children.append((LEAF_IMM, payload))
                else:
                    p: Inst = payload
                    if p.is_load:
                        node.children.append((LEAF_LOAD, p))
                    elif p.op == "mov" and all(t == SRC_IMM for t, _ in p.srcs):
                        # accumulator init (mov #imm): an immediate leaf
                        node.children.append((LEAF_IMM, p.srcs[0][1]))
                    elif (p.op in cim_set
                          and (claimed is None or p.seq not in claimed)):
                        sub = build(p)
                        if sub is None:
                            node.children.append((LEAF_MEMVAL, p))
                        else:
                            node.children.append(("node", sub))
                    else:
                        node.children.append((LEAF_MEMVAL, p))
            return node

        return build(root)

    def build_forest(self, cim_set: FrozenSet[str],
                     max_ops: int = 64) -> List[IDGNode]:
        """Algorithm 2's outer loop: one tree per CiM-supported instruction.

        (Offload selection uses a claimed-set variant instead so composite
        candidates are extracted exactly once — see core/offload.py.)
        """
        forest = []
        for inst in self.trace:
            if inst.op in cim_set:
                tree = self.create_tree(inst, cim_set, max_ops=max_ops)
                if tree is not None:
                    forest.append(tree)
        return forest


# ======================================================================
# Auxiliary producer/consumer indices used by selection + reshaping
# ======================================================================
@dataclasses.dataclass
class FlowIndex:
    """Derived O(N) maps over a trace (built once, reused by the analysis)."""
    reg_consumers: Dict[int, List[int]]     # producer seq -> consumer seqs
    store_of: Dict[int, List[int]]          # op seq -> seqs of stores of its value
    load_source: Dict[int, Optional[int]]   # load seq -> producing op seq (via mem)
    value_loads: Dict[int, List[int]]       # producing op seq -> later load seqs


def build_flow_index(trace: Trace, rut, iht) -> FlowIndex:
    b = IDGBuilder(trace, rut, iht)
    reg_consumers: Dict[int, List[int]] = {}
    store_of: Dict[int, List[int]] = {}
    load_source: Dict[int, Optional[int]] = {}
    value_loads: Dict[int, List[int]] = {}
    last_writer_of_addr: Dict[int, int] = {}      # addr -> producing op seq

    for inst in trace:
        for kind, payload in b.producers(inst):
            if kind == "inst":
                p: Inst = payload
                reg_consumers.setdefault(p.seq, []).append(inst.seq)
                if inst.is_store:
                    store_of.setdefault(p.seq, []).append(inst.seq)
        if inst.is_store:
            prods = [p.seq for k, p in b.producers(inst) if k == "inst"]
            if prods:
                last_writer_of_addr[inst.addr] = prods[0]
        elif inst.is_load:
            src = last_writer_of_addr.get(inst.addr)
            load_source[inst.seq] = src
            if src is not None:
                value_loads.setdefault(src, []).append(inst.seq)
    return FlowIndex(reg_consumers, store_of, load_source, value_loads)
