"""Instruction Dependency Graph — the paper's Algorithm 2 (Fig. 6).

An IDG is a forest of *flipped trees*: the root of each tree is a
CiM-supported OP instruction, edges point from an instruction to the
instructions that produced its source operands, and leaves are loads or
immediates.  Construction is O(N) because producers are found with two
tables derived from the committed stream:

  RUT (register usage table)   reg -> [seq of instructions that wrote reg]
  IHT (index hash table)       seq -> [(src reg, RUT position at commit)]

The paper's probes build RUT/IHT incrementally at commit time; over a
columnar trace (:class:`repro.core.columnar.ColumnarTrace`) both tables —
and the producer of every register operand — are reconstructed *vectorized*
from the ``dst`` and source-operand columns (:func:`build_rut_iht`,
:func:`build_flow_index`): a write at sequence ``w`` produces the operand
read at ``s`` iff it is the latest write to that register before ``s``,
which is one ``searchsorted`` per register over the sorted write lists.
The :class:`IDGBuilder` then resolves producers with O(1) array lookups;
:class:`Inst` rows are materialized lazily only for the nodes an actual
tree walk touches.  Hand-built ``List[Inst]`` traces (tests, exploration)
keep the original dict-table path — both paths produce identical forests
(property-tested in ``tests/test_columnar.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.columnar import ColumnarTrace, decode_imm
from repro.core.isa import (OP_CODE, OP_LOAD, OP_STORE, SRC_IMM, SRC_REG,
                            Inst, Trace)

# leaf kinds
LEAF_LOAD = "load"            # Algorithm 2's LEAF_TRUE
LEAF_IMM = "imm"              # Fig. 4(b) variant
LEAF_MEMVAL = "memval"        # value produced by a non-CiM op, resident in
                              # memory via its store (Fig. 4(c) boundary)


@dataclasses.dataclass
class IDGNode:
    """One node of an IDG tree.  ``children`` holds (kind, payload) where
    payload is an Inst for load leaves / op nodes, or the immediate value."""
    inst: Inst
    children: List[Tuple[str, object]] = dataclasses.field(default_factory=list)

    @property
    def left(self):          # the paper's binary view (Algorithm 2)
        return self.children[0] if self.children else None

    @property
    def right(self):
        return self.children[1] if len(self.children) > 1 else None

    def iter_nodes(self) -> Iterator["IDGNode"]:
        yield self
        for kind, payload in self.children:
            if kind == "node":
                yield from payload.iter_nodes()

    def load_leaves(self) -> List[Inst]:
        out = []
        for kind, payload in self.children:
            if kind == LEAF_LOAD:
                out.append(payload)
            elif kind == "node":
                out.extend(payload.load_leaves())
        return out

    def size_ops(self) -> int:
        return sum(1 for _ in self.iter_nodes())


# ======================================================================
# Vectorized structural tables (columnar traces)
# ======================================================================
class _StructTables:
    """Derived structural indices of one columnar trace (built once, shared
    across every geometry variant via the trace's ``_struct`` memo).

    Register-source entries are the sub-sequence of the source-operand CSR
    with ``tag == SRC_REG``, in global (seq-major, slot-order) order:

      ``ent_seq``   consumer instruction of each entry
      ``ent_reg``   register read
      ``ent_pos``   the IHT position (writes-before-count − 1)
      ``ent_prod``  producing instruction (−1: no prior write)
      ``ireg_off``  CSR offsets per instruction into the entry arrays

    ``full_prod`` aligns with the *full* source CSR (immediates → −2) so
    producer resolution during a tree walk is one list index.
    """

    __slots__ = ("ent_seq", "ent_reg", "ent_pos", "ent_prod", "ireg_off",
                 "full_prod", "w_off", "w_seq", "full_prod_l", "src_off_l")

    def __init__(self, ct: ColumnarTrace):
        n = ct.n
        n_slots = ct.n_regs + 1                       # + induction register
        counts = np.diff(ct.src_off)
        seq_of_entry = np.repeat(np.arange(n, dtype=np.int64), counts)
        reg_mask = ct.src_tag == SRC_REG
        ent_idx = np.flatnonzero(reg_mask)
        self.ent_seq = seq_of_entry[ent_idx]
        self.ent_reg = ct.src_val[ent_idx].astype(np.int64)
        # per-instruction CSR over the entry arrays
        per_inst = np.bincount(self.ent_seq, minlength=n) if len(ent_idx) \
            else np.zeros(n, np.int64)
        self.ireg_off = np.zeros(n + 1, np.int64)
        np.cumsum(per_inst, out=self.ireg_off[1:])
        # writer lists per register (the RUT), register-major / seq-ascending
        wr_idx = np.flatnonzero(ct.dst >= 0)
        wr_reg = ct.dst[wr_idx].astype(np.int64)
        order = np.argsort(wr_reg, kind="stable")
        self.w_seq = wr_idx[order]
        self.w_off = np.zeros(n_slots + 1, np.int64)
        np.cumsum(np.bincount(wr_reg, minlength=n_slots),
                  out=self.w_off[1:])
        # producer of each register-source entry: latest write before it
        self.ent_pos = np.full(len(ent_idx), -1, np.int64)
        self.ent_prod = np.full(len(ent_idx), -1, np.int64)
        for r in range(n_slots):
            lo, hi = self.w_off[r], self.w_off[r + 1]
            sel = np.flatnonzero(self.ent_reg == r)
            if not len(sel):
                continue
            if lo == hi:                              # read, never written
                continue
            writes = self.w_seq[lo:hi]
            pos = np.searchsorted(writes, self.ent_seq[sel], side="left") - 1
            self.ent_pos[sel] = pos
            hit = pos >= 0
            self.ent_prod[sel[hit]] = writes[pos[hit]]
        self.full_prod = np.full(len(ct.src_tag), -2, np.int64)
        self.full_prod[ent_idx] = self.ent_prod
        # python-list mirrors for the (scalar-at-a-time) tree walks
        self.full_prod_l = self.full_prod.tolist()
        self.src_off_l = ct.src_off.tolist()


def _tables(ct: ColumnarTrace) -> _StructTables:
    t = ct._struct.get("tables")
    if t is None:
        t = ct._struct["tables"] = _StructTables(ct)
    return t


def build_rut_iht(ct: ColumnarTrace
                  ) -> Tuple[Dict[int, List[int]],
                             Dict[int, List[Tuple[int, int]]]]:
    """Reconstruct the probe-style RUT/IHT dicts from the columns.

    Exactly the tables the old incremental ``Machine._commit`` built: RUT
    has one (possibly empty) entry per architectural register, IHT one
    entry per committed instruction listing its register sources with
    their RUT position at commit time."""
    t = _tables(ct)
    rut: Dict[int, List[int]] = {}
    for r in range(ct.n_regs + 1):
        rut[r] = t.w_seq[t.w_off[r]:t.w_off[r + 1]].tolist()
    ent_reg = t.ent_reg.tolist()
    ent_pos = t.ent_pos.tolist()
    off = t.ireg_off.tolist()
    iht: Dict[int, List[Tuple[int, int]]] = {}
    for seq in range(ct.n):
        iht[seq] = [(ent_reg[j], ent_pos[j])
                    for j in range(off[seq], off[seq + 1])]
    return rut, iht


# ======================================================================
# Builder: resolves producers and builds trees (both trace layouts)
# ======================================================================
class IDGBuilder:
    """Resolves producers over a trace and builds IDG trees.

    Columnar traces use the vectorized producer index (O(1) lookups, lazy
    ``Inst`` row views); hand-built ``List[Inst]`` traces use the classic
    (RUT, IHT) dict tables."""

    def __init__(self, trace: Trace,
                 rut: Optional[Dict[int, List[int]]] = None,
                 iht: Optional[Dict[int, List[Tuple[int, int]]]] = None):
        self.trace = trace
        self._fast = isinstance(trace, ColumnarTrace)
        if self._fast:
            self._t = _tables(trace)
            self._src_tag = trace.src_tag.tolist()
            self._src_val = trace.src_val.tolist()
            self._src_kind = trace.src_kind.tolist()
        else:
            if rut is None or iht is None:
                raise ValueError("list-of-Inst traces need explicit RUT/IHT "
                                 "tables (trace_program builds them)")
        self.rut = rut
        self.iht = iht

    # ------------------------------------------------------------ lookups
    def producer_of(self, seq: int, src_slot: int) -> Optional[Inst]:
        """Defining instruction of the ``src_slot``-th *register* source."""
        if self._fast:
            t = self._t
            lo = t.ireg_off[seq]
            if src_slot >= t.ireg_off[seq + 1] - lo:
                return None
            prod = t.ent_prod[lo + src_slot]
            return self.trace.row(int(prod)) if prod >= 0 else None
        entries = self.iht.get(seq, ())
        if src_slot >= len(entries):
            return None
        reg, pos = entries[src_slot]
        writes = self.rut.get(reg, ())
        if 0 <= pos < len(writes):
            return self.trace[writes[pos]]
        return None

    def producers(self, inst: Inst) -> List[Tuple[str, object]]:
        """All source operands of ``inst`` resolved to (kind, payload).

        kind: "imm" for immediates, "inst" for register operands (payload =
        producing Inst), "unknown" when the register has no recorded writer
        (pre-existing machine state).
        """
        if self._fast:
            return self._producers_seq(inst.seq)
        out: List[Tuple[str, object]] = []
        reg_slot = 0
        for tag, val in inst.srcs:
            if tag == SRC_IMM:
                out.append(("imm", val))
            else:
                p = self.producer_of(inst.seq, reg_slot)
                reg_slot += 1
                out.append(("inst", p) if p is not None else ("unknown", val))
        return out

    def _producers_seq(self, seq: int) -> List[Tuple[str, object]]:
        t = self._t
        row = self.trace.row
        tag, val, kind, prod = (self._src_tag, self._src_val,
                                self._src_kind, t.full_prod_l)
        out: List[Tuple[str, object]] = []
        for j in range(t.src_off_l[seq], t.src_off_l[seq + 1]):
            if tag[j] == SRC_IMM:
                out.append(("imm", decode_imm(val[j], kind[j])))
            else:
                p = prod[j]
                out.append(("inst", row(p)) if p >= 0
                           else ("unknown", int(val[j])))
        return out

    # ------------------------------------------------------- tree building
    def create_tree(self, root: Inst, cim_set: FrozenSet[str],
                    claimed: Optional[set] = None,
                    max_ops: int = 64) -> Optional[IDGNode]:
        """Algorithm 2's create_tree: recursive producer expansion.

        Recurses through CiM-supported producers (composite patterns),
        terminates at load leaves / immediates, and cuts at non-CiM
        producers (their value is memory-resident via its store ->
        LEAF_MEMVAL).  ``claimed`` marks instructions already owned by an
        accepted candidate — the partition step's bookkeeping.
        """
        if root.op not in cim_set:
            return None
        budget = [max_ops]

        def build(inst: Inst) -> Optional[IDGNode]:
            if budget[0] <= 0:
                return None
            budget[0] -= 1
            node = IDGNode(inst)
            for kind, payload in self.producers(inst):
                if kind == "imm":
                    node.children.append((LEAF_IMM, payload))
                elif kind == "unknown":
                    node.children.append((LEAF_IMM, payload))
                else:
                    p: Inst = payload
                    if p.is_load:
                        node.children.append((LEAF_LOAD, p))
                    elif p.op == "mov" and all(t == SRC_IMM for t, _ in p.srcs):
                        # accumulator init (mov #imm): an immediate leaf
                        node.children.append((LEAF_IMM, p.srcs[0][1]))
                    elif (p.op in cim_set
                          and (claimed is None or p.seq not in claimed)):
                        sub = build(p)
                        if sub is None:
                            node.children.append((LEAF_MEMVAL, p))
                        else:
                            node.children.append(("node", sub))
                    else:
                        node.children.append((LEAF_MEMVAL, p))
            return node

        return build(root)

    def cim_root_seqs(self, cim_set: FrozenSet[str]) -> np.ndarray:
        """Ascending seqs of every CiM-supported instruction (fast mode)."""
        codes = [OP_CODE[o] for o in cim_set if o in OP_CODE]
        return np.flatnonzero(np.isin(self.trace.op, codes))

    def build_forest(self, cim_set: FrozenSet[str],
                     max_ops: int = 64) -> List[IDGNode]:
        """Algorithm 2's outer loop: one tree per CiM-supported instruction.

        (Offload selection uses a claimed-set variant instead so composite
        candidates are extracted exactly once — see core/offload.py.)
        """
        forest = []
        if self._fast:
            for seq in self.cim_root_seqs(cim_set):
                tree = self.create_tree(self.trace.row(int(seq)), cim_set,
                                        max_ops=max_ops)
                if tree is not None:
                    forest.append(tree)
            return forest
        for inst in self.trace:
            if inst.op in cim_set:
                tree = self.create_tree(inst, cim_set, max_ops=max_ops)
                if tree is not None:
                    forest.append(tree)
        return forest


# ======================================================================
# Auxiliary producer/consumer indices used by selection + reshaping
# ======================================================================
class FlowIndex:
    """Derived O(N) flow maps over a trace (built once, reused everywhere).

    Columnar storage — four CSR/paired-array tables instead of dicts —
    with the original dict views available as lazy properties, so legacy
    consumers (``flow.reg_consumers[p]`` …) keep working while the hot
    selection path uses the O(1) array accessors:

      ``consumers_of(seq)``    register consumers of an op's value
      ``stores_of(seq)``       stores that spilled an op's value
      ``load_source_of(seq)``  producing op behind a load (−1: none)
    """

    __slots__ = ("n", "rc_off", "rc_val", "so_off", "so_val", "ls_seq",
                 "ls_src", "_py", "_dicts")

    def __init__(self, n: int, rc_off, rc_val, so_off, so_val,
                 ls_seq, ls_src, dicts: Optional[dict] = None):
        self.n = n
        self.rc_off = rc_off
        self.rc_val = rc_val
        self.so_off = so_off
        self.so_val = so_val
        self.ls_seq = ls_seq
        self.ls_src = ls_src
        self._py = None
        self._dicts = dicts

    # ------------------------------------------------------ fast accessors
    def _py_tables(self):
        """Plain-list mirrors of the CSR tables (lazy, one-time): the
        selection inner loop does tens of thousands of point lookups, and
        list slicing/indexing beats numpy scalar indexing ~10x there."""
        if self._py is None:
            full = np.full(self.n, -1, np.int64)
            full[self.ls_seq] = self.ls_src
            self._py = (self.rc_off.tolist(), self.rc_val.tolist(),
                        self.so_off.tolist(), self.so_val.tolist(),
                        full.tolist())
        return self._py

    def consumers_of(self, seq: int) -> List[int]:
        rc_off, rc_val, _, _, _ = self._py_tables()
        return rc_val[rc_off[seq]:rc_off[seq + 1]]

    def stores_of(self, seq: int) -> List[int]:
        _, _, so_off, so_val, _ = self._py_tables()
        return so_val[so_off[seq]:so_off[seq + 1]]

    def load_source_of(self, seq: int) -> int:
        return self._py_tables()[4][seq]

    # ------------------------------------------------------- dict views
    def _build_dicts(self) -> dict:
        if self._dicts is None:
            def csr_dict(off, val):
                out: Dict[int, List[int]] = {}
                vals = val.tolist()
                offs = off.tolist()
                for seq in np.flatnonzero(np.diff(off)).tolist():
                    out[seq] = vals[offs[seq]:offs[seq + 1]]
                return out

            load_source = {}
            value_loads: Dict[int, List[int]] = {}
            for s, src in zip(self.ls_seq.tolist(), self.ls_src.tolist()):
                load_source[s] = None if src < 0 else src
                if src >= 0:
                    value_loads.setdefault(src, []).append(s)
            self._dicts = {
                "reg_consumers": csr_dict(self.rc_off, self.rc_val),
                "store_of": csr_dict(self.so_off, self.so_val),
                "load_source": load_source,
                "value_loads": value_loads,
            }
        return self._dicts

    @property
    def reg_consumers(self) -> Dict[int, List[int]]:
        return self._build_dicts()["reg_consumers"]

    @property
    def store_of(self) -> Dict[int, List[int]]:
        return self._build_dicts()["store_of"]

    @property
    def load_source(self) -> Dict[int, Optional[int]]:
        return self._build_dicts()["load_source"]

    @property
    def value_loads(self) -> Dict[int, List[int]]:
        return self._build_dicts()["value_loads"]

    # -------------------------------------------------------- construction
    @classmethod
    def from_dicts(cls, reg_consumers, store_of, load_source, value_loads,
                   n: int) -> "FlowIndex":
        """Wrap dict tables built by the legacy (row-path) construction."""
        def dict_csr(d):
            off = np.zeros(n + 1, np.int64)
            for k, v in d.items():
                off[k + 1] = len(v)
            np.cumsum(off, out=off)
            val = np.empty(int(off[-1]), np.int64)
            for k, v in d.items():
                val[off[k]:off[k + 1]] = v
            return off, val

        rc_off, rc_val = dict_csr(reg_consumers)
        so_off, so_val = dict_csr(store_of)
        ls_seq = np.asarray(sorted(load_source), np.int64)
        ls_src = np.asarray([-1 if load_source[s] is None else load_source[s]
                             for s in ls_seq.tolist()], np.int64)
        return cls(n, rc_off, rc_val, so_off, so_val, ls_seq, ls_src,
                   dicts={"reg_consumers": reg_consumers,
                          "store_of": store_of,
                          "load_source": load_source,
                          "value_loads": value_loads})

    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Array dict for .npz persistence (repro.dse.store layer 1)."""
        return {"flow_n": np.asarray([self.n], np.int64),
                "flow_rc_off": self.rc_off, "flow_rc_val": self.rc_val,
                "flow_so_off": self.so_off, "flow_so_val": self.so_val,
                "flow_ls_seq": self.ls_seq, "flow_ls_src": self.ls_src}

    @classmethod
    def from_arrays(cls, arrays: Dict[str, np.ndarray]) -> "FlowIndex":
        return cls(int(arrays["flow_n"][0]),
                   arrays["flow_rc_off"], arrays["flow_rc_val"],
                   arrays["flow_so_off"], arrays["flow_so_val"],
                   arrays["flow_ls_seq"], arrays["flow_ls_src"])

    # ---------------------------------------------------------- pickling
    def __getstate__(self):
        return (self.n, self.rc_off, self.rc_val, self.so_off, self.so_val,
                self.ls_seq, self.ls_src)

    def __setstate__(self, state):
        (self.n, self.rc_off, self.rc_val, self.so_off, self.so_val,
         self.ls_seq, self.ls_src) = state
        self._py = None
        self._dicts = None


def _build_flow_columnar(ct: ColumnarTrace) -> FlowIndex:
    """Vectorized flow construction over the structural columns."""
    t = _tables(ct)
    n = ct.n
    valid = t.ent_prod >= 0
    prod_v = t.ent_prod[valid]
    cons_v = t.ent_seq[valid]

    def group_csr(prods, vals):
        order = np.argsort(prods, kind="stable")
        off = np.zeros(n + 1, np.int64)
        if len(prods):
            np.cumsum(np.bincount(prods, minlength=n), out=off[1:])
        return off, vals[order]

    rc_off, rc_val = group_csr(prod_v, cons_v)
    cons_is_store = ct.op[cons_v] == OP_STORE if len(cons_v) \
        else np.zeros(0, bool)
    so_off, so_val = group_csr(prod_v[cons_is_store], cons_v[cons_is_store])

    # --- memory flow: each load's producing op via the last store to its
    # address with a resolvable producer (stores without one leave the
    # previous mapping intact, exactly like the incremental construction)
    mem_idx = np.flatnonzero(ct.mem_mask)
    m = len(mem_idx)
    if m == 0:
        return FlowIndex(n, rc_off, rc_val, so_off, so_val,
                         np.zeros(0, np.int64), np.zeros(0, np.int64))
    ev_is_store = ct.op[mem_idx] == OP_STORE
    # first resolved producer per store instruction
    ev_prod = np.full(m, -1, np.int64)
    if len(cons_v):
        s_seq = cons_v[cons_is_store]
        s_prod = prod_v[cons_is_store]
        uniq, first = np.unique(s_seq, return_index=True)
        pos = np.searchsorted(uniq, mem_idx)
        ok = (pos < len(uniq))
        ok[ok] = uniq[pos[ok]] == mem_idx[ok]
        ev_prod[ok] = s_prod[first[pos[ok]]]
    participate = ev_prod >= 0                       # producer-carrying stores

    order = np.argsort(ct.addr[mem_idx], kind="stable")   # addr-major
    a_sorted = ct.addr[mem_idx][order]
    new_grp = np.empty(m, bool)
    new_grp[0] = True
    new_grp[1:] = a_sorted[1:] != a_sorted[:-1]
    gid = np.cumsum(new_grp) - 1
    # segmented running "last participating store": offset the positions by
    # group so the cummax can never leak across address groups
    v = np.where(participate[order], np.arange(m, dtype=np.int64), -1)
    base = gid * (m + 1)
    w = np.where(v >= 0, v + base, base - 1)
    res = np.maximum.accumulate(w) - base
    last = np.where(res >= 0, res, -1)

    load_pos = np.flatnonzero(~ev_is_store[order])
    lsrc = np.where(last[load_pos] >= 0,
                    ev_prod[order[np.maximum(last[load_pos], 0)]], -1)
    load_seqs = mem_idx[order[load_pos]]
    o2 = np.argsort(load_seqs)
    return FlowIndex(n, rc_off, rc_val, so_off, so_val,
                     load_seqs[o2], lsrc[o2])


def _build_flow_rows(trace: Trace, rut, iht) -> FlowIndex:
    """The original object-at-a-time construction (hand-built traces)."""
    b = IDGBuilder(trace, rut, iht)
    reg_consumers: Dict[int, List[int]] = {}
    store_of: Dict[int, List[int]] = {}
    load_source: Dict[int, Optional[int]] = {}
    value_loads: Dict[int, List[int]] = {}
    last_writer_of_addr: Dict[int, int] = {}      # addr -> producing op seq

    for inst in trace:
        for kind, payload in b.producers(inst):
            if kind == "inst":
                p: Inst = payload
                reg_consumers.setdefault(p.seq, []).append(inst.seq)
                if inst.is_store:
                    store_of.setdefault(p.seq, []).append(inst.seq)
        if inst.is_store:
            prods = [p.seq for k, p in b.producers(inst) if k == "inst"]
            if prods:
                last_writer_of_addr[inst.addr] = prods[0]
        elif inst.is_load:
            src = last_writer_of_addr.get(inst.addr)
            load_source[inst.seq] = src
            if src is not None:
                value_loads.setdefault(src, []).append(inst.seq)
    return FlowIndex.from_dicts(reg_consumers, store_of, load_source,
                                value_loads, len(trace))


def build_flow_index(trace: Trace, rut=None, iht=None) -> FlowIndex:
    """Flow tables for a trace — vectorized for columnar traces (cached on
    the structural trace, so every geometry variant shares one build),
    object-at-a-time for hand-built ``List[Inst]`` traces."""
    if isinstance(trace, ColumnarTrace):
        flow = trace._struct.get("flow")
        if flow is None:
            flow = trace._struct["flow"] = _build_flow_columnar(trace)
        return flow
    return _build_flow_rows(trace, rut, iht)
