"""Pseudo-RISC ISA + I-state records (paper Table I).

The paper traces committed ARM instructions out of GEM5; we lower jaxpr
equations to an equivalent scalar RISC stream (``core/trace.py``).  Each
committed instruction is one :class:`Inst` — the "I-state" of Table I:

  sequence index        -> ``seq``
  mnemonic code         -> ``op`` (+ ``dtype`` tag)
  execution logic       -> ``unit`` (triggered functional unit)
  request from master   -> ``addr`` (address of a load/store request)
  memory access         -> ``level`` (cache level that served it), ``bank``
  response from slave   -> ``hit`` / ``mshr`` status

Registers are a finite file per class (int / float); ``srcs`` entries are
``(SRC_REG, reg_id)`` or ``(SRC_IMM, value)`` — immediates are the paper's
Fig. 4(b) variant.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

# ----------------------------------------------------------------- source tags
SRC_REG = 0
SRC_IMM = 1

# ------------------------------------------------------------ functional units
# (PipeProbe's "triggered functional unit" vocabulary.)
U_INT_ALU = "IntAlu"
U_INT_MUL = "IntMult"
U_INT_DIV = "IntDiv"
U_FP_ALU = "FloatAdd"
U_FP_MUL = "FloatMult"
U_FP_DIV = "FloatDiv"
U_FP_SPECIAL = "FloatSqrt"       # exp/log/tanh/rsqrt — the special-function unit
U_MEM_RD = "MemRead"
U_MEM_WR = "MemWrite"
U_BRANCH = "Branch"
U_SIMD = "SimdAlu"

_FLOAT_OPS_UNITS = {
    "add": U_FP_ALU, "sub": U_FP_ALU, "max": U_FP_ALU, "min": U_FP_ALU,
    "cmp": U_FP_ALU, "abs": U_FP_ALU, "neg": U_FP_ALU, "sel": U_FP_ALU,
    "mul": U_FP_MUL, "div": U_FP_DIV,
    "exp": U_FP_SPECIAL, "log": U_FP_SPECIAL, "tanh": U_FP_SPECIAL,
    "sqrt": U_FP_SPECIAL, "rsqrt": U_FP_SPECIAL, "sigmoid": U_FP_SPECIAL,
    "pow": U_FP_SPECIAL, "floor": U_FP_ALU, "round": U_FP_ALU, "sign": U_FP_ALU,
}
_INT_OPS_UNITS = {
    "add": U_INT_ALU, "sub": U_INT_ALU, "max": U_INT_ALU, "min": U_INT_ALU,
    "and": U_INT_ALU, "or": U_INT_ALU, "xor": U_INT_ALU, "not": U_INT_ALU,
    "shl": U_INT_ALU, "shr": U_INT_ALU, "cmp": U_INT_ALU, "sel": U_INT_ALU,
    "abs": U_INT_ALU, "neg": U_INT_ALU, "mov": U_INT_ALU, "sign": U_INT_ALU,
    "mul": U_INT_MUL, "div": U_INT_DIV, "rem": U_INT_DIV,
    "floor": U_INT_ALU, "round": U_INT_ALU,
    "agen": U_INT_ALU,            # loop induction / address generation —
                                  # never CiM-offloadable (host-only)
}


def unit_for(op: str, is_float: bool) -> str:
    if op == "load":
        return U_MEM_RD
    if op == "store":
        return U_MEM_WR
    table = _FLOAT_OPS_UNITS if is_float else _INT_OPS_UNITS
    return table.get(op, U_FP_ALU if is_float else U_INT_ALU)


# -------------------------------------------------------------- CiM op presets
# Table III's realized op set is {OR, AND, XOR, ADDW32}; [23] (STT-CiM)
# additionally supports SUB and CMP (-> max/min via compare-select).  We keep
# three presets; experiments use CIM_SET_STT unless stated otherwise.
CIM_SET_LOGIC = frozenset({"and", "or", "xor"})
CIM_SET_STT = frozenset({"and", "or", "xor", "add", "sub", "max", "min", "cmp"})
CIM_SET_FULL = CIM_SET_STT | frozenset({"mul"})   # bit-serial in-memory multiply

# Map an offloaded op onto the priced CiM operation class of Table III.
CIM_OP_CLASS = {
    "or": "CiM-OR", "and": "CiM-AND", "xor": "CiM-XOR", "not": "CiM-OR",
    "add": "CiM-ADD", "sub": "CiM-ADD",
    "max": "CiM-XOR", "min": "CiM-XOR", "cmp": "CiM-XOR",  # compare via SA tags
    "mul": "CiM-MUL",
}


# --------------------------------------------------- integer vocabularies
# The columnar trace core (repro.core.columnar) stores one small integer per
# I-state field instead of Python strings; these tuples are the shared,
# stable decode tables.  Codes index the tuples, so ``OPS[code]`` /
# ``OP_CODE[name]`` round-trip.  Order is append-only: extending a
# vocabulary must add at the END (persisted .npz artifacts embed the codes;
# reordering is a TRACE_VM_VERSION bump).
OPS = (
    "load", "store", "branch", "agen", "mov",
    "add", "sub", "mul", "div", "rem", "pow",
    "max", "min", "cmp", "sel",
    "and", "or", "xor", "not", "shl", "shr",
    "abs", "neg", "sign", "floor", "round",
    "exp", "log", "tanh", "sqrt", "rsqrt", "sigmoid",
)
OP_CODE = {name: i for i, name in enumerate(OPS)}
OP_LOAD = OP_CODE["load"]
OP_STORE = OP_CODE["store"]
OP_MOV = OP_CODE["mov"]

UNITS = (U_INT_ALU, U_INT_MUL, U_INT_DIV, U_FP_ALU, U_FP_MUL, U_FP_DIV,
         U_FP_SPECIAL, U_MEM_RD, U_MEM_WR, U_BRANCH, U_SIMD)
UNIT_CODE = {name: i for i, name in enumerate(UNITS)}

# cache level served an access (0 = not a memory instruction)
LEVELS = (None, "L1", "L2", "MEM")
LEVEL_CODE = {name: i for i, name in enumerate(LEVELS) if name}
LEVEL_NONE, LEVEL_L1, LEVEL_L2, LEVEL_MEM = 0, 1, 2, 3

DTYPE_TAGS = ("i", "f")
DTYPE_CODE = {"i": 0, "f": 1}

# immediate-value kinds (float64 storage round-trips through these)
IMM_INT, IMM_FLOAT, IMM_BOOL = 0, 1, 2


class Inst:
    """One committed instruction (I-state record, Table I)."""

    __slots__ = ("seq", "op", "unit", "dtype", "dst", "srcs", "addr", "size",
                 "level", "hit", "bank", "mshr")

    def __init__(self, seq: int, op: str, unit: str, dtype: str,
                 dst: Optional[int], srcs: Tuple,
                 addr: Optional[int] = None, size: int = 4):
        self.seq = seq
        self.op = op
        self.unit = unit
        self.dtype = dtype
        self.dst = dst                  # destination register id (None: store)
        self.srcs = srcs                # ((SRC_REG, r) | (SRC_IMM, v), ...)
        self.addr = addr                # memory address (load/store only)
        self.size = size                # access bytes
        # Filled by the cache model (AccessProbe / response-from-slave):
        self.level = None               # "L1" | "L2" | "MEM"
        self.hit = None                 # bool: hit at first-level lookup
        self.bank = None                # bank id at `level`
        self.mshr = False               # miss merged into an in-flight MSHR

    # --- serialization hooks (repro.dse.store persists whole traces) -------
    # Default __slots__ pickling emits a per-instance dict of slot names;
    # a positional tuple is ~2x smaller and faster over 10^4-10^5 records.
    def __getstate__(self) -> Tuple:
        return (self.seq, self.op, self.unit, self.dtype, self.dst,
                self.srcs, self.addr, self.size, self.level, self.hit,
                self.bank, self.mshr)

    def __setstate__(self, state: Tuple) -> None:
        (self.seq, self.op, self.unit, self.dtype, self.dst, self.srcs,
         self.addr, self.size, self.level, self.hit, self.bank,
         self.mshr) = state

    @property
    def is_load(self) -> bool:
        return self.op == "load"

    @property
    def is_store(self) -> bool:
        return self.op == "store"

    @property
    def is_mem(self) -> bool:
        return self.op in ("load", "store")

    @property
    def is_float(self) -> bool:
        return self.dtype == "f"

    def __repr__(self) -> str:  # debugging aid, mirrors Fig. 6's queue rows
        srcs = ",".join((f"r{v}" if t == SRC_REG else f"#{v!r}") for t, v in self.srcs)
        mem = f" @{self.addr:#x}[{self.level or '?'}]" if self.is_mem else ""
        dst = f"r{self.dst} <- " if self.dst is not None else ""
        return f"<{self.seq}: {dst}{self.op}.{self.dtype} {srcs}{mem}>"


Trace = List[Inst]                       # the committed instruction queue (CIQ)
