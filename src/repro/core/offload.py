"""Offloading-candidate selection — the paper's Algorithm 1.

Walks the CIQ in reverse order (outermost consumers first, so composite
patterns are extracted maximally), builds the IDG tree under each
CiM-supported root (Algorithm 2 via :mod:`repro.core.idg`), then applies
the paper's §IV-A/§IV-B constraints:

  * every op node's operation must be in the CiM-supported set;
  * leaves are loads, immediates, or memory-resident values;
  * at least one operand must actually come from memory;
  * the operands must co-reside at one CiM-capable cache level — operands
    at a *shallower* level can be written back to the offload level
    (§IV-C's reshaping rule, priced as `moves`), operands at a *deeper*
    level than any CiM-capable cache make the candidate infeasible there.

Dependent candidates from the same IDG tree (the output of one subtree
feeding another, Fig. 5c) are merged through memory: the connecting
load+store pair is elided and counted as an in-bank move (`internal_edges`).

Over a columnar trace the algorithm splits into two phases with different
dependence keys, mirroring the trace/replay split one layer down:

  * **partition** (structural) — tree extraction and the removal sets.
    With cross-level writeback enabled and no same-bank constraint
    (every sweep configuration), acceptance does not depend on *where*
    a leaf resides — a deeper-than-capable leaf is lifted, a shallower
    one moves — so the partition depends only on the program and the
    CiM op set.  It is computed once per (structural trace, op set) and
    shared across every cache geometry and CiM level set of a sweep.
  * **placement** (per geometry/level set) — vectorized: offload levels,
    cross-level moves, banks, and surviving DRAM fills, from the
    level/bank columns with `reduceat`/`bincount` segment operations.

Hand-built ``List[Inst]`` traces (and configs with the same-bank or
no-cross-level constraints, where acceptance *is* placement-dependent)
run the original single-pass algorithm; both paths produce identical
results (property-tested in ``tests/test_columnar.py``).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import (Dict, FrozenSet, Iterator, List, Optional, Sequence, Set,
                    Tuple)

import numpy as np

from repro.core.columnar import ColumnarTrace
from repro.core.idg import (LEAF_IMM, LEAF_LOAD, LEAF_MEMVAL, FlowIndex,
                            IDGBuilder, IDGNode, build_flow_index)
from repro.core.isa import (CIM_OP_CLASS, CIM_SET_STT, LEVEL_L1, LEVEL_MEM,
                            OPS, OP_STORE, Inst, Trace)

_LEVEL_DEPTH = {"L1": 0, "L2": 1, "MEM": 2}
_DEPTH_LEVEL = {v: k for k, v in _LEVEL_DEPTH.items()}

# Version of the *analysis* semantics layered on top of the trace: IDG/flow
# construction (core/idg.py), candidate selection (this module), and trace
# reshaping (core/reshape.py) — plus the serialized shape of their
# artifacts.  Bump whenever any of them would produce different artifacts
# for an unchanged trace — the on-disk analysis store (repro.dse.store)
# keys flow and selection artifacts by this number, so a selection-rule (or
# flow-encoding) change invalidates persisted results instead of silently
# re-serving pre-change numbers.  (Trace lowering changes are covered
# separately by repro.core.trace.TRACE_VM_VERSION.)
# v2: FlowIndex became columnar (CSR arrays instead of pickled dicts).
ANALYSIS_VERSION = 2


@dataclasses.dataclass(frozen=True)
class OffloadConfig:
    cim_set: FrozenSet[str] = CIM_SET_STT
    cim_levels: Tuple[str, ...] = ("L1", "L2")   # CiM-capable cache levels
    require_same_bank: bool = False   # off: assume [18]/[20]-style operand-
                                      # locality support (address translation)
    allow_cross_level: bool = True    # §IV-C writeback of shallower operands
    min_mem_operands: int = 1
    # the paper's IDG leaf rule: "the leaf node needs to be either a load
    # instruction or an immediate value" — at least one true load leaf,
    # otherwise offloading saves nothing (it would only add re-loads)
    min_load_leaves: int = 1
    max_tree_ops: int = 64

    def partition_key(self) -> Tuple:
        """The structural-phase dependence key (see module docstring)."""
        return (self.cim_set, self.min_mem_operands, self.min_load_leaves,
                self.max_tree_ops)


@dataclasses.dataclass
class Candidate:
    """One accepted offloading candidate (a subtree of one IDG tree)."""
    root_seq: int
    op_seqs: List[int]                 # CiM-executed op nodes (root included)
    op_classes: List[str]              # Table III pricing class per op node
    load_seqs: List[int]               # converted (removed) host loads
    store_seqs: List[int]              # stores absorbed into CiM writes
    level: str                         # offload level
    bank: Optional[int]
    moves: int                         # operands written back to `level`
    internal_edges: int                # merged same-tree subtree links
    added_loads: int                   # outside reg-consumers now load from mem
    memval_leaves: int
    dram_fills: int = 0                # leaves/stores whose line sat in DRAM —
                                       # the fill happens in BOTH scenarios

    @property
    def n_ops(self) -> int:
        return len(self.op_seqs)

    @property
    def converted_accesses(self) -> int:
        return len(self.load_seqs) + len(self.store_seqs)


@dataclasses.dataclass
class OffloadResult:
    candidates: List[Candidate]
    claimed: Set[int]                  # all removed host instruction seqs
    flow: FlowIndex
    config: OffloadConfig

    # compact pickling: the claimed set covers most of the trace — a packed
    # sorted array is ~10x smaller than a pickled set of Python ints
    def __getstate__(self):
        state = self.__dict__.copy()
        state["claimed"] = np.asarray(sorted(self.claimed), np.int32)
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self.claimed = set(state["claimed"].tolist())

    # ------------------------------------------------------------ metrics
    def macr(self, trace: Trace) -> float:
        """Memory-access conversion ratio (the paper's §VI-C metric)."""
        if isinstance(trace, ColumnarTrace):
            total = trace.mem_accesses()
        else:
            total = sum(1 for i in trace if i.is_mem)
        if total == 0:
            return 0.0
        converted = sum(c.converted_accesses for c in self.candidates)
        return converted / total

    def macr_breakdown(self, trace: Trace) -> Dict[str, float]:
        """Fig. 13: converted accesses split into L1 / other levels."""
        if isinstance(trace, ColumnarTrace):
            total = max(1, trace.mem_accesses())
            seqs = list(itertools.chain.from_iterable(
                c.load_seqs + c.store_seqs for c in self.candidates))
            if seqs:
                lv = trace.level[np.asarray(seqs, np.int64)]
                l1 = int((lv == LEVEL_L1).sum())
                other = len(seqs) - l1
            else:
                l1 = other = 0
        else:
            total = max(1, sum(1 for i in trace if i.is_mem))
            l1 = other = 0
            for c in self.candidates:
                for s in c.load_seqs + c.store_seqs:
                    if trace[s].level == "L1":
                        l1 += 1
                    else:
                        other += 1
        return {"macr": (l1 + other) / total, "l1": l1 / total,
                "other": other / total,
                "total_accesses": total, "converted": l1 + other}


# ======================================================================
# Generic (single-pass) acceptance — row traces + placement-constrained cfgs
# ======================================================================
def _leaf_levels(node: IDGNode, flow: FlowIndex, trace: Trace
                 ) -> Optional[List[Tuple[str, Optional[int], str, int]]]:
    """(kind, seq, level, bank) per memory-resident operand of a subtree."""
    out = []
    for kind, payload in node.children:
        if kind == LEAF_LOAD:
            inst: Inst = payload
            out.append((LEAF_LOAD, inst.seq, inst.level, inst.bank))
        elif kind == LEAF_MEMVAL:
            inst: Inst = payload
            stores = flow.stores_of(inst.seq)
            if not stores:
                return None                      # value never reached memory
            st = trace[stores[-1]]
            out.append((LEAF_MEMVAL, inst.seq, st.level, st.bank))
        elif kind == "node":
            sub = _leaf_levels(payload, flow, trace)
            if sub is None:
                return None
            out.extend(sub)
    return out


def _try_accept(node: IDGNode, flow: FlowIndex, trace: Trace,
                cfg: OffloadConfig, claimed: Set[int]) -> Optional[Candidate]:
    ops = list(node.iter_nodes())
    if any(n.inst.seq in claimed for n in ops):
        return None
    leaves = _leaf_levels(node, flow, trace)
    if leaves is None:
        return None
    mem_leaves = [l for l in leaves if l[0] in (LEAF_LOAD, LEAF_MEMVAL)]
    if len(mem_leaves) < cfg.min_mem_operands:
        return None
    if sum(1 for l in leaves if l[0] == LEAF_LOAD) < cfg.min_load_leaves:
        return None

    # ---- locality: pick the offload level (deepest leaf level among
    # CiM-capable levels); deeper-than-capable leaves are infeasible.
    depth_cap = max(_LEVEL_DEPTH[l] for l in cfg.cim_levels)
    max_depth = 0
    for _, _, level, _ in mem_leaves:
        d = _LEVEL_DEPTH.get(level, 2)
        if d > depth_cap:
            # data currently in DRAM (or below any CiM cache): the fill
            # happens in both scenarios — offload at the deepest CiM level.
            d = depth_cap
        max_depth = max(max_depth, d)
    # lift to the shallowest *enabled* level >= max_depth
    enabled_depths = sorted(_LEVEL_DEPTH[l] for l in cfg.cim_levels)
    target_depth = next((d for d in enabled_depths if d >= max_depth),
                        enabled_depths[-1])
    level = _DEPTH_LEVEL[target_depth]
    moves = sum(1 for _, _, lv, _ in mem_leaves
                if _LEVEL_DEPTH.get(lv, 2) < target_depth)
    if moves and not cfg.allow_cross_level:
        return None

    if cfg.require_same_bank:
        banks = {b for _, _, lv, b in mem_leaves if lv == level}
        if len(banks) > 1:
            return None

    # ---- gather the removal set --------------------------------------
    op_seqs = [n.inst.seq for n in ops]
    op_set = set(op_seqs)
    # loads/stores already claimed by an earlier candidate are shared
    # operands (the value is already array-resident) — never count twice
    load_seqs = sorted({s for k, s, _, _ in leaves if k == LEAF_LOAD}
                       - claimed)
    internal = 0
    # dependent-subtree merge: converted loads whose value was produced by
    # an op we also offload become in-bank moves (Fig. 5c)
    for s in load_seqs:
        src = flow.load_source_of(s)
        if src >= 0 and src in op_set:
            internal += 1
    store_set: Set[int] = set()
    added_loads = 0
    root_seq = node.inst.seq
    for p in op_seqs:
        store_set.update(s for s in flow.stores_of(p)
                         if s not in claimed)
        if p == root_seq:
            # the CiM macro-instruction is read-class ([23]): the root's
            # result returns to the host destination register like a load
            # result — its register consumers need no re-load
            continue
        for consumer in flow.consumers_of(p):  # outside reg readers
            # consumers claimed by *other* candidates read the value in the
            # array (selection runs in reverse order, so later consumers are
            # already resolved); only surviving host ops re-load it
            if (consumer not in op_set and consumer not in claimed
                    and not trace[consumer].is_store):
                added_loads += 1
    store_seqs = sorted(store_set)
    bank = trace[load_seqs[0]].bank if load_seqs else None
    # DRAM fills kept in both scenarios: one per unique line this candidate
    # touches whose access was served by main memory.
    fill_lines = {trace[s].addr // 64 for s in load_seqs
                  if trace[s].level == "MEM"}
    fill_lines |= {trace[s].addr // 64 for s in store_seqs
                   if trace[s].level == "MEM"}
    dram_fills = len(fill_lines)
    return Candidate(
        root_seq=node.inst.seq,
        op_seqs=op_seqs,
        op_classes=[CIM_OP_CLASS.get(trace[s].op, "CiM-ADD") for s in op_seqs],
        load_seqs=load_seqs,
        store_seqs=store_seqs,
        level=level,
        bank=bank,
        moves=moves,
        internal_edges=internal,
        added_loads=added_loads,
        memval_leaves=sum(1 for k, *_ in leaves if k == LEAF_MEMVAL),
        dram_fills=dram_fills,
    )


# ======================================================================
# Columnar fast path: structural partition + vectorized placement
# ======================================================================
@dataclasses.dataclass
class _ProtoCandidate:
    """Structural (placement-free) half of one accepted candidate."""
    root_seq: int
    op_seqs: List[int]
    op_classes: List[str]
    load_seqs: List[int]
    store_seqs: List[int]
    internal_edges: int
    added_loads: int
    memval_leaves: int
    leaf_src: List[int]               # per mem leaf: load / last-store seq


@dataclasses.dataclass
class SelectionPartition:
    """Output of the structural phase: the candidate partition of one
    trace under one CiM op set (shared across geometries/level sets)."""
    protos: List[_ProtoCandidate]
    claimed: Set[int]


class _SeqNode:
    """Skeleton IDG node for the structural partition: sequence indices
    only, no ``Inst`` materialization.  ``children`` entries are
    ``("node", _SeqNode)`` / ``(LEAF_LOAD, seq)`` / ``(LEAF_MEMVAL, seq)``
    — immediate leaves carry no structural information and are omitted."""

    __slots__ = ("seq", "children")

    def __init__(self, seq: int):
        self.seq = seq
        self.children: List[Tuple[str, object]] = []

    def iter_seqs(self) -> Iterator[int]:          # pre-order, like IDGNode
        yield self.seq
        for kind, payload in self.children:
            if kind == "node":
                yield from payload.iter_seqs()


def _create_seq_tree(root_seq: int, ct_lists, cim_codes: FrozenSet[int],
                     claimed: Set[int], max_ops: int) -> Optional[_SeqNode]:
    """Algorithm 2's create_tree over raw sequence indices (fast path).

    Exactly :meth:`IDGBuilder.create_tree`'s recursion — same producer
    resolution, same mov-immediate collapse, same claimed/budget cuts —
    expressed over the integer columns."""
    op_l, src_off_l, prod_l, ireg_off_l, mov_code, load_code = ct_lists
    budget = [max_ops]

    def build(seq: int) -> Optional[_SeqNode]:
        if budget[0] <= 0:
            return None
        budget[0] -= 1
        node = _SeqNode(seq)
        children = node.children
        for j in range(src_off_l[seq], src_off_l[seq + 1]):
            p = prod_l[j]
            if p < 0:
                continue                          # immediate / unknown leaf
            p_op = op_l[p]
            if p_op == load_code:
                children.append((LEAF_LOAD, p))
            elif p_op == mov_code and ireg_off_l[p] == ireg_off_l[p + 1]:
                continue                          # accumulator init: imm leaf
            elif p_op in cim_codes and p not in claimed:
                sub = build(p)
                children.append((LEAF_MEMVAL, p) if sub is None
                                else ("node", sub))
            else:
                children.append((LEAF_MEMVAL, p))
        return node

    return build(root_seq)


def _leaf_sources(node: _SeqNode, flow: FlowIndex
                  ) -> Optional[List[Tuple[str, int]]]:
    """(kind, residence seq) per memory-resident operand of a subtree —
    the structural analogue of :func:`_leaf_levels` (levels attach later)."""
    out = []
    for kind, payload in node.children:
        if kind == LEAF_LOAD:
            out.append((LEAF_LOAD, payload))
        elif kind == LEAF_MEMVAL:
            stores = flow.stores_of(payload)
            if not stores:
                return None                      # value never reached memory
            out.append((LEAF_MEMVAL, stores[-1]))
        else:
            sub = _leaf_sources(payload, flow)
            if sub is None:
                return None
            out.extend(sub)
    return out


def _try_accept_structural(node: _SeqNode, flow: FlowIndex, op_col: List[int],
                           cfg: OffloadConfig, claimed: Set[int]
                           ) -> Optional[_ProtoCandidate]:
    children = node.children
    if not any(k == "node" for k, _ in children):
        # single-op tree (the overwhelmingly common shape): no subtree
        # recursion, no outside register consumers beyond the root's (whose
        # result returns in-register), so the removal set is direct
        seq = node.seq
        if seq in claimed:
            return None
        loads = [s for k, s in children if k == LEAF_LOAD]
        n_leaves = len(children)          # imm leaves were never appended
        memvals = n_leaves - len(loads)
        if n_leaves < cfg.min_mem_operands or len(loads) < cfg.min_load_leaves:
            return None
        leaf_src = []
        for kind, s in children:
            if kind == LEAF_LOAD:
                leaf_src.append(s)
            else:
                stores = flow.stores_of(s)
                if not stores:
                    return None
                leaf_src.append(stores[-1])
        load_seqs = sorted(set(loads) - claimed)
        load_source_of = flow.load_source_of
        internal = sum(1 for s in load_seqs if load_source_of(s) == seq)
        return _ProtoCandidate(
            root_seq=seq, op_seqs=[seq],
            op_classes=[CIM_OP_CLASS.get(OPS[op_col[seq]], "CiM-ADD")],
            load_seqs=load_seqs,
            store_seqs=sorted(s for s in flow.stores_of(seq)
                              if s not in claimed),
            internal_edges=internal, added_loads=0, memval_leaves=memvals,
            leaf_src=leaf_src)

    op_seqs = list(node.iter_seqs())
    if not claimed.isdisjoint(op_seqs):
        return None
    leaves = _leaf_sources(node, flow)
    if leaves is None:
        return None
    if len(leaves) < cfg.min_mem_operands:
        return None
    if sum(1 for k, _ in leaves if k == LEAF_LOAD) < cfg.min_load_leaves:
        return None

    op_set = set(op_seqs)
    load_seqs = sorted({s for k, s in leaves if k == LEAF_LOAD} - claimed)
    internal = 0
    for s in load_seqs:
        src = flow.load_source_of(s)
        if src >= 0 and src in op_set:
            internal += 1
    store_set: Set[int] = set()
    added_loads = 0
    root_seq = node.seq
    for p in op_seqs:
        store_set.update(s for s in flow.stores_of(p)
                         if s not in claimed)
        if p == root_seq:
            continue
        for consumer in flow.consumers_of(p):
            if (consumer not in op_set and consumer not in claimed
                    and op_col[consumer] != OP_STORE):
                added_loads += 1
    return _ProtoCandidate(
        root_seq=root_seq,
        op_seqs=op_seqs,
        op_classes=[CIM_OP_CLASS.get(OPS[op_col[s]], "CiM-ADD")
                    for s in op_seqs],
        load_seqs=load_seqs,
        store_seqs=sorted(store_set),
        internal_edges=internal,
        added_loads=added_loads,
        memval_leaves=sum(1 for k, _ in leaves if k == LEAF_MEMVAL),
        leaf_src=[s for _, s in leaves],
    )


def _partition(ct: ColumnarTrace, builder: IDGBuilder, flow: FlowIndex,
               cfg: OffloadConfig) -> SelectionPartition:
    """Algorithm 1's reverse-order tree extraction, structural fields only.

    Memoized per (structural trace, partition key) on the trace's shared
    ``_struct`` dict — one partition serves every geometry and CiM level
    set of a sweep."""
    memo = ct._struct.setdefault("partitions", {})
    hit = memo.get(cfg.partition_key())
    if hit is not None:
        return hit
    from repro.core.idg import _tables
    from repro.core.isa import OP_CODE, OP_LOAD, OP_MOV
    t = _tables(ct)
    op_col = ct.op.tolist()
    ct_lists = (op_col, t.src_off_l, t.full_prod_l, t.ireg_off.tolist(),
                OP_MOV, OP_LOAD)
    cim_codes = frozenset(OP_CODE[o] for o in cfg.cim_set if o in OP_CODE)
    claimed: Set[int] = set()
    protos: List[_ProtoCandidate] = []
    roots = builder.cim_root_seqs(cfg.cim_set)
    for seq in roots[::-1].tolist():
        if seq in claimed:
            continue
        tree = _create_seq_tree(seq, ct_lists, cim_codes, claimed,
                                cfg.max_tree_ops)
        if tree is None:
            continue
        proto = _try_accept_structural(tree, flow, op_col, cfg, claimed)
        if proto is None:
            # Fig. 5: the whole tree failed — try its child subtrees
            for kind, payload in tree.children:
                if kind == "node":
                    sub = _try_accept_structural(payload, flow, op_col, cfg,
                                                 claimed)
                    if sub is not None:
                        protos.append(sub)
                        claimed.update(sub.op_seqs)
                        claimed.update(sub.load_seqs)
                        claimed.update(sub.store_seqs)
            continue
        protos.append(proto)
        claimed.update(proto.op_seqs)
        claimed.update(proto.load_seqs)
        claimed.update(proto.store_seqs)
    protos.reverse()                         # report in program order
    part = SelectionPartition(protos, claimed)
    memo[cfg.partition_key()] = part
    return part


def _place(part: SelectionPartition, ct: ColumnarTrace,
           cfg: OffloadConfig) -> List[Candidate]:
    """Vectorized placement: levels, moves, banks, DRAM fills per proto."""
    protos = part.protos
    if not protos:
        return []
    from repro.core import accel
    if accel.enabled():
        placed = accel.place_candidates(part, ct, cfg)
        if placed is not None:          # None: int32 overflow -> numpy oracle
            return placed
    depth_cap = max(_LEVEL_DEPTH[l] for l in cfg.cim_levels)
    enabled = np.asarray(sorted(_LEVEL_DEPTH[l] for l in cfg.cim_levels))

    leaf_counts = np.asarray([len(p.leaf_src) for p in protos], np.int64)
    off = np.zeros(len(protos) + 1, np.int64)
    np.cumsum(leaf_counts, out=off[1:])
    all_leaf = np.asarray(list(itertools.chain.from_iterable(
        p.leaf_src for p in protos)), np.int64)
    nonempty = leaf_counts > 0

    # depth per leaf (level codes are 1=L1, 2=L2, 3=MEM -> depth = code-1),
    # clamped at the deepest CiM-capable level (DRAM-resident operands fill
    # in both scenarios)
    depth = np.minimum(ct.level[all_leaf].astype(np.int64) - 1, depth_cap)
    max_depth = np.zeros(len(protos), np.int64)
    if len(all_leaf):
        seg_max = np.maximum.reduceat(depth, np.minimum(off[:-1],
                                                        len(all_leaf) - 1))
        max_depth[nonempty] = seg_max[nonempty]
    # lift to the shallowest enabled level >= max_depth
    tpos = np.minimum(np.searchsorted(enabled, max_depth), len(enabled) - 1)
    target = enabled[tpos]
    moves = np.zeros(len(protos), np.int64)
    if len(all_leaf):
        shallower = (depth < np.repeat(target, leaf_counts)).astype(np.int64)
        seg_sum = np.add.reduceat(shallower, np.minimum(off[:-1],
                                                        len(all_leaf) - 1))
        moves[nonempty] = seg_sum[nonempty]

    # DRAM fills: unique (proto, line) pairs among converted accesses whose
    # access was served by main memory
    acc_counts = np.asarray([len(p.load_seqs) + len(p.store_seqs)
                             for p in protos], np.int64)
    acc_seqs = np.asarray(list(itertools.chain.from_iterable(
        p.load_seqs + p.store_seqs for p in protos)), np.int64)
    fills = np.zeros(len(protos), np.int64)
    if len(acc_seqs):
        pid = np.repeat(np.arange(len(protos)), acc_counts)
        in_mem = ct.level[acc_seqs] == LEVEL_MEM
        if in_mem.any():
            lines = ct.addr[acc_seqs[in_mem]] // 64
            key = pid[in_mem] * (1 << 40) + lines
            uniq_pid = np.unique(key) >> 40
            fills += np.bincount(uniq_pid, minlength=len(protos))

    bank_col = ct.bank
    level_of = [_DEPTH_LEVEL[int(d)] for d in target]
    out = []
    for i, p in enumerate(protos):
        out.append(Candidate(
            root_seq=p.root_seq, op_seqs=p.op_seqs, op_classes=p.op_classes,
            load_seqs=p.load_seqs, store_seqs=p.store_seqs,
            level=level_of[i],
            bank=int(bank_col[p.load_seqs[0]]) if p.load_seqs else None,
            moves=int(moves[i]), internal_edges=p.internal_edges,
            added_loads=p.added_loads, memval_leaves=p.memval_leaves,
            dram_fills=int(fills[i])))
    return out


# ======================================================================
# Analysis bundle + entry points
# ======================================================================
class TraceAnalysis:
    """Config-independent artifacts of one traced workload.

    Everything here depends only on the program (and, for the level/bank
    columns consulted at placement time, the cache hierarchy it was
    replayed under) — not on the CiM level set, op set, or technology.
    Building it once and pricing many configurations against it is what
    makes design-space sweeps cheap (see :mod:`repro.dse.engine`).  For
    columnar traces the builder, flow index, and selection partitions are
    shared through the structural trace's memo, so geometry variants of
    one workload reuse them automatically.
    """

    def __init__(self, trace: Trace, rut=None, iht=None,
                 builder: Optional[IDGBuilder] = None,
                 flow: Optional[FlowIndex] = None):
        self.trace = trace
        self._rut = rut
        self._iht = iht
        self.builder = builder or IDGBuilder(trace, rut, iht)
        self.flow = flow if flow is not None \
            else build_flow_index(trace, rut, iht)

    @property
    def rut(self):
        if self._rut is None and isinstance(self.trace, ColumnarTrace):
            return self.trace.rut
        return self._rut

    @property
    def iht(self):
        if self._iht is None and isinstance(self.trace, ColumnarTrace):
            return self.trace.iht
        return self._iht

    def select(self, cfg: OffloadConfig = OffloadConfig()) -> OffloadResult:
        """Run Algorithm 1 against these artifacts for one configuration."""
        return select_candidates(self.trace, self._rut, self._iht, cfg,
                                 flow=self.flow, builder=self.builder)


def analyze_trace(tr) -> TraceAnalysis:
    """Build the reusable IDG/flow artifacts for a ``TraceResult`` (or any
    object exposing ``trace`` — plus ``rut``/``iht`` for row traces)."""
    trace = tr.trace
    if isinstance(trace, ColumnarTrace):
        return TraceAnalysis(trace)
    return TraceAnalysis(trace, tr.rut, tr.iht)


def rehydrate_analysis(tr, flow: FlowIndex) -> TraceAnalysis:
    """Reassemble a :class:`TraceAnalysis` from persisted artifacts.

    The only *derived* table worth storing is the :class:`FlowIndex`
    (:class:`IDGBuilder` is a stateless view over the trace), so the
    on-disk analysis store saves ``(TraceResult, FlowIndex)`` and this hook
    rebuilds the full analysis without re-walking the trace."""
    trace = tr.trace
    if isinstance(trace, ColumnarTrace):
        trace._struct.setdefault("flow", flow)
        return TraceAnalysis(trace, flow=flow)
    return TraceAnalysis(trace, tr.rut, tr.iht, flow=flow)


def select_candidates(trace: Trace, rut=None, iht=None,
                      cfg: OffloadConfig = OffloadConfig(),
                      flow: Optional[FlowIndex] = None,
                      builder: Optional[IDGBuilder] = None) -> OffloadResult:
    """Algorithm 1: build tables -> build IDG trees -> partition/extract."""
    builder = builder or IDGBuilder(trace, rut, iht)
    flow = flow or build_flow_index(trace, rut, iht)

    if isinstance(trace, ColumnarTrace):
        if cfg.allow_cross_level and not cfg.require_same_bank:
            # structural partition (shared across geometries) + placement
            part = _partition(trace, builder, flow, cfg)
            return OffloadResult(_place(part, trace, cfg), part.claimed,
                                 flow, cfg)
        # placement-dependent acceptance: single-pass over CiM roots only
        claimed: Set[int] = set()
        candidates: List[Candidate] = []
        for seq in builder.cim_root_seqs(cfg.cim_set)[::-1].tolist():
            if seq in claimed:
                continue
            tree = builder.create_tree(trace.row(seq), cfg.cim_set,
                                       claimed=claimed,
                                       max_ops=cfg.max_tree_ops)
            if tree is None:
                continue
            _accept_or_descend(tree, flow, trace, cfg, claimed, candidates)
        candidates.reverse()
        return OffloadResult(candidates, claimed, flow, cfg)

    claimed = set()
    candidates = []
    # reverse order: outermost roots first => maximal composite extraction
    for seq in range(len(trace) - 1, -1, -1):
        inst = trace[seq]
        if inst.op not in cfg.cim_set or seq in claimed:
            continue
        tree = builder.create_tree(inst, cfg.cim_set, claimed=claimed,
                                   max_ops=cfg.max_tree_ops)
        if tree is None:
            continue
        _accept_or_descend(tree, flow, trace, cfg, claimed, candidates)

    candidates.reverse()                     # report in program order
    return OffloadResult(candidates, claimed, flow, cfg)


def _accept_or_descend(tree: IDGNode, flow: FlowIndex, trace: Trace,
                       cfg: OffloadConfig, claimed: Set[int],
                       candidates: List[Candidate]) -> None:
    """Accept the whole tree, or (Fig. 5) its immediate child subtrees."""
    cand = _try_accept(tree, flow, trace, cfg, claimed)
    if cand is None:
        for kind, payload in tree.children:
            if kind == "node":
                sub = _try_accept(payload, flow, trace, cfg, claimed)
                if sub is not None:
                    candidates.append(sub)
                    claimed.update(sub.op_seqs)
                    claimed.update(sub.load_seqs)
                    claimed.update(sub.store_seqs)
        return
    candidates.append(cand)
    claimed.update(cand.op_seqs)
    claimed.update(cand.load_seqs)
    claimed.update(cand.store_seqs)
