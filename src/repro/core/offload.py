"""Offloading-candidate selection — the paper's Algorithm 1.

Walks the CIQ in reverse order (outermost consumers first, so composite
patterns are extracted maximally), builds the IDG tree under each
CiM-supported root (Algorithm 2 via :mod:`repro.core.idg`), then applies
the paper's §IV-A/§IV-B constraints:

  * every op node's operation must be in the CiM-supported set;
  * leaves are loads, immediates, or memory-resident values;
  * at least one operand must actually come from memory;
  * the operands must co-reside at one CiM-capable cache level — operands
    at a *shallower* level can be written back to the offload level
    (§IV-C's reshaping rule, priced as `moves`), operands at a *deeper*
    level than any CiM-capable cache make the candidate infeasible there.

Dependent candidates from the same IDG tree (the output of one subtree
feeding another, Fig. 5c) are merged through memory: the connecting
load+store pair is elided and counted as an in-bank move (`internal_edges`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from repro.core.idg import (LEAF_IMM, LEAF_LOAD, LEAF_MEMVAL, FlowIndex,
                            IDGBuilder, IDGNode, build_flow_index)
from repro.core.isa import CIM_OP_CLASS, CIM_SET_STT, Inst, Trace

_LEVEL_DEPTH = {"L1": 0, "L2": 1, "MEM": 2}

# Version of the *analysis* semantics layered on top of the trace: IDG/flow
# construction (core/idg.py), candidate selection (this module), and trace
# reshaping (core/reshape.py).  Bump whenever any of them would produce
# different artifacts for an unchanged trace — the on-disk analysis store
# (repro.dse.store) keys flow and selection artifacts by this number, so a
# selection-rule change invalidates persisted results instead of silently
# re-serving pre-change numbers.  (Trace lowering changes are covered
# separately by repro.core.trace.TRACE_VM_VERSION.)
ANALYSIS_VERSION = 1


@dataclasses.dataclass(frozen=True)
class OffloadConfig:
    cim_set: FrozenSet[str] = CIM_SET_STT
    cim_levels: Tuple[str, ...] = ("L1", "L2")   # CiM-capable cache levels
    require_same_bank: bool = False   # off: assume [18]/[20]-style operand-
                                      # locality support (address translation)
    allow_cross_level: bool = True    # §IV-C writeback of shallower operands
    min_mem_operands: int = 1
    # the paper's IDG leaf rule: "the leaf node needs to be either a load
    # instruction or an immediate value" — at least one true load leaf,
    # otherwise offloading saves nothing (it would only add re-loads)
    min_load_leaves: int = 1
    max_tree_ops: int = 64


@dataclasses.dataclass
class Candidate:
    """One accepted offloading candidate (a subtree of one IDG tree)."""
    root_seq: int
    op_seqs: List[int]                 # CiM-executed op nodes (root included)
    op_classes: List[str]              # Table III pricing class per op node
    load_seqs: List[int]               # converted (removed) host loads
    store_seqs: List[int]              # stores absorbed into CiM writes
    level: str                         # offload level
    bank: Optional[int]
    moves: int                         # operands written back to `level`
    internal_edges: int                # merged same-tree subtree links
    added_loads: int                   # outside reg-consumers now load from mem
    memval_leaves: int
    dram_fills: int = 0                # leaves/stores whose line sat in DRAM —
                                       # the fill happens in BOTH scenarios

    @property
    def n_ops(self) -> int:
        return len(self.op_seqs)

    @property
    def converted_accesses(self) -> int:
        return len(self.load_seqs) + len(self.store_seqs)


@dataclasses.dataclass
class OffloadResult:
    candidates: List[Candidate]
    claimed: Set[int]                  # all removed host instruction seqs
    flow: FlowIndex
    config: OffloadConfig

    # ------------------------------------------------------------ metrics
    def macr(self, trace: Trace) -> float:
        """Memory-access conversion ratio (the paper's §VI-C metric)."""
        total = sum(1 for i in trace if i.is_mem)
        if total == 0:
            return 0.0
        converted = sum(c.converted_accesses for c in self.candidates)
        return converted / total

    def macr_breakdown(self, trace: Trace) -> Dict[str, float]:
        """Fig. 13: converted accesses split into L1 / other levels."""
        total = max(1, sum(1 for i in trace if i.is_mem))
        l1 = other = 0
        for c in self.candidates:
            for s in c.load_seqs + c.store_seqs:
                if trace[s].level == "L1":
                    l1 += 1
                else:
                    other += 1
        return {"macr": (l1 + other) / total, "l1": l1 / total,
                "other": other / total,
                "total_accesses": total, "converted": l1 + other}


def _leaf_levels(node: IDGNode, flow: FlowIndex, trace: Trace
                 ) -> Optional[List[Tuple[str, Optional[int], str, int]]]:
    """(kind, seq, level, bank) per memory-resident operand of a subtree."""
    out = []
    for kind, payload in node.children:
        if kind == LEAF_LOAD:
            inst: Inst = payload
            out.append((LEAF_LOAD, inst.seq, inst.level, inst.bank))
        elif kind == LEAF_MEMVAL:
            inst: Inst = payload
            stores = flow.store_of.get(inst.seq, [])
            if not stores:
                return None                      # value never reached memory
            st = trace[stores[-1]]
            out.append((LEAF_MEMVAL, inst.seq, st.level, st.bank))
        elif kind == "node":
            sub = _leaf_levels(payload, flow, trace)
            if sub is None:
                return None
            out.extend(sub)
    return out


def _try_accept(node: IDGNode, flow: FlowIndex, trace: Trace,
                cfg: OffloadConfig, claimed: Set[int]) -> Optional[Candidate]:
    ops = list(node.iter_nodes())
    if any(n.inst.seq in claimed for n in ops):
        return None
    leaves = _leaf_levels(node, flow, trace)
    if leaves is None:
        return None
    mem_leaves = [l for l in leaves if l[0] in (LEAF_LOAD, LEAF_MEMVAL)]
    if len(mem_leaves) < cfg.min_mem_operands:
        return None
    if sum(1 for l in leaves if l[0] == LEAF_LOAD) < cfg.min_load_leaves:
        return None

    # ---- locality: pick the offload level (deepest leaf level among
    # CiM-capable levels); deeper-than-capable leaves are infeasible.
    depth_cap = max(_LEVEL_DEPTH[l] for l in cfg.cim_levels)
    max_depth = 0
    for _, _, level, _ in mem_leaves:
        d = _LEVEL_DEPTH.get(level, 2)
        if d > depth_cap:
            # data currently in DRAM (or below any CiM cache): the fill
            # happens in both scenarios — offload at the deepest CiM level.
            d = depth_cap
        max_depth = max(max_depth, d)
    # lift to the shallowest *enabled* level >= max_depth
    enabled_depths = sorted(_LEVEL_DEPTH[l] for l in cfg.cim_levels)
    target_depth = next((d for d in enabled_depths if d >= max_depth),
                        enabled_depths[-1])
    level = {v: k for k, v in _LEVEL_DEPTH.items()}[target_depth]
    moves = sum(1 for _, _, lv, _ in mem_leaves
                if _LEVEL_DEPTH.get(lv, 2) < target_depth)
    if moves and not cfg.allow_cross_level:
        return None

    if cfg.require_same_bank:
        banks = {b for _, _, lv, b in mem_leaves if lv == level}
        if len(banks) > 1:
            return None

    # ---- gather the removal set --------------------------------------
    op_seqs = [n.inst.seq for n in ops]
    op_set = set(op_seqs)
    # loads/stores already claimed by an earlier candidate are shared
    # operands (the value is already array-resident) — never count twice
    load_seqs = sorted({s for k, s, _, _ in leaves if k == LEAF_LOAD}
                       - claimed)
    internal = 0
    # dependent-subtree merge: converted loads whose value was produced by
    # an op we also offload become in-bank moves (Fig. 5c)
    for s in load_seqs:
        src = flow.load_source.get(s)
        if src is not None and src in op_set:
            internal += 1
    store_set: Set[int] = set()
    added_loads = 0
    root_seq = node.inst.seq
    for p in op_seqs:
        store_set.update(s for s in flow.store_of.get(p, ())
                         if s not in claimed)
        if p == root_seq:
            # the CiM macro-instruction is read-class ([23]): the root's
            # result returns to the host destination register like a load
            # result — its register consumers need no re-load
            continue
        for consumer in flow.reg_consumers.get(p, ()):  # outside reg readers
            # consumers claimed by *other* candidates read the value in the
            # array (selection runs in reverse order, so later consumers are
            # already resolved); only surviving host ops re-load it
            if (consumer not in op_set and consumer not in claimed
                    and not trace[consumer].is_store):
                added_loads += 1
    store_seqs = sorted(store_set)
    bank = trace[load_seqs[0]].bank if load_seqs else None
    # DRAM fills kept in both scenarios: one per unique line this candidate
    # touches whose access was served by main memory.
    fill_lines = {trace[s].addr // 64 for s in load_seqs
                  if trace[s].level == "MEM"}
    fill_lines |= {trace[s].addr // 64 for s in store_seqs
                   if trace[s].level == "MEM"}
    dram_fills = len(fill_lines)
    return Candidate(
        root_seq=node.inst.seq,
        op_seqs=op_seqs,
        op_classes=[CIM_OP_CLASS.get(trace[s].op, "CiM-ADD") for s in op_seqs],
        load_seqs=load_seqs,
        store_seqs=store_seqs,
        level=level,
        bank=bank,
        moves=moves,
        internal_edges=internal,
        added_loads=added_loads,
        memval_leaves=sum(1 for k, *_ in leaves if k == LEAF_MEMVAL),
        dram_fills=dram_fills,
    )


@dataclasses.dataclass
class TraceAnalysis:
    """Config-independent artifacts of one traced workload.

    Everything here depends only on the program and the cache hierarchy it
    was traced under — not on the CiM level set, op set, or technology.
    Building it once and pricing many configurations against it is what
    makes design-space sweeps cheap (see :mod:`repro.dse.engine`).
    """
    trace: Trace
    rut: Dict[int, List[int]]
    iht: Dict[int, List[Tuple[int, int]]]
    builder: IDGBuilder
    flow: FlowIndex

    def select(self, cfg: OffloadConfig = OffloadConfig()) -> OffloadResult:
        """Run Algorithm 1 against these artifacts for one configuration."""
        return select_candidates(self.trace, self.rut, self.iht, cfg,
                                 flow=self.flow, builder=self.builder)


def analyze_trace(tr) -> TraceAnalysis:
    """Build the reusable IDG/flow artifacts for a ``TraceResult`` (or any
    object exposing ``trace``/``rut``/``iht``)."""
    builder = IDGBuilder(tr.trace, tr.rut, tr.iht)
    flow = build_flow_index(tr.trace, tr.rut, tr.iht)
    return TraceAnalysis(tr.trace, tr.rut, tr.iht, builder, flow)


def rehydrate_analysis(tr, flow: FlowIndex) -> TraceAnalysis:
    """Reassemble a :class:`TraceAnalysis` from persisted artifacts.

    The only *derived* table worth storing is the :class:`FlowIndex`
    (:class:`IDGBuilder` is a stateless view over trace/RUT/IHT), so the
    on-disk analysis store saves ``(TraceResult, FlowIndex)`` and this hook
    rebuilds the full analysis without re-walking the trace."""
    return TraceAnalysis(tr.trace, tr.rut, tr.iht,
                         IDGBuilder(tr.trace, tr.rut, tr.iht), flow)


def select_candidates(trace: Trace, rut, iht,
                      cfg: OffloadConfig = OffloadConfig(),
                      flow: Optional[FlowIndex] = None,
                      builder: Optional[IDGBuilder] = None) -> OffloadResult:
    """Algorithm 1: build tables -> build IDG trees -> partition/extract."""
    builder = builder or IDGBuilder(trace, rut, iht)
    flow = flow or build_flow_index(trace, rut, iht)
    claimed: Set[int] = set()
    candidates: List[Candidate] = []

    # reverse order: outermost roots first => maximal composite extraction
    for seq in range(len(trace) - 1, -1, -1):
        inst = trace[seq]
        if inst.op not in cfg.cim_set or seq in claimed:
            continue
        tree = builder.create_tree(inst, cfg.cim_set, claimed=claimed,
                                   max_ops=cfg.max_tree_ops)
        if tree is None:
            continue
        cand = _try_accept(tree, flow, trace, cfg, claimed)
        if cand is None:
            # Fig. 5: the whole tree failed — try its child subtrees
            for kind, payload in tree.children:
                if kind == "node":
                    sub = _try_accept(payload, flow, trace, cfg, claimed)
                    if sub is not None:
                        candidates.append(sub)
                        claimed.update(sub.op_seqs)
                        claimed.update(sub.load_seqs)
                        claimed.update(sub.store_seqs)
            continue
        candidates.append(cand)
        claimed.update(cand.op_seqs)
        claimed.update(cand.load_seqs)
        claimed.update(cand.store_seqs)

    candidates.reverse()                     # report in program order
    return OffloadResult(candidates, claimed, flow, cfg)
