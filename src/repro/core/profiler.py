"""System profiler — the paper's modified-McPAT stage (§V-C).

Combines the application model (the CIQ from the trace VM), the reshaped
trace, the device/CiM array model (Table III / Fig. 11) and the host model
into whole-system energy + performance for the baseline (non-CiM) and the
CiM-enabled system, and emits the paper's reported metrics: energy
improvement, speedup, processor/cache contribution breakdown (Table VI) and
MACR (Fig. 13).
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.cache import CacheConfig, CacheHierarchy
from repro.core.columnar import ColumnarTrace
from repro.core.device_model import (DRAM_ACCESS_PJ, DRAM_LATENCY_CYCLES,
                                     TechModel, TECHS)
from repro.core.host_model import DEFAULT_HOST, HostModel
from repro.core.isa import (LEVELS, LEVEL_L2, LEVEL_MEM, OP_STORE, UNITS,
                            Trace)
from repro.core.offload import OffloadConfig, OffloadResult, select_candidates
from repro.core.reshape import ReshapedTrace, reshape
from repro.core.trace import TraceResult


@dataclasses.dataclass
class EnergyBreakdown:
    host_pipeline: float = 0.0          # pJ
    host_units: float = 0.0
    host_static: float = 0.0            # static/clock energy over the runtime
    cache: Dict[str, float] = dataclasses.field(default_factory=dict)
    cim: Dict[str, float] = dataclasses.field(default_factory=dict)
    dram: float = 0.0

    @property
    def processor(self) -> float:
        return self.host_pipeline + self.host_units + self.host_static

    @property
    def caches(self) -> float:
        return sum(self.cache.values()) + sum(self.cim.values())

    @property
    def total(self) -> float:
        """Paper scope (SVI-B): 'total energy including both host CPU and
        cache' — main-memory energy is reported separately in `dram`."""
        return self.processor + self.caches

    @property
    def total_with_dram(self) -> float:
        return self.total + self.dram


@dataclasses.dataclass
class SystemReport:
    """Everything Table VI / Figs. 12-16 need for one (program, config)."""
    base: EnergyBreakdown
    cim: EnergyBreakdown
    base_cycles: float
    cim_cycles: float
    macr: float
    macr_l1: float
    macr_other: float
    n_instructions: int
    n_mem_accesses: int
    n_candidates: int
    n_cim_ops: int
    n_offloaded: int
    tech: str

    @property
    def energy_improvement(self) -> float:
        return self.base.total / max(self.cim.total, 1e-9)

    @property
    def speedup(self) -> float:
        return self.base_cycles / max(self.cim_cycles, 1e-9)

    @property
    def processor_ratio(self) -> float:
        """Table VI row 4: share of the energy delta from the processor."""
        delta = self.base.total - self.cim.total
        if abs(delta) < 1e-12:
            return 0.0
        return (self.base.processor - self.cim.processor) / delta

    @property
    def cache_ratio(self) -> float:
        """Table VI row 5 (can be negative: CiM ops cost more than the
        array accesses they replace)."""
        delta = self.base.total - self.cim.total
        if abs(delta) < 1e-12:
            return 0.0
        return ((self.base.caches + self.base.dram)
                - (self.cim.caches + self.cim.dram)) / delta

    @property
    def cim_favorable(self) -> bool:
        """Paper §VI-C: MACR >= ~50% indicates a CiM-favorable program."""
        return self.macr >= 0.5

    def summary(self) -> Dict[str, float]:
        return {
            "energy_improvement": round(self.energy_improvement, 3),
            "speedup": round(self.speedup, 3),
            "macr": round(self.macr, 4),
            "processor_ratio": round(self.processor_ratio, 3),
            "cache_ratio": round(self.cache_ratio, 3),
            "base_energy_nj": round(self.base.total / 1e3, 3),
            "cim_energy_nj": round(self.cim.total / 1e3, 3),
            "n_instructions": self.n_instructions,
            "n_cim_ops": self.n_cim_ops,
        }


class Profiler:
    def __init__(self, cache_levels: Tuple[CacheConfig, ...],
                 tech: str = "sram", host: HostModel = DEFAULT_HOST):
        self.levels = {c.name: c for c in cache_levels}
        self.tech_name = tech
        self.tech: TechModel = TECHS[tech]
        self.host = host

    # ----------------------------------------------------- per-access costs
    def _access_energy(self, level: str, is_write: bool) -> float:
        """Array energy for one host access served at ``level``.

        Every access probes L1; deeper services add the deeper array and —
        for DRAM — the line transfer.  (Fill writes are folded into the
        service-level access; documented surrogate.)
        """
        op = "write" if is_write else "read"
        e = self.tech.energy(op, self.levels["L1"])
        if level in ("L2", "MEM") and "L2" in self.levels:
            e += self.tech.energy(op, self.levels["L2"])
        if level == "MEM":
            e += DRAM_ACCESS_PJ
        return e

    # -------------------------------------------- vectorized accumulation
    def _price_host_columns(self, eb: EnergyBreakdown, unit_counts,
                            mem_counts) -> float:
        """Shared host-side pricing from per-unit / per-(level, rw) counts.

        ``unit_counts`` is a bincount over functional-unit codes;
        ``mem_counts`` maps (level code, is_write) -> accesses.  One
        multiply per distinct (unit | level x r/w) bucket replaces the
        per-instruction loop — same constants, same totals.
        """
        host = self.host
        n = int(unit_counts.sum())
        eb.host_pipeline += n * host.pipeline_pj
        unit_pj = host.unit_pj
        for code, cnt in enumerate(unit_counts.tolist()):
            if cnt:
                eb.host_units += cnt * unit_pj.get(UNITS[code], 15.0)
        cycles = n * host.base_cpi
        for (lvl_code, is_wr), cnt in mem_counts.items():
            level = LEVELS[lvl_code]
            e = self._access_energy(level, bool(is_wr))
            if lvl_code == LEVEL_MEM:
                eb.dram += cnt * DRAM_ACCESS_PJ
                e -= DRAM_ACCESS_PJ
                cycles += cnt * host.mem_stall * host.overlap
            elif lvl_code == LEVEL_L2:
                cycles += cnt * host.l2_stall * host.overlap
            key = level if level != "MEM" else "L2" \
                if "L2" in self.levels else "L1"
            eb.cache[key] = eb.cache.get(key, 0.0) + cnt * e
        return cycles

    @staticmethod
    def _mem_counts(level_col, is_store_col) -> Dict[Tuple[int, int], int]:
        """(level code, is_write) -> count over the memory instructions."""
        mem = level_col > 0
        if not mem.any():
            return {}
        combo = level_col[mem].astype(np.int64) * 2 \
            + is_store_col[mem].astype(np.int64)
        counts = np.bincount(combo)
        return {(int(c) // 2, int(c) % 2): int(n)
                for c, n in enumerate(counts) if n}

    # ------------------------------------------------------------ baseline
    def price_baseline(self, trace: Trace) -> Tuple[EnergyBreakdown, float]:
        eb = EnergyBreakdown()
        if isinstance(trace, ColumnarTrace):
            unit_counts = np.bincount(trace.unit, minlength=len(UNITS))
            mem_counts = self._mem_counts(trace.level, trace.op == OP_STORE)
            cycles = self._price_host_columns(eb, unit_counts, mem_counts)
            eb.host_static = self.host.static_pj_per_cycle * cycles
            return eb, cycles
        cycles = 0.0
        for inst in trace:
            eb.host_pipeline += self.host.pipeline_pj
            eb.host_units += self.host.unit_pj.get(inst.unit, 15.0)
            if inst.is_mem:
                e = self._access_energy(inst.level, inst.is_store)
                if inst.level == "MEM":
                    eb.dram += DRAM_ACCESS_PJ
                    e -= DRAM_ACCESS_PJ
                key = inst.level if inst.level != "MEM" else "L2" \
                    if "L2" in self.levels else "L1"
                eb.cache[key] = eb.cache.get(key, 0.0) + e
            cycles += self.host.inst_cycles(inst)
        eb.host_static = self.host.static_pj_per_cycle * cycles
        return eb, cycles

    # ------------------------------------------------------------ CiM run
    def price_cim(self, trace: Trace, reshaped: ReshapedTrace
                  ) -> Tuple[EnergyBreakdown, float]:
        eb = EnergyBreakdown()
        if isinstance(trace, ColumnarTrace):
            hs = np.asarray(reshaped.host_seqs, np.int64)
            unit_counts = (np.bincount(trace.unit[hs], minlength=len(UNITS))
                           if len(hs) else np.zeros(len(UNITS), np.int64))
            mem_counts = (self._mem_counts(trace.level[hs],
                                           trace.op[hs] == OP_STORE)
                          if len(hs) else {})
            cycles = self._price_host_columns(eb, unit_counts, mem_counts)
        else:
            cycles = 0.0
            for seq in reshaped.host_seqs:
                inst = trace[seq]
                eb.host_pipeline += self.host.pipeline_pj
                eb.host_units += self.host.unit_pj.get(inst.unit, 15.0)
                if inst.is_mem:
                    e = self._access_energy(inst.level, inst.is_store)
                    if inst.level == "MEM":
                        eb.dram += DRAM_ACCESS_PJ
                        e -= DRAM_ACCESS_PJ
                    key = inst.level if inst.level != "MEM" else "L2" \
                        if "L2" in self.levels else "L1"
                    eb.cache[key] = eb.cache.get(key, 0.0) + e
                cycles += self.host.inst_cycles(inst)

        l1_read_lat = self.tech.latency("read", "L1")
        # one CiM macro-instruction issued/committed by the host per
        # candidate; the array pipelines its op sequence back-to-back.
        # Aggregated: host issue cost per group, array energy/occupancy per
        # (level, op class) bucket — the counts replace the per-op loop.
        n_groups = len(reshaped.cim_groups)
        eb.host_pipeline += n_groups * self.host.pipeline_pj
        cycles += n_groups * self.host.base_cpi
        cls_counts: Counter = Counter()
        for grp in reshaped.cim_groups:
            for cls in grp.op_classes:
                cls_counts[(grp.level, cls)] += 1
        for (level, cls), cnt in cls_counts.items():
            lvl_cfg = self.levels[level]
            eb.cim[level] = eb.cim.get(level, 0.0) + \
                cnt * self.tech.energy(cls, lvl_cfg)
            lat = self.tech.latency(cls, level)
            cycles += cnt * (self.host.cim_occupancy +
                             self.host.cim_overlap
                             * max(0.0, lat - l1_read_lat))

        for level, n in reshaped.moves.items():          # cross-level writebacks
            cfg = self.levels[level]
            eb.cim[level] = eb.cim.get(level, 0.0) + n * self.tech.energy("write", cfg)
            cycles += n * self.host.overlap * self.tech.latency("write", level)
        for level, n in reshaped.internal_moves.items():  # in-bank merges
            cfg = self.levels[level]
            eb.cim[level] = eb.cim.get(level, 0.0) + n * self.tech.energy("CiM-OR", cfg)
            cycles += n * self.host.overlap
        # DRAM fills survive offloading: the operand's line still has to
        # reach the CiM-capable array (same fill as the baseline's miss path)
        if reshaped.dram_fills:
            n = reshaped.dram_fills
            eb.dram += n * DRAM_ACCESS_PJ
            fill_level = "L2" if "L2" in self.levels else "L1"
            eb.cache[fill_level] = eb.cache.get(fill_level, 0.0) + \
                n * self.tech.energy("write", self.levels[fill_level])
            cycles += n * self.host.mem_stall * self.host.overlap
        for level, n in reshaped.added_loads.items():     # re-materialized reads
            eb.host_pipeline += n * self.host.pipeline_pj
            eb.host_units += n * self.host.unit_pj.get("MemRead", 20.0)
            eb.cache[level] = eb.cache.get(level, 0.0) + \
                n * self._access_energy(level, False)
            cycles += n * (self.host.base_cpi +
                           (self.host.l2_stall * self.host.overlap
                            if level == "L2" else 0.0))
        eb.host_static = self.host.static_pj_per_cycle * cycles
        return eb, cycles


# ======================================================================
# One-call pipeline: trace -> select -> reshape -> profile
# ======================================================================
def profile_system(tr: TraceResult,
                   offload_cfg: OffloadConfig = OffloadConfig(),
                   tech: str = "sram",
                   host: HostModel = DEFAULT_HOST,
                   offload: Optional[OffloadResult] = None,
                   reshaped: Optional[ReshapedTrace] = None) -> SystemReport:
    """Price one (program, configuration) pair.

    ``offload`` / ``reshaped`` let callers reuse the config-independent
    analysis artifacts (see :func:`repro.core.offload.analyze_trace` and the
    sweep engine in :mod:`repro.dse`): passing them skips candidate
    selection and trace reshaping, leaving only the cheap pricing phase.
    """
    trace = tr.trace
    cache_cfgs = tuple(lv.cfg for lv in tr.cache.levels)
    if offload is not None:
        result = offload
    elif isinstance(trace, ColumnarTrace):
        # columnar traces carry their own derived tables — never force the
        # legacy RUT/IHT dict views just to pass them through
        result = select_candidates(trace, cfg=offload_cfg)
    else:
        result = select_candidates(trace, tr.rut, tr.iht, offload_cfg)
    reshaped = reshaped or reshape(trace, result)
    prof = Profiler(cache_cfgs, tech=tech, host=host)
    base_eb, base_cycles = prof.price_baseline(trace)
    cim_eb, cim_cycles = prof.price_cim(trace, reshaped)
    mb = result.macr_breakdown(trace)
    return SystemReport(
        base=base_eb, cim=cim_eb,
        base_cycles=base_cycles, cim_cycles=cim_cycles,
        macr=mb["macr"], macr_l1=mb["l1"], macr_other=mb["other"],
        n_instructions=len(trace),
        n_mem_accesses=int(mb["total_accesses"]),
        n_candidates=len(result.candidates),
        n_cim_ops=reshaped.n_cim_ops,
        n_offloaded=reshaped.n_offloaded,
        tech=tech,
    )
