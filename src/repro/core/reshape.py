"""Trace reshaping (paper §IV-C): turn CIQ + accepted candidates into the
profiling-ready instruction mix.

All offloaded host instructions (loads, OP nodes, and the stores absorbed
into CiM writes) leave the host pipeline; each candidate contributes:

  * one CiM operation per OP node, allocated at the cache level where the
    operands reside (`Candidate.level`),
  * `moves` write-backs for operands that lived at a shallower level
    ("write the operand at the higher-level cache back to the lower-level
    cache, and forward its operator to the same level"),
  * `internal_edges` in-bank data moves for dependent subtrees merged from
    the same IDG tree (post-order combine, Fig. 5c),
  * `added_loads` fresh host loads for values whose register consumers
    survive outside the candidate (the value now lives only in the array).

The reshaped trace keeps host instructions as (index-into-CIQ) references —
no copying — and materializes CiM ops as compact records.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.core.columnar import ColumnarTrace
from repro.core.isa import Inst, Trace
from repro.core.offload import Candidate, OffloadResult


@dataclasses.dataclass(frozen=True)
class CimGroup:
    """One reshaped candidate == ONE host-issued CiM macro-instruction
    ([35]-style PIM-enabled instruction; the paper's post-order combine
    merges dependent subtrees into 'one in-cache operation').  The array
    then executes ``op_classes`` back-to-back without host involvement."""
    level: str                         # "L1" | "L2"
    op_classes: Tuple[str, ...]        # Table III pricing class per array op


@dataclasses.dataclass
class ReshapedTrace:
    host_seqs: List[int]               # surviving host instructions (CIQ idx)
    cim_groups: List[CimGroup]
    moves: Dict[str, int]              # level -> cross-level writebacks
    internal_moves: Dict[str, int]     # level -> in-bank merge moves
    added_loads: Dict[str, int]        # level -> synthetic host loads
    dram_fills: int                    # line fills from DRAM kept in both runs
    n_offloaded: int                   # host instructions removed

    @property
    def n_cim_ops(self) -> int:
        return sum(len(g.op_classes) for g in self.cim_groups)

    # ``host_seqs`` is most of the trace — a pickled list of Python ints is
    # ~10x the bytes of the packed array (the persistent layer-2 store and
    # process-pool transfers both ship these)
    def __getstate__(self):
        state = self.__dict__.copy()
        state["host_seqs"] = np.asarray(self.host_seqs, np.int32)
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self.host_seqs = state["host_seqs"].tolist()


def reshape(trace: Trace, result: OffloadResult) -> ReshapedTrace:
    claimed = result.claimed
    if isinstance(trace, ColumnarTrace):
        # surviving host instructions without materializing a single row
        mask = np.ones(len(trace), bool)
        if claimed:
            mask[np.fromiter(claimed, np.int64, len(claimed))] = False
        host_seqs = np.flatnonzero(mask).tolist()
    else:
        host_seqs = [i.seq for i in trace if i.seq not in claimed]
    groups: List[CimGroup] = []
    moves: Dict[str, int] = {}
    internal: Dict[str, int] = {}
    added: Dict[str, int] = {}
    dram_fills = 0
    # post-order is trace order here: candidates are reported in program
    # order and each candidate's ops execute where its data lives.
    for c in result.candidates:
        groups.append(CimGroup(c.level, tuple(c.op_classes)))
        if c.moves:
            moves[c.level] = moves.get(c.level, 0) + c.moves
        if c.internal_edges:
            internal[c.level] = internal.get(c.level, 0) + c.internal_edges
        if c.added_loads:
            added[c.level] = added.get(c.level, 0) + c.added_loads
        dram_fills += c.dram_fills
    return ReshapedTrace(host_seqs, groups, moves, internal, added,
                         dram_fills=dram_fills, n_offloaded=len(claimed))
