"""§Roofline report builder: reads the dry-run JSON artifacts and derives
the per-(arch x shape x mesh) three-term roofline table for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES
from repro.core.tpu_model import (RooflineTerms, TpuChip, V5E, model_flops,
                                  roofline_terms, step_energy_pj)

ART = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"


def analytic_bytes_per_device(cfg, shape, n_dev: int, mp: int = 16) -> float:
    """Fusion-ideal HBM traffic per device per step (lower bound).

    The HLO-derived ``bytes_scaled`` is a NO-fusion upper bound (CPU-backend
    HLO keeps every intermediate); real TPU executables fuse elementwise
    chains, so the §Roofline memory term uses this analytic minimum:
    parameter/optimizer traffic (sharded: params over the model axis,
    ZeRO-1 optimizer over all devices) + activation residuals + logits +
    KV/state traffic.  Both bounds are reported.
    """
    dp = max(n_dev // mp, 1)
    P = cfg.param_count()
    Pa = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    L, d, V = cfg.n_layers, cfg.d_model, cfg.padded_vocab
    kv_dim = cfg.kv_dim

    if shape.kind == "train":
        # fwd read + bwd read + remat re-read (bf16) + grad write/read (f32)
        param_traffic = (2 + 2 + 2) * Pa + 8 * Pa
        param_traffic /= mp                        # params sharded over model
        opt_traffic = (16 + 2) * P / n_dev          # ZeRO-1: m,v rw + update
        acts = 12.0 * L * B * S * d / n_dev         # block-remat residuals
        logits = 2 * 2.0 * B * S * V / n_dev        # fwd + bwd
        return param_traffic + opt_traffic + acts + logits
    if shape.kind == "prefill":
        param_traffic = 2 * Pa / mp
        acts = 4.0 * L * B * S * d / n_dev
        kv = 2 * 2.0 * L * B * S * kv_dim / n_dev   # cache write
        logits = 2.0 * B * S * V / n_dev
        return param_traffic + acts + kv + logits
    # decode: every token reads all (active) params + the live context
    param_traffic = 2 * Pa / mp
    if shape.name == "long_500k" and cfg.supports_long_decode:
        window = cfg.sliding_window or 2048
        ctx = min(S, window)
        state = 0.0
        if cfg.has_ssm_state:
            ssm = cfg.ssm
            state = 4.0 * L * B * ssm.n_heads * ssm.head_dim * max(ssm.d_state, ssm.head_dim)
        kv = 2 * 2.0 * L * B * ctx * kv_dim + state
    else:
        kv = 2 * 2.0 * L * B * S * kv_dim
    logits = 2.0 * B * V
    return param_traffic + (kv + logits) / n_dev


def load_cell(arch: str, shape: str, mesh: str = "single") -> Optional[dict]:
    p = ART / mesh / f"{arch}__{shape}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def cell_roofline(rec: dict, chip: TpuChip = V5E) -> Optional[Dict]:
    """One roofline row from one dry-run artifact."""
    if not rec.get("ok"):
        return None
    n_dev = rec["n_devices"]
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    # prefer the trip-count-aware static analysis (cost_analysis counts
    # scan bodies once — see core/hlo_cost.py)
    flops = rec.get("flops_scaled_per_device") or rec["flops_per_device"]
    nofusion_bytes = (rec.get("bytes_scaled_per_device")
                      or rec["bytes_accessed_per_device"])
    mp = 16 if n_dev % 16 == 0 else 1
    fused_bytes = analytic_bytes_per_device(cfg, shape, n_dev, mp=mp)
    coll = rec.get("collective_scaled_total") or \
        rec.get("collectives", {}).get("total", 0)
    terms = roofline_terms(flops, fused_bytes, coll, n_dev, chip)
    kind = "train" if shape.kind == "train" else "serve"
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
    else:                                   # decode: one new token per seq
        tokens = shape.global_batch
    n_params = rec.get("active_params") or cfg.active_param_count()
    mf = model_flops(n_params, tokens, "train" if kind == "train" else "serve")
    mf_per_dev = mf / n_dev
    useful = mf_per_dev / flops if flops and flops > 0 else 0.0
    energy = step_energy_pj(flops, fused_bytes, coll, n_dev, chip)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        **terms.as_dict(),
        "memory_s_nofusion": nofusion_bytes / chip.hbm_bw,
        "model_flops_per_dev": mf_per_dev,
        "hlo_flops_per_dev": flops,
        "useful_compute_ratio": round(useful, 4),
        "hbm_bytes_per_dev": fused_bytes,
        "hbm_bytes_nofusion_per_dev": nofusion_bytes,
        "collective_bytes_per_dev": coll,
        "energy_j": round(energy["total_pj"] * 1e-12, 4),
        "n_devices": n_dev,
    }


def full_table(mesh: str = "single") -> List[Dict]:
    rows = []
    d = ART / mesh
    if not d.exists():
        return rows
    for p in sorted(d.glob("*.json")):
        rec = json.loads(p.read_text())
        row = cell_roofline(rec)
        if row is not None:
            rows.append(row)
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | useful/HLO | roofline frac |")
    sep = "|" + "---|" * 8
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['useful_compute_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(out)
