"""Stratified interval sampling of trace analysis (ROADMAP: "statistical
trace sampling").

Split a program's virtual instruction stream into fixed intervals, cluster
them by cheap structural features (SimPoint-style phases, or contiguous
strata), trace/replay/select/price only representative windows, and expand
back to whole-program metrics with bootstrap error bars.  See
:mod:`repro.core.sampling.spec` for the knob set and
``docs/architecture.md`` ("Statistical sampling") for the estimator math.
"""
from repro.core.sampling.cluster import SamplePlan, build_plan
from repro.core.sampling.estimate import (SampledEstimate, estimate,
                                          estimate_reports,
                                          window_components)
from repro.core.sampling.machines import (SamplingInterpreter, SkimMachine,
                                          SkimResult, WindowedMachine,
                                          WindowedTrace, skim_program,
                                          trace_windows)
from repro.core.sampling.pipeline import (SampledAnalysis, SampledStructural,
                                          attach_sampled, build_workload,
                                          price_sampled, sampled_report,
                                          sampled_structural, select_sampled,
                                          slice_columns)
from repro.core.sampling.spec import SAMPLING_VERSION, SamplingSpec

__all__ = [
    "SAMPLING_VERSION", "SamplingSpec", "SamplePlan", "build_plan",
    "SampledEstimate", "estimate", "estimate_reports", "window_components",
    "SamplingInterpreter", "SkimMachine", "SkimResult", "WindowedMachine",
    "WindowedTrace", "skim_program", "trace_windows",
    "SampledAnalysis", "SampledStructural", "attach_sampled",
    "build_workload", "price_sampled", "sampled_report",
    "sampled_structural", "select_sampled", "slice_columns",
]
