"""Interval clustering: SimPoint-style phases or contiguous strata.

Turns a skim pass (:class:`~repro.core.sampling.machines.SkimResult`) into
a :class:`SamplePlan`: every interval assigned to a cluster, ``budget``
representative windows picked across clusters proportionally to cluster
size (each non-empty cluster gets at least one), picks drawn uniformly
without replacement inside their cluster.  The estimator then weighs each
sampled window by ``L_c / m_c`` (intervals in its cluster over windows
sampled from it) — the classic stratified expansion estimator.

``phase`` mode runs a small numpy k-means (k-means++ init, deterministic
under the spec's seed) over row-normalized feature vectors; ``stratified``
mode skips the features entirely and uses contiguous equal strata, which
is both the fallback when phases are degenerate and the mode whose
unbiasedness the property tests pin down.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.sampling.machines import SkimResult
from repro.core.sampling.spec import SamplingSpec


@dataclasses.dataclass
class SamplePlan:
    """Which windows to trace, and how to weigh them back up."""
    interval: int
    total_virtual: int
    mode: str
    cluster_of: np.ndarray                  # [n_intervals] cluster id
    picks: Tuple[Tuple[int, int], ...]      # (interval idx, cluster), sorted
    #: budget covered every interval: one full window, weight 1 — the
    #: traced stream is byte-identical to exact mode (no cold windows)
    full: bool = False

    @property
    def n_intervals(self) -> int:
        return len(self.cluster_of)

    @property
    def n_windows(self) -> int:
        return 1 if self.full else len(self.picks)

    def windows(self) -> List[Tuple[int, int]]:
        """Virtual ``[lo, hi)`` ranges of the picked windows, in order."""
        if self.full:
            return [(0, self.total_virtual)]
        iv = self.interval
        return [(p * iv, min((p + 1) * iv, self.total_virtual))
                for p, _ in self.picks]

    def weights(self) -> np.ndarray:
        """Expansion weight per pick: ``L_c / m_c`` of its cluster."""
        if self.full:
            return np.ones(1)
        sizes = np.bincount(self.cluster_of)
        m = np.zeros_like(sizes)
        for _, c in self.picks:
            m[c] += 1
        return np.array([sizes[c] / m[c] for _, c in self.picks], float)

    def pick_clusters(self) -> np.ndarray:
        if self.full:
            return np.zeros(1, np.int64)
        return np.array([c for _, c in self.picks], np.int64)


# ----------------------------------------------------------------- k-means
def _kmeans(X: np.ndarray, k: int, rng: np.random.Generator,
            iters: int = 25) -> np.ndarray:
    n = X.shape[0]
    centers = np.empty((k, X.shape[1]))
    centers[0] = X[int(rng.integers(n))]
    d2 = ((X - centers[0]) ** 2).sum(1)
    for i in range(1, k):                       # k-means++ seeding
        s = d2.sum()
        idx = int(rng.choice(n, p=d2 / s)) if s > 0 else int(rng.integers(n))
        centers[i] = X[idx]
        d2 = np.minimum(d2, ((X - centers[i]) ** 2).sum(1))
    assign = np.zeros(n, np.int64)
    for _ in range(iters):
        dist = ((X[:, None, :] - centers[None]) ** 2).sum(2)
        assign = dist.argmin(1)
        moved = False
        for c in range(k):
            members = assign == c
            if members.any():
                new = X[members].mean(0)
            else:                               # reseed empty clusters
                new = X[int(rng.integers(n))]
            if not np.allclose(new, centers[c]):
                moved = True
            centers[c] = new
        if not moved:
            break
    return ((X[:, None, :] - centers[None]) ** 2).sum(2).argmin(1)


def _compact_labels(labels: np.ndarray) -> np.ndarray:
    """Relabel to dense 0..k'-1 (k-means can leave empty clusters)."""
    uniq = np.unique(labels)
    remap = np.zeros(labels.max() + 1, np.int64)
    remap[uniq] = np.arange(len(uniq))
    return remap[labels]


def _alloc_reps(sizes: np.ndarray, budget: int) -> np.ndarray:
    """Windows per cluster: proportional to size, >=1 each, capped at the
    cluster size, summing to <= budget (largest-remainder rounding)."""
    sizes = np.asarray(sizes, np.int64)
    k = len(sizes)
    raw = budget * sizes / sizes.sum()
    m = np.maximum(1, np.floor(raw).astype(np.int64))
    m = np.minimum(m, sizes)
    rem = budget - int(m.sum())
    if rem > 0:
        order = np.argsort(-(raw - np.floor(raw)))
        while rem > 0:
            grew = False
            for i in order:
                if rem <= 0:
                    break
                if m[i] < sizes[i]:
                    m[i] += 1
                    rem -= 1
                    grew = True
            if not grew:                        # every cluster saturated
                break
    return m


def build_plan(skim: SkimResult, spec: SamplingSpec) -> SamplePlan:
    """Cluster the skimmed intervals and pick the windows to trace."""
    if spec.is_exact:
        raise ValueError("exact mode has no sampling plan")
    n_int = skim.n_intervals
    if spec.budget >= n_int:
        # the budget covers every interval: trace one full window instead
        # of n_int cold ones — byte-identical to exact, zero estimator
        # error, and no window-boundary dependency truncation.  Sampling
        # proper only engages when the trace outgrows interval * budget.
        return SamplePlan(interval=skim.interval,
                          total_virtual=skim.total_virtual,
                          mode=spec.mode,
                          cluster_of=np.zeros(n_int, np.int64),
                          picks=((0, 0),), full=True)
    budget = min(spec.budget, n_int)
    rng = np.random.default_rng(spec.seed)

    if spec.mode == "phase" and n_int > 2:
        X = np.asarray(skim.features, float)
        norms = X.sum(1, keepdims=True)
        X = X / np.maximum(norms, 1.0)          # op-mix proportions
        k = max(1, min(budget, n_int, 64) // 2) or 1
        labels = _compact_labels(_kmeans(X, k, rng)) if k > 1 \
            else np.zeros(n_int, np.int64)
    else:                                       # stratified (and tiny inputs)
        k = max(1, min(budget // 2, n_int)) if budget > 1 else 1
        labels = np.minimum(np.arange(n_int) * k // n_int, k - 1)

    sizes = np.bincount(labels)
    reps = _alloc_reps(sizes, budget)
    picks: List[Tuple[int, int]] = []
    for c in range(len(sizes)):
        members = np.flatnonzero(labels == c)
        chosen = rng.choice(members, size=int(reps[c]), replace=False)
        picks.extend((int(i), int(c)) for i in chosen)
    picks.sort()
    return SamplePlan(interval=skim.interval,
                      total_virtual=skim.total_virtual,
                      mode=spec.mode, cluster_of=labels,
                      picks=tuple(picks))
