"""Cluster-weighted whole-program estimates with bootstrap error bars.

Every priced window ``j`` contributes a component vector ``y_j`` of the
*additive* quantities a :class:`~repro.core.profiler.SystemReport` is made
of (energies, cycles, covered/total access counts — never the ratios).
The whole-program total of each component is the stratified expansion

    T_hat = sum_c (L_c / m_c) * sum_{j in c} y_j

(cluster ``c`` holds ``L_c`` intervals, ``m_c`` of them sampled), and the
reported metrics are ratios of estimated totals — energy improvement
``T[base] / T[cim]``, MACR ``T[covered] / T[accesses]``, and so on.  This
is the textbook ratio-of-totals estimator: consistent, with O(1/n) bias
that the property tests bound empirically.

Error bars are bootstrap percentile intervals: windows are resampled with
replacement *within their cluster* (``n_boot`` times), the metric is
recomputed per resample, and the CI half-width at the spec's confidence
level is attached to the record.  Clusters with a single sampled window
contribute no variance to the bootstrap — a wider ``budget`` (>= 2 windows
per cluster) is what makes the error bars honest.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import numpy as np

from repro.core.profiler import SystemReport
from repro.core.sampling.cluster import SamplePlan
from repro.core.sampling.spec import SamplingSpec

#: the additive component vector (order is the contract between
#: :func:`window_components` and :func:`estimate`)
COMPONENTS = (
    "base_energy", "cim_energy",
    "base_processor", "cim_processor",
    "base_memory", "cim_memory",
    "base_cycles", "cim_cycles",
    "macr_covered", "macr_l1_covered",
    "mem_accesses", "n_instructions", "n_candidates", "n_cim_ops",
)
_I = {name: i for i, name in enumerate(COMPONENTS)}


def window_components(rep: SystemReport) -> np.ndarray:
    """One window's additive contribution vector."""
    mem = float(rep.n_mem_accesses)
    return np.array([
        rep.base.total, rep.cim.total,
        rep.base.processor, rep.cim.processor,
        rep.base.caches + rep.base.dram, rep.cim.caches + rep.cim.dram,
        rep.base_cycles, rep.cim_cycles,
        rep.macr * mem, rep.macr_l1 * mem,
        mem, float(rep.n_instructions),
        float(rep.n_candidates), float(rep.n_cim_ops),
    ])


def _metrics(t: np.ndarray) -> Dict[str, float]:
    delta = t[_I["base_energy"]] - t[_I["cim_energy"]]
    return {
        "energy_improvement":
            t[_I["base_energy"]] / max(t[_I["cim_energy"]], 1e-9),
        "speedup": t[_I["base_cycles"]] / max(t[_I["cim_cycles"]], 1e-9),
        "macr": t[_I["macr_covered"]] / max(t[_I["mem_accesses"]], 1e-9),
        "macr_l1":
            t[_I["macr_l1_covered"]] / max(t[_I["mem_accesses"]], 1e-9),
        "processor_ratio": 0.0 if abs(delta) < 1e-12 else
            (t[_I["base_processor"]] - t[_I["cim_processor"]]) / delta,
        "cache_ratio": 0.0 if abs(delta) < 1e-12 else
            (t[_I["base_memory"]] - t[_I["cim_memory"]]) / delta,
    }


@dataclasses.dataclass
class SampledEstimate:
    """Whole-program estimate: totals, headline metrics, and CI half-widths
    (bootstrap percentile, at the spec's confidence) for the three metrics
    the sweep records carry error bars for."""
    totals: Dict[str, float]
    metrics: Dict[str, float]
    ci: Dict[str, float]
    n_windows: int
    n_intervals: int

    def total(self, name: str) -> float:
        return self.totals[name]


def estimate(Y: np.ndarray, plan: SamplePlan,
             spec: SamplingSpec) -> SampledEstimate:
    """Estimate whole-program metrics from per-window components.

    ``Y``: ``[n_windows, len(COMPONENTS)]`` in plan pick order.
    """
    Y = np.asarray(Y, float)
    if Y.shape[0] != plan.n_windows:
        raise ValueError(f"{Y.shape[0]} component rows for "
                         f"{plan.n_windows} planned windows")
    w = plan.weights()
    totals_vec = (w[:, None] * Y).sum(0)
    metrics = _metrics(totals_vec)

    # bootstrap: resample windows with replacement within each cluster
    rng = np.random.default_rng(spec.seed + 0x5A11)
    clusters = plan.pick_clusters()
    sizes = np.bincount(plan.cluster_of)
    groups = [np.flatnonzero(clusters == c) for c in range(len(sizes))
              if (clusters == c).any()]
    boot = {"energy_improvement": [], "speedup": [], "macr": []}
    for _ in range(spec.n_boot):
        t = np.zeros(len(COMPONENTS))
        for g in groups:
            take = g if len(g) == 1 else rng.choice(g, size=len(g))
            t += (w[take][:, None] * Y[take]).sum(0)
        mb = _metrics(t)
        for k in boot:
            boot[k].append(mb[k])
    alpha = 1.0 - spec.confidence
    ci = {}
    for k, vals in boot.items():
        lo, hi = np.percentile(vals, [100 * alpha / 2,
                                      100 * (1 - alpha / 2)])
        ci[k] = float(hi - lo) / 2.0
    return SampledEstimate(
        totals={name: float(totals_vec[i])
                for i, name in enumerate(COMPONENTS)},
        metrics=metrics, ci=ci,
        n_windows=plan.n_windows, n_intervals=plan.n_intervals)


def estimate_reports(reports: Sequence[SystemReport], plan: SamplePlan,
                     spec: SamplingSpec) -> SampledEstimate:
    """Convenience: stack per-window reports and estimate."""
    return estimate(np.stack([window_components(r) for r in reports]),
                    plan, spec)
