"""Sampling modes of the trace VM: the structural skim and windowed traces.

Both run the ordinary :class:`~repro.core.trace.TraceInterpreter` program
walk (so control flow, loop-scoped buffer reuse, and concrete values are
exactly the exact-mode ones) but swap the machine underneath:

:class:`SkimMachine`
    Never emits an instruction.  Every array-shaped handler announces the
    exact number of *virtual* instructions its exact-mode emission loop
    would commit (the no-elision count — elision depends on register-file
    state that the skim deliberately does not model) and the machine
    consumes the whole span in O(1), accumulating per-interval structural
    feature rows (op-mix + dependency-depth histograms).  This is the
    ≥10x-cheaper feature pass that phase clustering runs on.

:class:`WindowedMachine`
    Emits only inside the sampled windows.  Spans that miss every window
    are skipped in O(1); spans that overlap one run the real per-element
    emission loop, gated per instruction.  Each window starts *cold*
    (register file cleared at entry — the standard sampled-simulation
    approximation), and the builder row range of every window is recorded
    in ``marks`` so the finished columnar trace can be sliced back into
    per-window traces.

The two machines share one virtual-instruction coordinate system (the
position in the no-elision instruction stream), which is what makes skim
intervals and traced windows line up.  :class:`SamplingInterpreter`'s
per-handler count formulas are asserted against the actual emission
whenever a span is emitted — formula drift fails loudly, not silently.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.isa import OPS, OP_CODE, OP_LOAD, OP_STORE, SRC_IMM, SRC_REG
from repro.core.trace import (Machine, StructuralTrace, TraceInterpreter,
                              TraceLimits, Value, _dtype_tag, _itemsize)

_OP_AGEN = OP_CODE["agen"]
_OP_BRANCH = OP_CODE["branch"]
_OP_MOV = OP_CODE["mov"]
_OP_CMP = OP_CODE["cmp"]
_OP_SEL = OP_CODE["sel"]
_OP_MUL = OP_CODE["mul"]
_OP_ADD = OP_CODE["add"]

#: dependency-depth histogram buckets (log2 of the accumulation chain
#: length, clipped) appended after the per-opcode columns
N_DEPTH = 8
N_FEATURES = len(OPS) + N_DEPTH


def _depth_col(depth: int) -> int:
    d = max(1, int(depth))
    return len(OPS) + min(N_DEPTH - 1, d.bit_length() - 1)


class _SamplingMachine(Machine):
    """Shared virtual-counter plumbing of the two sampling machines."""

    def __init__(self, n_regs: int = 24,
                 limits: TraceLimits = TraceLimits()):
        super().__init__(n_regs=n_regs, limits=limits, loop_overhead=True)
        self.virtual = 0          # position in the no-elision stream

    def span_total(self, k_ov: int, rows: int) -> int:
        """Virtual instructions of a span that emits ``rows`` payload rows
        plus ``k_ov`` loop-overhead agens (amortized branches included)."""
        c0 = self._ov_count
        return rows + k_ov + (c0 + k_ov) // self.UNROLL - c0 // self.UNROLL

    def take_bulk(self, total: int, k_ov: int,
                  ops: Tuple[Tuple[int, int], ...], loads: int, stores: int,
                  depth: int, depth_n: int) -> bool:
        """Offer a whole handler span; True = consumed in O(1), False =
        the caller must run the real emission loop."""
        raise NotImplementedError

    def span_inside(self, total: int) -> bool:
        """True if the next ``total`` virtual slots all lie inside an
        emitting window (the exact per-element loop is then both correct
        and cheap — every gate check passes)."""
        return False


# ======================================================================
# Skim
# ======================================================================
class SkimMachine(_SamplingMachine):
    """Feature-columns-only interpretation (no instruction is ever built)."""

    def __init__(self, interval: int, n_regs: int = 24):
        # virtual length is unbounded by the builder: lift the trace limit
        super().__init__(n_regs=n_regs,
                         limits=TraceLimits(max_instructions=1 << 62))
        self.interval = int(interval)
        self._feat = np.zeros((256, N_FEATURES))

    # ------------------------------------------------------------ features
    def _row(self, i: int) -> np.ndarray:
        f = self._feat
        if i >= f.shape[0]:
            grown = np.zeros((max(i + 1, f.shape[0] * 2), N_FEATURES))
            grown[:f.shape[0]] = f
            self._feat = f = grown
        return f[i]

    def features(self) -> np.ndarray:
        """Per-interval feature matrix ``[n_intervals, N_FEATURES]``."""
        n = max(1, -(-self.virtual // self.interval))
        self._row(n - 1)                         # ensure capacity
        return self._feat[:n].copy()

    def _tick(self, col: int) -> None:
        v = self.virtual
        self.virtual = v + 1
        self._row(v // self.interval)[col] += 1

    # ----------------------------------------------------------- bulk path
    def take_bulk(self, total, k_ov, ops, loads, stores, depth, depth_n):
        v0 = self.virtual
        self.virtual = v0 + total
        self._ov_count += k_ov
        if total <= 0:
            return True
        opsum = 0
        pairs = []
        for code, c in ops:
            if c:
                pairs.append((code, c))
                opsum += c
        if loads:
            pairs.append((OP_LOAD, loads))
        if stores:
            pairs.append((OP_STORE, stores))
        if k_ov:
            pairs.append((_OP_AGEN, k_ov))
        nbr = total - k_ov - loads - stores - opsum
        if nbr:
            pairs.append((_OP_BRANCH, nbr))
        iv = self.interval
        i0, i1 = v0 // iv, (v0 + total - 1) // iv
        if i0 == i1:
            row = self._row(i0)
            for col, c in pairs:
                row[col] += c
            if depth_n:
                row[_depth_col(depth)] += depth_n
            return True
        dcol = _depth_col(depth)
        for i in range(i0, i1 + 1):
            frac = (min(v0 + total, (i + 1) * iv) - max(v0, i * iv)) / total
            row = self._row(i)
            for col, c in pairs:
                row[col] += c * frac
            if depth_n:
                row[dcol] += depth_n * frac
        return True

    # ----------------------------------------------- per-emit fallback path
    # Handlers without a bulk formula (scatter, materialize) still run their
    # exact emission loops; these overrides keep the virtual counter and the
    # feature rows in step without ever touching the columnar builder.
    def emit_load(self, addr, tag, size):
        self._tick(OP_LOAD)
        return 0

    def emit_op(self, op, tag, srcs, dst=None):
        self._tick(OP_CODE[op])
        return 0 if dst is None else dst

    def emit_store(self, addr, reg, tag, size):
        self._tick(OP_STORE)

    def emit_branch(self):
        self._tick(_OP_BRANCH)

    def emit_loop_overhead(self):
        self._tick(_OP_AGEN)
        self._ov_count += 1
        if self._ov_count % self.UNROLL == 0:
            self.emit_branch()

    def emit_scalar(self, op, tag, invals, out_addr, osize):
        self.emit_loop_overhead()
        for v in invals:
            if v.addr is not None:
                self._tick(OP_LOAD)
        self._tick(OP_CODE[op])
        self._tick(OP_STORE)


# ======================================================================
# Windowed trace
# ======================================================================
class WindowedMachine(_SamplingMachine):
    """Emit only inside sampled windows of the virtual stream.

    ``bounds`` is the flattened, sorted window-boundary list
    ``[lo0, hi0, lo1, hi1, ...]`` (half-open, non-overlapping; adjacent
    windows may share a boundary — each crossing toggles).  ``marks``
    records ``[window_index, first_row, end_row]`` per entered window over
    the *builder* rows, so the finished trace slices back per window.
    """

    def __init__(self, bounds: Sequence[int], n_regs: int = 24,
                 limits: TraceLimits = TraceLimits()):
        super().__init__(n_regs=n_regs, limits=limits)
        self._bounds = list(map(int, bounds))
        self._bounds_arr = np.asarray(self._bounds, np.int64)
        self._bptr = 0
        self._inside = False
        self.marks: List[List[int]] = []

    # ----------------------------------------------------------- stepping
    def _cross(self, bp: int) -> None:
        if bp & 1:                           # crossed a hi: exiting
            self._inside = False
            self.marks[-1][2] = self.b.n
        else:                                # crossed a lo: entering
            self._inside = True
            lo = self._bounds[bp]
            if lo > 0 and (bp == 0 or self._bounds[bp - 1] < lo):
                # Entry after a *gap*: every register holds an unknown
                # value from the skipped stretch.  Poison bindings
                # (addresses no load ever asks for) keep the allocator in
                # its steady state — one LRU eviction per allocation —
                # instead of granting n_regs eviction-free allocations,
                # which would let the window's own bindings survive longer
                # than in the exact machine and elide loads the exact
                # trace emits.  Adjacent windows (shared boundary — e.g. a
                # warmup window flowing into its measured window) keep the
                # running state, and a window at virtual 0 is genuinely
                # cold, so the full-window trace stays byte-identical to
                # exact mode.
                self._reg_of_addr.clear()
                self._addr_of_reg.clear()
                self._free_regs = []
                self._rr = -1
                for r in range(self.n_regs):
                    self._reg_of_addr[-r - 1] = r
                    self._addr_of_reg[r] = -r - 1
            self.marks.append([bp // 2, self.b.n, -1])

    def _step(self) -> bool:
        """Advance the virtual counter one slot; True if it lies inside a
        window (crossing a boundary toggles, entering resets the register
        file — sampled windows start cold)."""
        v = self.virtual
        self.virtual = v + 1
        bounds = self._bounds
        bp = self._bptr
        while bp < len(bounds) and v >= bounds[bp]:
            self._cross(bp)
            bp += 1
        self._bptr = bp
        return self._inside

    def _sync(self) -> None:
        """Process boundary crossings a bulk jump passed over.  Jumps only
        ever span inactive stretches (no emission between the crossing and
        now), so the deferred mark row ``b.n`` is the one the crossing
        would have recorded."""
        v = self.virtual
        bounds = self._bounds
        bp = self._bptr
        while bp < len(bounds) and v >= bounds[bp]:
            self._cross(bp)
            bp += 1
        self._bptr = bp

    def finish_marks(self) -> List[Tuple[int, int, int]]:
        self._sync()
        if self.marks and self.marks[-1][2] == -1:
            self.marks[-1][2] = self.b.n
        return [tuple(m) for m in self.marks]

    # ----------------------------------------------------------- bulk path
    def take_bulk(self, total, k_ov, ops, loads, stores, depth, depth_n):
        self._sync()
        if self._inside:
            return False
        v = self.virtual
        bp = self._bptr
        if bp < len(self._bounds) and v + total > self._bounds[bp]:
            return False                     # span reaches the next window
        self.virtual = v + total
        self._ov_count += k_ov
        return True

    def span_inside(self, total):
        self._sync()
        return (self._inside and self._bptr < len(self._bounds)
                and self.virtual + total <= self._bounds[self._bptr])

    # -------------------------------------------------------- gated emits
    def emit_load(self, addr, tag, size):
        if self._step():
            return super().emit_load(addr, tag, size)
        return 0

    def emit_op(self, op, tag, srcs, dst=None):
        if self._step():
            return super().emit_op(op, tag, srcs, dst=dst)
        return 0 if dst is None else dst

    def emit_store(self, addr, reg, tag, size):
        if self._step():
            super().emit_store(addr, reg, tag, size)

    def emit_branch(self):
        if self._step():
            super().emit_branch()

    def emit_loop_overhead(self):
        if self._step():
            self.b.add(*self._ov_args)
            self._check_limit()
        self._ov_count += 1
        if self._ov_count % self.UNROLL == 0:
            self.emit_branch()

    def emit_scalar(self, op, tag, invals, out_addr, osize):
        # the exact machine inlines this sequence for speed; the windowed
        # machine re-expands it so every slot goes through the gate
        self.emit_loop_overhead()
        srcs = []
        for v in invals:
            if v.addr is None:
                srcs.append((SRC_IMM, v.data.item()))
            else:
                r = self.emit_load(v.addr.item(),
                                   _dtype_tag(v.data.dtype),
                                   _itemsize(v.data.dtype))
                srcs.append((SRC_REG, r))
        rd = self.emit_op(op, tag, srcs)
        self.emit_store(out_addr, rd, tag, osize)


def _active(bounds: np.ndarray, s: np.ndarray, e: np.ndarray) -> np.ndarray:
    """Which of the cells ``[s[i], e[i])`` overlap any window of the
    flattened boundary list (vectorized over all cells of a span)."""
    if len(bounds) == 0:
        return np.zeros(len(s), bool)
    p = np.searchsorted(bounds, s, side="right")
    inside = (p & 1) == 1
    nxt = bounds[np.minimum(p, len(bounds) - 1)]
    return inside | ((p < len(bounds)) & (nxt < e))


_noop = lambda *a: None  # noqa: E731


# ======================================================================
# Counting interpreter
# ======================================================================
class SamplingInterpreter(TraceInterpreter):
    """TraceInterpreter whose array handlers announce exact no-elision
    span counts up front (see module doc).  Handlers without a formula
    (scatter, materialize) degrade to the per-emit gated/skimmed path.

    Spans that only *partially* overlap a window never walk their whole
    Python emission loop: :meth:`_slice_nested` jumps straight to the
    overlapping elements through the span's affine virtual layout, so a
    3M-element span with one 2k window inside costs O(window), not
    O(span).  Spans under ``SLICE_MIN`` virtual slots just run the gated
    exact loop — identical bytes, bounded cost.
    """

    m: _SamplingMachine

    #: below this span length the gated exact loop beats slicing setup
    SLICE_MIN = 4096

    def _emit_checked(self, fn, total: int, what: str):
        m = self.m
        v0 = m.virtual
        out = fn()
        if m.virtual - v0 != total:
            raise AssertionError(
                f"sampling span drift in {what}: predicted {total} virtual "
                f"instructions, walked {m.virtual - v0} — count formula out "
                f"of sync with the exact emission loop")
        return out

    def _slice_nested(self, n_outer: int, prefix_rows: int, n_inner: int,
                      inner_rows: int, suffix_rows: int,
                      emit_prefix, emit_inner, emit_suffix) -> None:
        """Emit only the window-overlapping cells of a span laid out as
        ``n_outer`` × (prefix rows, ``n_inner`` × (overhead + inner rows),
        suffix rows).

        Cell and iteration start positions are affine in the indices (plus
        the amortized-branch correction), so inactive stretches are skipped
        by assigning ``virtual``/``_ov_count`` directly; the machine's
        deferred-crossing sync keeps window marks exact because skipped
        stretches never contain an emitting slot.
        """
        m = self.m
        U = m.UNROLL
        bounds = m._bounds_arr
        v0, c0 = m.virtual, m._ov_count
        cell_rows = prefix_rows + suffix_rows + n_inner * (inner_rows + 1)
        oi = np.arange(n_outer + 1, dtype=np.int64)
        os_ = v0 + oi * cell_rows + (c0 + oi * n_inner) // U - c0 // U
        act_o = _active(bounds, os_[:-1], os_[1:])
        ii = np.arange(n_inner + 1, dtype=np.int64)
        per_inner = inner_rows + 1
        for i in map(int, np.flatnonzero(act_o)):
            m.virtual = int(os_[i])
            m._ov_count = c0 + i * n_inner
            emit_prefix(i)
            vi, ci = m.virtual, m._ov_count
            is_ = vi + ii * per_inner + (ci + ii) // U - ci // U
            act_i = _active(bounds, is_[:-1], is_[1:])
            for j in map(int, np.flatnonzero(act_i)):
                m.virtual = int(is_[j])
                m._ov_count = ci + j
                emit_inner(i, j)
            m.virtual = int(is_[-1])
            m._ov_count = ci + n_inner
            emit_suffix(i)
        m.virtual = int(os_[-1])
        m._ov_count = c0 + n_outer * n_inner

    # ------------------------------------------------------- elementwise
    def _elementwise(self, op, invals, out_data):
        m = self.m
        out_data = np.asarray(out_data)
        n = out_data.size
        n_mem = 0
        for v in invals:
            if v.addr is not None:
                n_mem += 1
        total = m.span_total(n, n * (2 + n_mem))
        if m.take_bulk(total, n, ((OP_CODE[op], n),), n * n_mem, n, 1, n):
            return Value(out_data, m.alloc(out_data.shape, out_data.dtype))
        if total < self.SLICE_MIN or m.span_inside(total):
            return self._emit_checked(
                lambda: super(SamplingInterpreter, self)._elementwise(
                    op, invals, out_data), total, f"elementwise:{op}")
        out_addr = m.alloc(out_data.shape, out_data.dtype)
        tag = _dtype_tag(out_data.dtype)
        osize = _itemsize(out_data.dtype)
        srcs = []
        for v in invals:
            data = np.asarray(v.data)
            srcs.append((np.broadcast_to(data, out_data.shape),
                         None if v.addr is None
                         else np.broadcast_to(v.addr, out_data.shape),
                         _dtype_tag(data.dtype), _itemsize(data.dtype)))
        oa = out_addr.ravel()

        def inner(_, i):
            m.emit_loop_overhead()
            row = []
            for bd, ba, stag, ssize in srcs:
                if ba is None:
                    row.append((SRC_IMM, bd.flat[i].item()))
                else:
                    row.append((SRC_REG,
                                m.emit_load(int(ba.flat[i]), stag, ssize)))
            rd = m.emit_op(op, tag, row)
            m.emit_store(int(oa[i]), rd, tag, osize)

        def run():
            self._slice_nested(1, 0, n, 2 + n_mem, 0, _noop, inner, _noop)
            return Value(out_data, out_addr)
        return self._emit_checked(run, total, f"elementwise:{op}")

    # --------------------------------------------------------- reductions
    def _reduce(self, op, inval, axes, out_data, init_imm):
        m = self.m
        x = np.asarray(inval.data)
        red_n = 1
        for a in axes:
            red_n *= x.shape[a]
        r = x.size // max(1, red_n)
        has = inval.addr is not None
        total = m.span_total(r * red_n, r * (2 + red_n * (1 + has)))
        ops = ((_OP_MOV, r), (OP_CODE[op], r * red_n))
        if m.take_bulk(total, r * red_n, ops, r * red_n if has else 0, r,
                       red_n, r * red_n):
            out_data = np.asarray(out_data)
            return Value(out_data, m.alloc(out_data.shape, out_data.dtype))
        if total < self.SLICE_MIN or m.span_inside(total):
            return self._emit_checked(
                lambda: super(SamplingInterpreter, self)._reduce(
                    op, inval, axes, out_data, init_imm), total,
                f"reduce:{op}")
        out_data = np.asarray(out_data)
        tag = _dtype_tag(out_data.dtype)
        osize = _itemsize(out_data.dtype)
        ssize = _itemsize(x.dtype)
        keep = [a for a in range(x.ndim) if a not in axes]
        perm = keep + list(axes)
        xa2 = (np.transpose(inval.addr, perm).reshape(-1, red_n)
               if has else None)
        xd2 = np.transpose(x, perm).reshape(-1, red_n)
        out_addr = m.alloc(out_data.shape, out_data.dtype)
        oa = out_addr.ravel()
        acc = [0]

        def prefix(i):
            acc[0] = m.emit_op("mov", tag, ((SRC_IMM, init_imm),))

        def inner(i, j):
            m.emit_loop_overhead()
            if xa2 is None:
                src = (SRC_IMM, xd2[i, j].item())
            else:
                src = (SRC_REG, m.emit_load(int(xa2[i, j]), tag, ssize))
            acc[0] = m.emit_op(op, tag, ((SRC_REG, acc[0]), src), dst=acc[0])

        def suffix(i):
            m.emit_store(int(oa[i]), acc[0], tag, osize)

        def run():
            self._slice_nested(r, 1, red_n, 1 + has, 1,
                               prefix, inner, suffix)
            return Value(out_data, out_addr)
        return self._emit_checked(run, total, f"reduce:{op}")

    def _argreduce(self, cmp_np, inval, axis, out_data):
        m = self.m
        x = np.asarray(inval.data)
        red_n = x.shape[axis]
        r = x.size // max(1, red_n)
        has = inval.addr is not None
        inner = red_n - 1
        total = m.span_total(r * inner, r * (3 + 4 * inner))
        movs = r + (0 if has else r * red_n)
        ops = ((_OP_MOV, movs), (_OP_CMP, r * inner), (_OP_SEL, 2 * r * inner))
        if m.take_bulk(total, r * inner, ops, r * red_n if has else 0, r,
                       red_n, r * inner):
            out_data = np.asarray(out_data)
            return Value(out_data, m.alloc(out_data.shape, out_data.dtype))
        if total < self.SLICE_MIN or m.span_inside(total):
            return self._emit_checked(
                lambda: super(SamplingInterpreter, self)._argreduce(
                    cmp_np, inval, axis, out_data), total, "argreduce")
        out_data = np.asarray(out_data)
        perm = [a for a in range(x.ndim) if a != axis] + [axis]
        xa2 = (np.transpose(inval.addr, perm).reshape(-1, red_n)
               if has else None)
        xd2 = np.transpose(x, perm).reshape(-1, red_n)
        out_addr = m.alloc(out_data.shape, out_data.dtype)
        oa = out_addr.ravel()
        tag = _dtype_tag(x.dtype)
        ssize = _itemsize(x.dtype)
        osize = _itemsize(out_data.dtype)
        st = [0, 0]                          # best, bidx registers

        def prefix(i):
            st[0] = m.emit_op("mov", tag, ((SRC_IMM, xd2[i, 0].item()),)) \
                if xa2 is None else m.emit_load(int(xa2[i, 0]), tag, ssize)
            st[1] = m.emit_op("mov", "i", ((SRC_IMM, 0),))

        def inner_fn(i, jm1):
            j = jm1 + 1
            m.emit_loop_overhead()
            if xa2 is None:
                cur = m.emit_op("mov", tag, ((SRC_IMM, xd2[i, j].item()),))
            else:
                cur = m.emit_load(int(xa2[i, j]), tag, ssize)
            c = m.emit_op("cmp", tag, ((SRC_REG, cur), (SRC_REG, st[0])))
            st[0] = m.emit_op("sel", tag, ((SRC_REG, c), (SRC_REG, cur),
                                           (SRC_REG, st[0])), dst=st[0])
            st[1] = m.emit_op("sel", "i", ((SRC_REG, c), (SRC_IMM, j),
                                           (SRC_REG, st[1])), dst=st[1])

        def suffix(i):
            m.emit_store(int(oa[i]), st[1], "i", osize)

        def run():
            self._slice_nested(r, 2, inner, 4, 1, prefix, inner_fn, suffix)
            return Value(out_data, out_addr)
        return self._emit_checked(run, total, "argreduce")

    # -------------------------------------------------------- dot_general
    def _dot_general(self, a, b, dnums, out_data):
        m = self.m
        (lc, rc), (lb, rb) = dnums
        A, B = np.asarray(a.data), np.asarray(b.data)
        nb = 1
        for i in lb:
            nb *= A.shape[i]
        K = 1
        for i in lc:
            K *= A.shape[i]
        cells = 0 if A.size == 0 or B.size == 0 else \
            (A.size // (nb * K)) * (B.size // (nb * K)) * nb
        ka = 1 if a.addr is not None else 0
        kb = 1 if b.addr is not None else 0
        total = m.span_total(cells * K, cells * (2 + K * (2 + ka + kb)))
        ops = ((_OP_MOV, cells), (_OP_MUL, cells * K), (_OP_ADD, cells * K))
        if m.take_bulk(total, cells * K, ops, cells * K * (ka + kb), cells,
                       K, cells * K):
            out_data = np.asarray(out_data)
            return Value(out_data, m.alloc(out_data.shape, out_data.dtype))
        if total < self.SLICE_MIN or m.span_inside(total):
            return self._emit_checked(
                lambda: super(SamplingInterpreter, self)._dot_general(
                    a, b, dnums, out_data), total, "dot_general")

        def order(x, batch, contract):
            keep = [i for i in range(x.ndim) if i not in batch + contract]
            return list(batch) + keep + list(contract)

        pa, pb = order(A, tuple(lb), tuple(lc)), order(B, tuple(rb), tuple(rc))
        Mm = A.size // (nb * K)
        Nn = B.size // (nb * K)
        Ad3 = np.transpose(A, pa).reshape(nb, Mm, K)
        Bd3 = np.transpose(B, pb).reshape(nb, Nn, K)
        Aa3 = (np.transpose(a.addr, pa).reshape(nb, Mm, K) if ka else None)
        Ba3 = (np.transpose(b.addr, pb).reshape(nb, Nn, K) if kb else None)
        out_data = np.asarray(out_data)
        out_addr = m.alloc(out_data.shape, out_data.dtype)
        oa3 = out_addr.reshape(nb, Mm, Nn)
        tag = _dtype_tag(out_data.dtype)
        asz, bsz = _itemsize(A.dtype), _itemsize(B.dtype)
        osize = _itemsize(out_data.dtype)
        cur = {}
        acc = [0]

        def prefix(c):
            bi, rem = divmod(c, Mm * Nn)
            i, j = divmod(rem, Nn)
            cur["aa"] = Aa3[bi, i] if Aa3 is not None else None
            cur["ad"] = Ad3[bi, i]
            cur["ba"] = Ba3[bi, j] if Ba3 is not None else None
            cur["bd"] = Bd3[bi, j]
            cur["oa"] = int(oa3[bi, i, j])
            acc[0] = m.emit_op("mov", tag, ((SRC_IMM, 0),))

        def inner(c, k):
            m.emit_loop_overhead()
            aa, ba = cur["aa"], cur["ba"]
            sa = ((SRC_REG, m.emit_load(int(aa[k]), tag, asz))
                  if aa is not None else (SRC_IMM, cur["ad"][k].item()))
            sb = ((SRC_REG, m.emit_load(int(ba[k]), tag, bsz))
                  if ba is not None else (SRC_IMM, cur["bd"][k].item()))
            prod = m.emit_op("mul", tag, (sa, sb))
            acc[0] = m.emit_op("add", tag, ((SRC_REG, acc[0]),
                                            (SRC_REG, prod)), dst=acc[0])

        def suffix(c):
            m.emit_store(cur["oa"], acc[0], tag, osize)

        def run():
            self._slice_nested(cells, 1, K, 2 + ka + kb, 1,
                               prefix, inner, suffix)
            return Value(out_data, out_addr)
        return self._emit_checked(run, total, "dot_general")

    # ------------------------------------------------------- copy family
    def _copy_to_new_buffer(self, src, out_data):
        m = self.m
        out_data = np.asarray(out_data)
        n = out_data.size
        has = src.addr is not None
        total = m.span_total(n, 2 * n)
        ops = () if has else ((_OP_MOV, n),)
        if m.take_bulk(total, n, ops, n if has else 0, n, 1, n):
            return Value(out_data, m.alloc(out_data.shape, out_data.dtype))
        if total < self.SLICE_MIN or m.span_inside(total):
            return self._emit_checked(
                lambda: super(SamplingInterpreter, self)._copy_to_new_buffer(
                    src, out_data), total, "copy")
        out_addr = m.alloc(out_data.shape, out_data.dtype)
        tag = _dtype_tag(out_data.dtype)
        size = _itemsize(out_data.dtype)
        sa = src.addr.ravel() if has else None
        sd = np.asarray(src.data).ravel()
        oa = out_addr.ravel()

        def inner(_, i):
            m.emit_loop_overhead()
            if sa is None:
                r = m.emit_op("mov", tag, ((SRC_IMM, sd[i].item()),))
            else:
                r = m.emit_load(int(sa[i]), tag, size)
            m.emit_store(int(oa[i]), r, tag, size)

        def run():
            self._slice_nested(1, 0, n, 2, 0, _noop, inner, _noop)
            return Value(out_data, out_addr)
        return self._emit_checked(run, total, "copy")

    def _concat_copy(self, fake, out):
        m = self.m
        n = out.size
        n_imm = int((fake.addr.ravel() < 0).sum())
        total = m.span_total(n, 2 * n)
        if m.take_bulk(total, n, ((_OP_MOV, n_imm),), n - n_imm, n, 1, n):
            return Value(out, m.alloc(out.shape, out.dtype))
        if total < self.SLICE_MIN or m.span_inside(total):
            return self._emit_checked(
                lambda: super(SamplingInterpreter, self)._concat_copy(
                    fake, out), total, "concat")
        out_addr = m.alloc(out.shape, out.dtype)
        tag = _dtype_tag(out.dtype)
        size = _itemsize(out.dtype)
        sa = fake.addr.ravel()
        sd = out.ravel()
        oa = out_addr.ravel()

        def inner(_, i):
            m.emit_loop_overhead()
            if sa[i] < 0:
                r = m.emit_op("mov", tag, ((SRC_IMM, sd[i].item()),))
            else:
                r = m.emit_load(int(sa[i]), tag, size)
            m.emit_store(int(oa[i]), r, tag, size)

        def run():
            self._slice_nested(1, 0, n, 2, 0, _noop, inner, _noop)
            return Value(out, out_addr)
        return self._emit_checked(run, total, "concat")

    def _store_region(self, base, update, sl):
        m = self.m
        n = np.asarray(update.data).size
        has = update.addr is not None
        total = m.span_total(n, 2 * n)
        ops = () if has else ((_OP_MOV, n),)
        if m.take_bulk(total, n, ops, n if has else 0, n, 1, n):
            return None
        if total < self.SLICE_MIN or m.span_inside(total):
            return self._emit_checked(
                lambda: super(SamplingInterpreter, self)._store_region(
                    base, update, sl), total, "store_region")
        ud = np.asarray(update.data)
        tag = _dtype_tag(ud.dtype)
        size = _itemsize(ud.dtype)
        ua = update.addr.ravel() if has else None
        udf = ud.ravel()
        ta = base.addr[sl].ravel()

        def inner(_, i):
            m.emit_loop_overhead()
            if ua is None:
                r = m.emit_op("mov", tag, ((SRC_IMM, udf[i].item()),))
            else:
                r = m.emit_load(int(ua[i]), tag, size)
            m.emit_store(int(ta[i]), r, tag, size)

        def run():
            self._slice_nested(1, 0, n, 2, 0, _noop, inner, _noop)
        return self._emit_checked(run, total, "store_region")

    def _gather_pointer_chase(self, operand, out_data, gathered_addrs,
                              index_srcs):
        m = self.m
        out_data = np.asarray(out_data)
        n = out_data.size
        hi = 1 if (index_srcs is not None
                   and index_srcs.addr is not None) else 0
        total = m.span_total(n, n * (2 + 2 * hi))
        if m.take_bulk(total, n, ((_OP_AGEN, n * hi),), n * (1 + hi), n,
                       2, n):
            return Value(out_data, m.alloc(out_data.shape, out_data.dtype))
        if total < self.SLICE_MIN or m.span_inside(total):
            return self._emit_checked(
                lambda: super(
                    SamplingInterpreter, self)._gather_pointer_chase(
                        operand, out_data, gathered_addrs, index_srcs),
                total, "gather")
        out_addr = m.alloc(out_data.shape, out_data.dtype)
        tag = _dtype_tag(out_data.dtype)
        size = _itemsize(out_data.dtype)
        ia = (index_srcs.addr.ravel() if hi else None)
        id_flat = (np.asarray(index_srcs.data).ravel()
                   if index_srcs is not None else None)
        ga = gathered_addrs.ravel()
        oa = out_addr.ravel()
        n_idx = len(id_flat) if id_flat is not None else 0

        def inner(_, i):
            m.emit_loop_overhead()
            if ia is not None:
                ri = m.emit_load(int(ia[i % n_idx]), "i", 4)
                m.emit_op("agen", "i", ((SRC_REG, ri), (SRC_IMM, 0)))
            r = m.emit_load(int(ga[i]), tag, size)
            m.emit_store(int(oa[i]), r, tag, size)

        def run():
            self._slice_nested(1, 0, n, 2 + 2 * hi, 0, _noop, inner, _noop)
            return Value(out_data, out_addr)
        return self._emit_checked(run, total, "gather")


# ======================================================================
# Drivers
# ======================================================================
@dataclasses.dataclass
class SkimResult:
    """The feature pass: per-interval structural features + stream length."""
    features: np.ndarray       # [n_intervals, N_FEATURES]
    total_virtual: int
    interval: int

    @property
    def n_intervals(self) -> int:
        return self.features.shape[0]


@dataclasses.dataclass
class WindowedTrace:
    """The sampled emission pass: one columnar trace holding only the
    sampled windows, plus per-window builder row ranges."""
    structural: StructuralTrace
    marks: List[Tuple[int, int, int]]   # (window index, row lo, row hi)
    total_virtual: int


def skim_program(fn, *args, interval: int, n_regs: int = 24) -> SkimResult:
    """Run the feature-columns-only pass over ``fn(*args)``."""
    closed = jax.make_jaxpr(fn)(*args)
    m = SkimMachine(interval, n_regs=n_regs)
    interp = SamplingInterpreter(m)
    arg_vals = [m.store_const(np.asarray(a))
                for a in jax.tree_util.tree_leaves(args)]
    interp.run(closed.jaxpr, closed.consts, arg_vals)
    return SkimResult(features=m.features(), total_virtual=m.virtual,
                      interval=interval)


def trace_windows(fn, *args, windows: Sequence[Tuple[int, int]],
                  n_regs: int = 24,
                  limits: TraceLimits = TraceLimits(),
                  expect_total: Optional[int] = None) -> WindowedTrace:
    """Trace only the given ``[lo, hi)`` virtual windows of ``fn(*args)``.

    ``expect_total`` (the skim's ``total_virtual``) cross-checks that the
    two passes walked the same virtual stream.
    """
    bounds: List[int] = []
    for lo, hi in windows:
        if bounds and lo < bounds[-1]:
            raise ValueError("windows must be sorted and non-overlapping")
        bounds.extend((int(lo), int(hi)))
    closed = jax.make_jaxpr(fn)(*args)
    m = WindowedMachine(bounds, n_regs=n_regs, limits=limits)
    interp = SamplingInterpreter(m)
    arg_vals = [m.store_const(np.asarray(a))
                for a in jax.tree_util.tree_leaves(args)]
    outs = interp.run(closed.jaxpr, closed.consts, arg_vals)
    marks = m.finish_marks()
    if expect_total is not None and m.virtual != expect_total:
        raise AssertionError(
            f"windowed pass walked {m.virtual} virtual instructions, "
            f"skim walked {expect_total} — passes diverged")
    st = StructuralTrace(m.b.finish(m.n_regs),
                         [np.asarray(v.data) for v in outs])
    return WindowedTrace(structural=st, marks=marks, total_virtual=m.virtual)
