"""The sampled analysis pipeline: skim → plan → windows → replay → price.

Mirrors the exact pipeline's layering so the
:class:`~repro.dse.backends.CimBackend` can cache each piece at the right
granularity:

``sampled_structural``  (layer 1, geometry-independent, persisted)
    One skim pass for features + stream length, one plan, one windowed
    trace pass.  Serialized as plain arrays
    (:meth:`SampledStructural.to_payload`) so the store blob never pickles
    live trace objects.

``attach_sampled``  (layer 1, per geometry, memoized)
    ONE cache-hierarchy replay over the whole windowed trace in virtual
    order — windows warm each other exactly as their prefix would have
    (warm chaining) — then sliced back into per-window
    :class:`~repro.core.trace.TraceResult` views.

``select_sampled``  (layer 2, per offload config, memoized)
    Algorithm-1 selection + reshape per window.

``price_sampled``  (never cached)
    Per-window :func:`~repro.core.profiler.profile_system`, then the
    cluster-weighted estimator with bootstrap CIs
    (:mod:`repro.core.sampling.estimate`).

Workload names accept a ``name@scale`` suffix (``"KM@64"``) that routes to
``repro.workloads.build(name, scale)`` — how the benchmark builds the
>=10^6-instruction loop-scaled variants without touching the registry.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core.cache import CacheConfig, CacheHierarchy
from repro.core.columnar import ColumnarTrace
from repro.core.host_model import DEFAULT_HOST, HostModel
from repro.core.offload import OffloadConfig, OffloadResult, analyze_trace
from repro.core.profiler import profile_system
from repro.core.reshape import ReshapedTrace, reshape
from repro.core.sampling.cluster import SamplePlan, build_plan
from repro.core.sampling.estimate import (SampledEstimate, estimate_reports,
                                          window_components)
from repro.core.sampling.machines import (TraceLimits, skim_program,
                                          trace_windows)
from repro.core.sampling.spec import SamplingSpec
from repro.core.trace import OP_STORE, TraceResult


# --------------------------------------------------------------- workloads
def build_workload(name: str):
    """``repro.workloads.build`` with ``name@scale`` syntax support."""
    from repro.workloads import build
    base, _, scale = name.partition("@")
    return build(base, int(scale)) if scale else build(base)


# --------------------------------------------------------------- slicing
def slice_columns(ct: ColumnarTrace, lo: int, hi: int) -> ColumnarTrace:
    """Rows ``[lo, hi)`` as a standalone columnar trace (source CSR
    re-based; fresh ``_struct`` memo — derived tables of a window are not
    the full trace's)."""
    so = ct.src_off
    slo, shi = int(so[lo]), int(so[hi])
    return ColumnarTrace(
        hi - lo, ct.op[lo:hi], ct.unit[lo:hi], ct.dtype[lo:hi],
        ct.dst[lo:hi], ct.addr[lo:hi], ct.size[lo:hi], ct.level[lo:hi],
        ct.hit[lo:hi], ct.bank[lo:hi], ct.mshr[lo:hi],
        so[lo:hi + 1] - slo, ct.src_tag[slo:shi], ct.src_val[slo:shi],
        ct.src_kind[slo:shi], ct.n_regs)


# ----------------------------------------------------------- layer-1 pieces
@dataclasses.dataclass
class SampledStructural:
    """Geometry-independent sampled artifact: the plan plus the windowed
    structural trace (only picklable primitives — safe as a store blob)."""
    workload: str
    spec_key: str
    plan: SamplePlan
    columns: Dict[str, np.ndarray]          # windowed trace, to_arrays form
    marks: Tuple[Tuple[int, int, int], ...]  # (window, row lo, row hi)
    skim_rate: float                        # virtual instrs/s of the skim
    # Indices into ``marks`` that are *measured* windows, one per plan
    # pick in order; the rest are warmup prefixes (traced to prime the
    # register file and cache, never priced).  Empty = every mark is
    # measured (no warmup — e.g. the degenerate full-coverage plan).
    measured: Tuple[int, ...] = ()

    def trace(self) -> ColumnarTrace:
        return ColumnarTrace.from_arrays(self.columns)

    def measured_marks(self) -> Tuple[Tuple[int, int, int], ...]:
        if not self.measured:
            return self.marks
        return tuple(self.marks[i] for i in self.measured)


def sampled_structural(workload: str, spec: SamplingSpec) -> SampledStructural:
    """Skim + plan + windowed trace for one workload (the expensive,
    geometry-independent pass of sampled analysis)."""
    import time
    fn, args = build_workload(workload)
    with obs.span("sampling.skim", cat="sampling", workload=workload,
                  interval=spec.interval) as sp:
        t0 = time.perf_counter()
        skim = skim_program(fn, *args, interval=spec.interval)
        dt = time.perf_counter() - t0
        rate = skim.total_virtual / max(dt, 1e-9)
        sp.set(virtual=skim.total_virtual, intervals=skim.n_intervals,
               rate=int(rate))
    plan = build_plan(skim, spec)
    # Interleave a warmup prefix [lo - warmup, lo) before each measured
    # window (clamped so it never overlaps the previous window): the
    # windowed machine flows register/cache state across the shared
    # boundary, so the measured window starts with a primed register file
    # instead of a cold one (SMARTS-style detailed warmup).  The full
    # coverage plan is one window from virtual 0 and needs none.
    warm = 0 if plan.full else spec.warmup
    traced: List[Tuple[int, int]] = []
    measured: List[int] = []
    prev_hi = 0
    for lo, hi in plan.windows():
        wlo = max(prev_hi, lo - warm)
        if wlo < lo:
            traced.append((wlo, lo))
        measured.append(len(traced))
        traced.append((lo, hi))
        prev_hi = hi
    with obs.span("sampling.windows", cat="sampling", workload=workload,
                  n_windows=plan.n_windows) as sp:
        wt = trace_windows(fn, *args, windows=traced,
                           limits=TraceLimits(max_instructions=1 << 62),
                           expect_total=skim.total_virtual)
        sp.set(rows=wt.structural.n_instructions,
               warm_windows=len(traced) - len(measured))
    return SampledStructural(
        workload=workload, spec_key=spec.key(), plan=plan,
        columns=wt.structural.columns.to_arrays(),
        marks=tuple(tuple(m) for m in wt.marks), skim_rate=rate,
        measured=tuple(measured) if len(traced) > len(measured) else ())


@dataclasses.dataclass
class SampledAnalysis:
    """Per-geometry sampled artifact: the warm-chained replayed windowed
    trace sliced into per-window results (shared hierarchy for pricing)."""
    structural: SampledStructural
    windows: List[TraceResult]              # one per plan pick, in order
    cache: CacheHierarchy

    @property
    def plan(self) -> SamplePlan:
        return self.structural.plan


def attach_sampled(ss: SampledStructural,
                   cache_levels: Tuple[CacheConfig, ...]) -> SampledAnalysis:
    """Replay the whole windowed trace through one hierarchy (windows warm
    each other in virtual order), then slice per window."""
    ct = ss.trace()
    with obs.span("sampling.replay", cat="sampling", workload=ss.workload,
                  n_windows=len(ss.marks)):
        hier = CacheHierarchy(cache_levels)
        mem_idx = np.flatnonzero(ct.mem_mask)
        lvl, hit, bank, mshr = hier.replay(ct.addr[mem_idx],
                                           ct.op[mem_idx] == OP_STORE)
        level_col = np.zeros(ct.n, np.int8)
        hit_col = np.full(ct.n, -1, np.int8)
        bank_col = np.full(ct.n, -1, np.int16)
        mshr_col = np.zeros(ct.n, bool)
        level_col[mem_idx] = lvl
        hit_col[mem_idx] = hit
        bank_col[mem_idx] = bank
        mshr_col[mem_idx] = mshr
        full = ct.with_mem_results(level_col, hit_col, bank_col, mshr_col)
        windows = [
            TraceResult(slice_columns(full, lo, hi), hier, [])
            for _, lo, hi in ss.measured_marks()]
    return SampledAnalysis(structural=ss, windows=windows, cache=hier)


# ------------------------------------------------------------------ layer 2
def select_sampled(sa: SampledAnalysis, cfg: OffloadConfig
                   ) -> List[Tuple[OffloadResult, ReshapedTrace]]:
    """Algorithm-1 selection + reshape, per sampled window."""
    out = []
    with obs.span("sampling.select", cat="sampling",
                  workload=sa.structural.workload,
                  n_windows=len(sa.windows)):
        for tr in sa.windows:
            analysis = analyze_trace(tr)
            result = analysis.select(cfg)
            out.append((result, reshape(analysis.trace, result)))
    return out


# ------------------------------------------------------------------ pricing
def price_sampled(sa: SampledAnalysis,
                  selections: Sequence[Tuple[OffloadResult, ReshapedTrace]],
                  spec: SamplingSpec, tech: str = "sram",
                  host: Optional[HostModel] = None) -> SampledEstimate:
    """Per-window pricing + the cluster-weighted bootstrap estimator."""
    host = host or DEFAULT_HOST
    with obs.span("sampling.estimate", cat="sampling",
                  workload=sa.structural.workload,
                  n_windows=len(sa.windows)):
        reports = [
            profile_system(tr, tech=tech, host=host,
                           offload=result, reshaped=reshaped)
            for tr, (result, reshaped) in zip(sa.windows, selections)]
        return estimate_reports(reports, sa.plan, spec)


# ----------------------------------------------------------- one-shot driver
def sampled_report(workload: str, spec: SamplingSpec,
                   cache_levels: Tuple[CacheConfig, ...],
                   cfg: OffloadConfig = OffloadConfig(),
                   tech: str = "sram",
                   host: Optional[HostModel] = None) -> SampledEstimate:
    """The whole sampled pipeline, uncached (benchmarks and tests)."""
    ss = sampled_structural(workload, spec)
    sa = attach_sampled(ss, cache_levels)
    return price_sampled(sa, select_sampled(sa, cfg), spec, tech=tech,
                         host=host)
