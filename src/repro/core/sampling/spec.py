"""Sampling configuration: the accuracy knob the whole stack learns.

A :class:`SamplingSpec` travels from the CLI / service request codec down
through :class:`~repro.dse.backends.CimBackend` into the sampled analysis
pipeline (:mod:`repro.core.sampling.pipeline`).  ``mode="exact"`` (the
default) is the identity: every code path, cache key, and artifact byte is
the pre-sampling one.  The other two modes trade accuracy for time:

``stratified``
    Contiguous equal strata over the interval index; ``budget`` windows
    sampled across strata proportionally.  No feature pass needed beyond
    the skim's virtual instruction count.

``phase``
    SimPoint-style phase detection: k-means over per-interval structural
    feature vectors (op mix + dependency-depth histogram) from the skim
    pass, one or more representative windows per phase.

``SAMPLING_VERSION`` stamps every persisted sampled artifact (and is
registered in the repro.lint version-integrity manifest): bump it whenever
the estimator, the plan construction, or the sampled artifact schema
changes meaning — old sampled blobs become unreachable while exact
artifacts stay warm.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

SAMPLING_VERSION = 1

MODES = ("exact", "stratified", "phase")

# knob -> (attribute, parser) for the CLI / request "mode:k=v,..." syntax
_KNOBS = {
    "interval": int,
    "budget": int,
    "warmup": int,
    "seed": int,
    "target_ci": float,
    "confidence": float,
    "n_boot": int,
}


@dataclasses.dataclass(frozen=True)
class SamplingSpec:
    """How (and whether) to sample a workload's trace.

    ==========  =========================================================
    knob        meaning
    ==========  =========================================================
    mode        ``exact`` | ``stratified`` | ``phase``
    interval    virtual instructions per interval (the sampling unit)
    budget      max sampled windows traced/replayed/priced per workload
    warmup      virtual instructions traced *before* each window to warm
                the register file and cache state (detailed warmup a la
                SMARTS); warmup rows are never priced
    seed        RNG seed: window picks, k-means init, bootstrap resamples
    target_ci   refine until the relative CI half-width of the energy
                estimate is below this (0 = one pass, no refinement)
    confidence  bootstrap percentile-interval confidence level
    n_boot      bootstrap resamples per estimate
    ==========  =========================================================

    Frozen + hashable: rides inside the frozen
    :class:`~repro.dse.backends.CimBackend` across process-pool
    boundaries and into :class:`~repro.dse.engine.AnalysisCache` memo
    keys.
    """
    mode: str = "exact"
    interval: int = 2048
    budget: int = 32
    warmup: int = 2048
    seed: int = 0
    target_ci: float = 0.0
    confidence: float = 0.95
    n_boot: int = 200

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown sampling mode {self.mode!r}; "
                             f"known: {MODES}")
        if self.interval < 64:
            raise ValueError("sampling interval must be >= 64 instructions")
        if self.budget < 1:
            raise ValueError("sampling budget must be >= 1 window")
        if self.warmup < 0:
            raise ValueError("warmup must be >= 0 instructions")
        if not 0.0 <= self.target_ci < 1.0:
            raise ValueError("target_ci must be in [0, 1)")
        if not 0.5 < self.confidence < 1.0:
            raise ValueError("confidence must be in (0.5, 1)")
        if self.n_boot < 10:
            raise ValueError("n_boot must be >= 10")

    @property
    def is_exact(self) -> bool:
        return self.mode == "exact"

    def key(self) -> str:
        """Compact identity string, used in cache/store keys and the
        ``sampling`` column of sampled :class:`~repro.dse.results.SweepRecord`
        rows.  Exact mode has no key — exact artifacts must keep their
        pre-sampling cache identity."""
        if self.is_exact:
            return "exact"
        k = f"{self.mode}:i{self.interval}:b{self.budget}:s{self.seed}"
        if self.warmup != 2048:
            k += f":w{self.warmup}"
        if self.target_ci:
            k += f":t{self.target_ci:g}"
        if self.confidence != 0.95:
            k += f":c{self.confidence:g}"
        if self.n_boot != 200:
            k += f":r{self.n_boot}"
        return k

    # ------------------------------------------------------------- codecs
    @classmethod
    def parse(cls, text: str) -> "SamplingSpec":
        """CLI syntax: ``mode[:knob=value,...]``.

        e.g. ``--sample phase:interval=1024,budget=16,seed=3``
        """
        mode, _, rest = text.strip().partition(":")
        kwargs: Dict[str, object] = {"mode": mode or "exact"}
        if rest:
            for item in rest.split(","):
                name, sep, val = item.partition("=")
                if not sep or name not in _KNOBS:
                    raise ValueError(
                        f"bad sampling knob {item!r}; knobs: "
                        f"{sorted(_KNOBS)} (syntax: mode:k=v,k=v)")
                kwargs[name] = _KNOBS[name](val)
        return cls(**kwargs)

    @classmethod
    def from_dict(cls, doc: Dict) -> "SamplingSpec":
        """Service request codec: ``{"mode": ..., "interval": ..., ...}``."""
        if not isinstance(doc, dict):
            raise ValueError("'sampling' must be a JSON object")
        bad = [k for k in doc if k != "mode" and k not in _KNOBS]
        if bad:
            raise ValueError(f"unknown sampling knob(s) {bad}; knobs: "
                             f"['mode'] + {sorted(_KNOBS)}")
        kwargs: Dict[str, object] = {}
        if "mode" in doc:
            kwargs["mode"] = doc["mode"]
        for name, conv in _KNOBS.items():
            if name in doc:
                kwargs[name] = conv(doc[name])
        return cls(**kwargs)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)
