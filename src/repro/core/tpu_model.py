"""TPU hardware model — the device/array model of Eva-CiM's TPU mode.

The paper prices an ARM host + SRAM/FeFET CiM caches; the TPU-native
adaptation (DESIGN.md §3) prices a v5e pod: MXU compute, HBM<->VMEM
traffic, and ICI collectives.  The same three questions (how much does the
workload benefit / which memory level / which technology) become the three
roofline terms the dry-run derives per (arch x shape x mesh) cell.

Hardware constants are the assignment's: 197 bf16 TFLOP/s per chip,
819 GB/s HBM, ~50 GB/s/link ICI.  Energy constants are public-literature
estimates used only for the Eva-CiM-style energy report (not the roofline).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class TpuChip:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12          # FLOP/s per chip
    hbm_bw: float = 819e9                    # B/s per chip
    ici_bw: float = 50e9                     # B/s per link (assignment value)
    hbm_bytes: float = 16e9                  # capacity per chip
    vmem_bytes: float = 128e6                # ~128 MB VMEM (v5e ~128MiB class)
    # energy (pJ) — literature-class estimates for the energy report
    pj_per_flop: float = 0.25                # MXU bf16 MAC amortized
    pj_per_hbm_byte: float = 8.0
    pj_per_ici_byte: float = 3.0
    pj_per_vmem_byte: float = 0.25


V5E = TpuChip()

# ---------------------------------------------------------------------------
# Named chip design points for the TPU-mode DSE axis (SweepSpace(tpus=...)),
# mirroring core.host_model.HOST_PRESETS: frozen, hashable constants so
# SweepPoint hashing/dedup works for TPU-carrying points.  Peak-FLOPs / HBM
# bandwidth / capacity are public spec-sheet numbers; the pJ constants are
# literature-class estimates scaled by process generation (v4 oldest, v5p
# most efficient per byte moved).  Declared in capability order
# (v5e < v4 < v5p by peak compute), so "adjacent chip" is a physically
# meaningful adaptive-refinement move.
# ---------------------------------------------------------------------------
TPU_PRESETS: Dict[str, TpuChip] = {
    # the assignment's baseline: 197 bf16 TFLOP/s, 819 GB/s HBM (== V5E)
    "v5e": V5E,
    # v4: 275 bf16 TFLOP/s, 1.2 TB/s HBM2, 32 GB — older process, so the
    # per-op energies sit above the v5 generation's
    "v4": TpuChip(name="tpu-v4", peak_flops_bf16=275e12, hbm_bw=1228e9,
                  ici_bw=50e9, hbm_bytes=32e9, vmem_bytes=128e6,
                  pj_per_flop=0.35, pj_per_hbm_byte=10.0, pj_per_ici_byte=4.0,
                  pj_per_vmem_byte=0.3),
    # v5p: 459 bf16 TFLOP/s, 2.76 TB/s HBM, 95 GB, fatter ICI links
    "v5p": TpuChip(name="tpu-v5p", peak_flops_bf16=459e12, hbm_bw=2765e9,
                   ici_bw=100e9, hbm_bytes=95e9, vmem_bytes=128e6,
                   pj_per_flop=0.2, pj_per_hbm_byte=6.0, pj_per_ici_byte=2.5,
                   pj_per_vmem_byte=0.2),
}


@dataclasses.dataclass
class RooflineTerms:
    """The three §Roofline terms, in seconds, for one compiled cell."""
    compute_s: float
    memory_s: float
    collective_s: float
    n_devices: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Lower-bound step time: the dominant term (no overlap assumed
        between the sub-dominant ones and it — they hide behind it)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """dominant / sum — 1.0 means perfectly limited by one resource
        (nothing wasted waiting on the others if perfectly overlapped)."""
        s = self.compute_s + self.memory_s + self.collective_s
        return self.bound_s / s if s > 0 else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "bound_s": self.bound_s,
            "roofline_fraction": round(self.roofline_fraction, 4),
        }


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float, n_devices: int,
                   chip: TpuChip = V5E) -> RooflineTerms:
    """The assignment's three-term model:

        compute    = HLO_FLOPs / peak_FLOP/s         (per device)
        memory     = HLO_bytes / HBM_bw              (per device)
        collective = collective_bytes / link_bw      (per device)
    """
    return RooflineTerms(
        compute_s=max(flops_per_device, 0.0) / chip.peak_flops_bf16,
        memory_s=max(bytes_per_device, 0.0) / chip.hbm_bw,
        collective_s=max(collective_bytes_per_device, 0.0) / chip.ici_bw,
        n_devices=n_devices,
    )


def step_energy_pj(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float, n_devices: int,
                   chip: TpuChip = V5E) -> Dict[str, float]:
    """Eva-CiM-style whole-system energy estimate for one step (all chips)."""
    compute = flops_per_device * chip.pj_per_flop * n_devices
    hbm = bytes_per_device * chip.pj_per_hbm_byte * n_devices
    ici = collective_bytes_per_device * chip.pj_per_ici_byte * n_devices
    return {"compute_pj": compute, "hbm_pj": hbm, "ici_pj": ici,
            "total_pj": compute + hbm + ici}


def model_flops(param_count: int, tokens: int, kind: str = "train") -> float:
    """MODEL_FLOPS = 6*N*D for training (fwd 2ND + bwd 4ND), 2*N*D for
    inference — the §Roofline 'useful compute' yardstick."""
    per_tok = 6.0 if kind == "train" else 2.0
    return per_tok * float(param_count) * float(tokens)
