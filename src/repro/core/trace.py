"""Trace VM: lower any JAX program to a committed pseudo-RISC instruction queue.

This is the repo's stand-in for the paper's modified GEM5 + probes
(Fig. 2): ``trace_program(fn, *args)`` traces ``fn`` to a jaxpr, interprets
it with concrete numpy values, and *scalarizes* every array equation into a
stream of committed instructions — loads / stores with real addresses from a
buffer arena, ALU ops over a finite register file, immediates for literals.

The register allocator is what makes the paper's Fig. 4 pattern variants
appear naturally:

  (a) Load-Load-OP-Store    — both operands fetched from memory;
  (b) Load-Imm-OP-Store     — jaxpr literals / iota lower to immediates;
  (c) OP-(reg)-OP-Store     — a recently produced value is still live in a
                              register, so the consumer's load is elided and
                              the IDG edge points at the producing OP.

Every load/store goes through the :mod:`repro.core.cache` hierarchy, which
fills the I-state's "memory access" / "response from slave" fields (level,
hit, bank, MSHR) — the data-locality ground truth the offload selector needs.

RUT (register usage table) and IHT (index hash table) — the paper's O(N)
IDG construction aids (Fig. 6 / Algorithm 2) — are built incrementally here
while the trace is emitted, exactly as the probes would.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.extend.core as jex_core
import numpy as np

from repro.core.cache import CacheConfig, CacheHierarchy, L1_32K, L2_256K
from repro.core.columnar import ColumnarBuilder, ColumnarTrace, _imm_kind
from repro.core.isa import (DTYPE_CODE, IMM_FLOAT, IMM_INT, OP_CODE, OP_LOAD,
                            OP_STORE, SRC_IMM, SRC_REG, U_BRANCH, UNIT_CODE,
                            Inst, Trace, unit_for)

# Version of the trace VM's *observable lowering semantics or artifact
# encoding*.  Bump whenever a change alters the committed instruction
# stream for an unchanged program (new lowering rules, register-allocator
# or arena-layout changes, cache model fixes...) OR the persisted layer-1
# representation (v2: columnar .npz columns replaced pickled Inst lists).
# The on-disk analysis store (repro.dse.store) keys every persisted
# artifact by this number, so stale traces from an older VM are
# invalidated instead of silently re-priced.
TRACE_VM_VERSION = 2

# pre-resolved emission codes: op -> (unit code for int, unit code for float)
_UNIT_CODES = {op: (UNIT_CODE[unit_for(op, False)],
                    UNIT_CODE[unit_for(op, True)]) for op in OP_CODE}
_MEM_RD_CODE = UNIT_CODE[unit_for("load", False)]
_MEM_WR_CODE = UNIT_CODE[unit_for("store", False)]
_BRANCH_CODE = UNIT_CODE[U_BRANCH]

# pre-packed ColumnarBuilder meta fragments for the inlined scalar emitter
# (see Machine.emit_scalar); the encodings mirror ColumnarBuilder.add
_LOAD_META = OP_LOAD | _MEM_RD_CODE << 5
_STORE_META = OP_STORE | _MEM_WR_CODE << 5
_IMM_INT_SMETA = SRC_IMM | IMM_INT << 1


# jit-compiled gather/scatter oracles, cached per static config: the eager
# lax dispatch costs tens of microseconds per call, which dominates kernels
# that hit these primitives once per loop iteration (mcf, astar); the jit
# cache re-traces per operand shape and replays the compiled computation
# after that — same XLA kernel the eager path runs, so values are bit-exact
@functools.lru_cache(maxsize=None)
def _jitted_gather(dnums, slice_sizes, mode):
    return jax.jit(functools.partial(jax.lax.gather,
                                     dimension_numbers=dnums,
                                     slice_sizes=slice_sizes, mode=mode))


@functools.lru_cache(maxsize=None)
def _jitted_scatter(is_add: bool, dnums, mode):
    op = jax.lax.scatter_add if is_add else jax.lax.scatter
    return jax.jit(functools.partial(op, dimension_numbers=dnums, mode=mode))

# ======================================================================
# Values: concrete data + an address map (None => immediate / generated)
# ======================================================================
class Value:
    __slots__ = ("data", "addr")

    def __init__(self, data: np.ndarray, addr: Optional[np.ndarray]):
        self.data = data
        self.addr = addr                    # int64 addresses, same shape, or None

    @property
    def in_memory(self) -> bool:
        return self.addr is not None


# dtype -> tag/itemsize are pure and the dtype universe is tiny; the
# issubdtype/np.dtype machinery is measurably hot in scalar-heavy traces
_TAG_CACHE: Dict[Any, str] = {}
_SIZE_CACHE: Dict[Any, int] = {}


def _dtype_tag(dt: np.dtype) -> str:
    tag = _TAG_CACHE.get(dt)
    if tag is None:
        tag = "f" if np.issubdtype(dt, np.floating) else "i"
        _TAG_CACHE[dt] = tag
    return tag


def _itemsize(dt: np.dtype) -> int:
    size = _SIZE_CACHE.get(dt)
    if size is None:
        size = int(np.dtype(dt).itemsize)
        _SIZE_CACHE[dt] = size
    return size


# ======================================================================
# The machine
# ======================================================================
@dataclasses.dataclass
class TraceLimits:
    max_instructions: int = 4_000_000


class Machine:
    """Arena + register file + the emitted CIQ (columnar).

    The machine emits *structural* columns only — opcode, registers,
    addresses — one scalar append per field per committed instruction
    (:class:`~repro.core.columnar.ColumnarBuilder`), never an
    :class:`~repro.core.isa.Inst` object.  The memory-response fields
    (level/hit/bank/MSHR) are geometry-dependent and are attached
    afterwards by replaying the access stream through a
    :class:`~repro.core.cache.CacheHierarchy`
    (:func:`attach_cache_results`), which is what lets one structural
    trace serve every cache configuration of a sweep.  RUT/IHT are no
    longer built at commit time either: they are derived tables,
    reconstructed vectorized from the source-operand columns
    (:func:`repro.core.idg.build_rut_iht`).
    """

    # compiled inner loops carry induction/address-gen + branch overhead;
    # -O2 typically unrolls ~4x, so: one agen per element, one branch per 4.
    UNROLL = 4

    def __init__(self, n_regs: int = 24, limits: TraceLimits = TraceLimits(),
                 loop_overhead: bool = True):
        from repro.core.columnar import MAX_REG_ID
        if not 1 <= n_regs <= MAX_REG_ID - 1:     # +1 induction register
            raise ValueError(f"n_regs must be in [1, {MAX_REG_ID - 1}] "
                             "(columnar dst packing)")
        self.b = ColumnarBuilder()
        self.limits = limits
        self.loop_overhead = loop_overhead
        self._arena_top = 0x1000
        self._ov_count = 0
        # register file (single class; dtype tag recorded per instruction)
        self.n_regs = n_regs
        self._free_regs = list(range(n_regs + 1))       # +1: induction reg
        self._ov_reg = self._free_regs.pop()            # reserved induction var
        self._reg_of_addr: "OrderedDict[int, int]" = OrderedDict()  # LRU
        self._addr_of_reg: Dict[int, int] = {}
        # pre-built argument tuple for the (constant) loop-overhead agen op
        self._ov_args = (OP_CODE["agen"], _UNIT_CODES["agen"][False], False,
                         self._ov_reg, -1, 4,
                         ((SRC_REG, self._ov_reg), (SRC_IMM, 4)))
        # pre-packed meta words for the inlined scalar emitter
        self._ov_meta = (OP_CODE["agen"] | _UNIT_CODES["agen"][False] << 5
                         | (self._ov_reg + 1) << 10 | 4 << 18)
        self._branch_meta = OP_CODE["branch"] | _BRANCH_CODE << 5 | 4 << 18
        self._loops: List[dict] = []
        self._scope_cache: Dict[Any, dict] = {}

    # ------------------------------------------------------------ arena
    # Loop-scoped buffer reuse: compiled loops keep their temporaries on the
    # stack / in fixed buffers rather than allocating fresh memory per
    # iteration.  Inside a scan/while body, the i-th allocation of iteration
    # t reuses the i-th allocation of iteration t-3 (triple buffering keeps
    # carries from t-1 and freshly stacked outputs intact).  Without this,
    # every temporary is a compulsory DRAM miss and the whole analysis
    # drowns in DRAM traffic no real binary would produce.
    LOOP_REUSE_DEPTH = 3

    def alloc(self, shape: Tuple[int, ...], dt: np.dtype) -> np.ndarray:
        n = 1
        for s in shape:
            n *= int(s)
        # temporaries pack like stack slots (8 B granularity); standalone
        # buffers outside loops stay line-aligned like heap allocations
        in_loop = bool(self._loops)
        align = 7 if in_loop else 63
        size = (n * _itemsize(dt) + align) & ~align
        base = None
        if in_loop:
            scope = self._loops[-1]
            idx = len(scope["cur"])
            hist = scope["hist"]
            if len(hist) == self.LOOP_REUSE_DEPTH and idx < len(hist[0]) \
                    and hist[0][idx][1] == size:
                base = hist[0][idx][0]                   # recycle old temp
            scope["cur"].append((base if base is not None else self._arena_top,
                                 size))
        if base is None:
            base = self._arena_top
            self._arena_top += size
        if n == 1:
            a = np.array(base, dtype=np.int64)
            return a if not shape else a.reshape(shape)
        return (base + np.arange(n, dtype=np.int64) * _itemsize(dt)).reshape(shape)

    def push_loop(self, key=None) -> None:
        """Enter a loop body scope.  ``key`` (the loop jaxpr's id) resumes
        the scope across re-entry — an inner loop reuses the same stack
        slots on every run, exactly like a compiled loop nest."""
        if key is not None and key in self._scope_cache:
            scope = self._scope_cache[key]
            scope["cur"] = []
        else:
            scope = {"hist": [], "cur": []}
            if key is not None:
                self._scope_cache[key] = scope
        self._loops.append(scope)

    def next_iteration(self) -> None:
        scope = self._loops[-1]
        scope["hist"].append(scope["cur"])
        if len(scope["hist"]) > self.LOOP_REUSE_DEPTH:
            scope["hist"].pop(0)
        scope["cur"] = []

    def pop_loop(self) -> None:
        self._loops.pop()

    # ---------------------------------------------------------- registers
    def _alloc_reg(self) -> int:
        if self._free_regs:
            return self._free_regs.pop()
        if self._reg_of_addr:
            # evict LRU mapping; its value now lives only in memory
            addr, reg = self._reg_of_addr.popitem(last=False)
            del self._addr_of_reg[reg]
            return reg
        # nothing evictable (all regs hold in-flight temporaries): round-robin
        self._rr = (getattr(self, "_rr", -1) + 1) % self.n_regs
        return self._rr

    def _bind(self, addr: int, reg: int) -> None:
        old = self._addr_of_reg.get(reg)
        if old is not None:
            self._reg_of_addr.pop(old, None)
        self._reg_of_addr[addr] = reg
        self._addr_of_reg[reg] = addr

    def reg_holding(self, addr: int) -> Optional[int]:
        reg = self._reg_of_addr.get(addr)
        if reg is not None:
            self._reg_of_addr.move_to_end(addr)
        return reg

    # ----------------------------------------------------------- emission
    def _check_limit(self) -> None:
        if self.b.n > self.limits.max_instructions:
            raise RuntimeError(
                f"trace exceeded {self.limits.max_instructions} instructions; "
                "shrink the workload size")

    def emit_load(self, addr: int, tag: str, size: int) -> int:
        hit_reg = self.reg_holding(addr)
        if hit_reg is not None:
            return hit_reg                                # load elided (Fig.4c)
        reg = self._alloc_reg()
        self.b.add(OP_LOAD, _MEM_RD_CODE, tag == "f", reg, addr, size,
                   ((SRC_IMM, addr),))
        self._check_limit()
        self._bind(addr, reg)
        return reg

    def emit_op(self, op: str, tag: str, srcs: Sequence[Tuple[int, Any]],
                dst: Optional[int] = None) -> int:
        """``dst``: reuse a register (reduction accumulators, like a compiler)."""
        reg = self._alloc_reg() if dst is None else dst
        if dst is not None:
            old = self._addr_of_reg.pop(dst, None)
            if old is not None:
                self._reg_of_addr.pop(old, None)
        is_f = tag == "f"
        self.b.add(OP_CODE[op], _UNIT_CODES[op][is_f], is_f, reg, -1, 4,
                   tuple(srcs))
        self._check_limit()
        return reg

    def emit_store(self, addr: int, reg: int, tag: str, size: int) -> None:
        self.b.add(OP_STORE, _MEM_WR_CODE, tag == "f", -1, addr, size,
                   ((SRC_REG, reg),))
        self._check_limit()
        self._bind(addr, reg)                            # value is in reg + mem

    def emit_branch(self) -> None:
        self.b.add(OP_CODE["branch"], _BRANCH_CODE, False, -1, -1, 4, ())
        self._check_limit()

    def emit_loop_overhead(self) -> None:
        """Per-element induction/addr-gen + amortized loop branch (UNROLL)."""
        if not self.loop_overhead:
            return
        self.b.add(*self._ov_args)
        self._check_limit()
        self._ov_count += 1
        if self._ov_count % self.UNROLL == 0:
            self.emit_branch()

    def emit_scalar(self, op: str, tag: str, invals: Sequence["Value"],
                    out_addr: int, osize: int) -> None:
        """One whole scalar equation — loop overhead, operand loads, the op,
        the store — emitted straight-line.

        Byte-identical to ``emit_loop_overhead`` + ``emit_load``* +
        ``emit_op`` + ``emit_store`` called in sequence; exists because
        scalar-heavy kernels (LCS, mcf) lower ~1 committed instruction per
        jaxpr equation and spend most of their trace time on the CPython
        call overhead of that sequence.
        """
        b = self.b
        meta_l, addr_l, srcn_l = b.meta, b.addr, b.src_n
        smeta_l, sval_l = b.src_meta, b.src_val
        n_new = 0
        if self.loop_overhead:
            meta_l.append(self._ov_meta)
            addr_l.append(-1)
            srcn_l.append(2)
            smeta_l.append(SRC_REG)
            sval_l.append(self._ov_reg)
            smeta_l.append(_IMM_INT_SMETA)
            sval_l.append(4.0)
            n_new = 1
            self._ov_count += 1
            if self._ov_count % self.UNROLL == 0:
                meta_l.append(self._branch_meta)
                addr_l.append(-1)
                srcn_l.append(0)
                n_new = 2
        reg_of_addr = self._reg_of_addr
        op_smeta: List[int] = []
        op_sval: List[float] = []
        for v in invals:
            if v.addr is None:
                d = v.data.item()
                t = type(d)
                kind = (IMM_INT if t is int else
                        IMM_FLOAT if t is float else _imm_kind(d))
                op_smeta.append(SRC_IMM | kind << 1)
                op_sval.append(float(d))
            else:
                a = v.addr.item()
                reg = reg_of_addr.get(a)
                if reg is not None:
                    reg_of_addr.move_to_end(a)      # load elided (Fig.4c)
                else:
                    dt = v.data.dtype
                    reg = self._alloc_reg()
                    meta_l.append(_LOAD_META | (_dtype_tag(dt) == "f") << 9
                                  | (reg + 1) << 10 | _itemsize(dt) << 18)
                    addr_l.append(a)
                    srcn_l.append(1)
                    smeta_l.append(_IMM_INT_SMETA)
                    sval_l.append(float(a))
                    n_new += 1
                    self._bind(a, reg)
                op_smeta.append(SRC_REG)
                op_sval.append(reg)
        is_f = tag == "f"
        rd = self._alloc_reg()
        meta_l.append(OP_CODE[op] | _UNIT_CODES[op][is_f] << 5 | is_f << 9
                      | (rd + 1) << 10 | 4 << 18)
        addr_l.append(-1)
        srcn_l.append(len(op_smeta))
        smeta_l.extend(op_smeta)
        sval_l.extend(op_sval)
        meta_l.append(_STORE_META | is_f << 9 | osize << 18)
        addr_l.append(out_addr)
        srcn_l.append(1)
        smeta_l.append(SRC_REG)
        sval_l.append(rd)
        b.n += n_new + 2
        self._bind(out_addr, rd)
        self._check_limit()

    # ------------------------------------------------- value-level helpers
    def materialize(self, val: Value) -> Value:
        """Give an immediate-only value a memory buffer (mov+store each elem)."""
        if val.in_memory:
            return val
        data = np.asarray(val.data)
        addr = self.alloc(data.shape, data.dtype)
        tag = _dtype_tag(data.dtype)
        size = _itemsize(data.dtype)
        flat_d = data.ravel().tolist()
        flat_a = addr.ravel().tolist()
        for d, a in zip(flat_d, flat_a):
            r = self.emit_op("mov", tag, ((SRC_IMM, d),))
            self.emit_store(a, r, tag, size)
        return Value(data, addr)

    def store_const(self, arr: np.ndarray) -> Value:
        """Program constants live in memory but cost no trace instructions
        (they were written by the loader, not the program)."""
        arr = np.asarray(arr)
        addr = self.alloc(arr.shape, arr.dtype)
        # pre-touch DRAM residency without recording instructions
        return Value(arr, addr)


# ======================================================================
# jaxpr interpretation + scalarization
# ======================================================================
_ELEMENTWISE = {
    "add": "add", "sub": "sub", "mul": "mul", "div": "div",
    "max": "max", "min": "min", "and": "and", "or": "or", "xor": "xor",
    "not": "not", "neg": "neg", "abs": "abs", "sign": "sign",
    "exp": "exp", "log": "log", "tanh": "tanh", "logistic": "sigmoid",
    "sqrt": "sqrt", "rsqrt": "rsqrt", "floor": "floor", "ceil": "floor",
    "round": "round", "rem": "rem", "pow": "pow",
    "shift_left": "shl", "shift_right_logical": "shr",
    "shift_right_arithmetic": "shr", "erf": "exp", "exp2": "exp", "log1p": "log",
    "expm1": "exp", "cos": "exp", "sin": "exp", "is_finite": "cmp",
    "square": "mul", "cbrt": "sqrt", "tan": "exp",
}
_COMPARE = {"lt": "cmp", "le": "cmp", "gt": "cmp", "ge": "cmp",
            "eq": "cmp", "ne": "cmp"}
_NP_BINOP = {
    "add": np.add, "sub": np.subtract, "mul": np.multiply,
    "div": lambda a, b: np.divide(a, b) if np.issubdtype(np.result_type(a, b), np.floating)
           else np.floor_divide(a, b),
    "max": np.maximum, "min": np.minimum,
    "and": np.bitwise_and, "or": np.bitwise_or, "xor": np.bitwise_xor,
    "rem": np.remainder, "pow": np.power,
    "shift_left": np.left_shift, "shift_right_logical": np.right_shift,
    "shift_right_arithmetic": np.right_shift,
    "lt": np.less, "le": np.less_equal, "gt": np.greater,
    "ge": np.greater_equal, "eq": np.equal, "ne": np.not_equal,
}
_NP_UNOP = {
    "not": np.logical_not, "neg": np.negative, "abs": np.abs, "sign": np.sign,
    "exp": np.exp, "log": np.log, "tanh": np.tanh,
    "logistic": lambda x: 1.0 / (1.0 + np.exp(-x)),
    "sqrt": np.sqrt, "rsqrt": lambda x: 1.0 / np.sqrt(x),
    "floor": np.floor, "ceil": np.ceil, "round": np.round,
    "erf": lambda x: np.vectorize(float)(x),  # unused in workloads
    "exp2": np.exp2, "log1p": np.log1p, "expm1": np.expm1,
    "cos": np.cos, "sin": np.sin, "tan": np.tan,
    "is_finite": np.isfinite, "square": np.square, "cbrt": np.cbrt,
}

# pre-joined dispatch tables: prim -> (vm op, numpy oracle).  The dict
# unions used to be rebuilt on every equation, which dominated dispatch
# for scalar-heavy traces where each eqn emits only a couple instructions.
_EW_OPS = {**_ELEMENTWISE, **_COMPARE}
_EW_BINOP = {p: (_EW_OPS[p], _NP_BINOP[p]) for p in _NP_BINOP if p in _EW_OPS}
_EW_UNOP = {p: (_ELEMENTWISE[p], _NP_UNOP[p])
            for p in _NP_UNOP if p in _ELEMENTWISE}
_CALL_PRIMS = frozenset((
    "pjit", "jit", "closed_call", "core_call", "custom_jvp_call",
    "custom_vjp_call", "checkpoint", "remat", "custom_vjp_call_jaxpr"))


class TraceInterpreter:
    def __init__(self, machine: Machine):
        self.m = machine

    # ---------------------------------------------------------------- API
    def run(self, jaxpr, consts, args: List[Value]) -> List[Value]:
        env: Dict[Any, Value] = {}

        def read(atom) -> Value:
            if isinstance(atom, jex_core.Literal):
                return Value(np.asarray(atom.val), None)
            return env[atom]

        def write(var, val: Value) -> None:
            env[var] = val

        for var, const in zip(jaxpr.constvars, consts):
            arr = np.asarray(const)
            write(var, Value(arr, None) if arr.ndim == 0 else self.m.store_const(arr))
        for var, arg in zip(jaxpr.invars, args):
            write(var, arg)

        for eqn in jaxpr.eqns:
            invals = [read(a) for a in eqn.invars]
            outvals = self.eqn(eqn, invals)
            for var, val in zip(eqn.outvars, outvals):
                write(var, val)

        return [read(v) for v in jaxpr.outvars]

    # ------------------------------------------------------------- fetch
    def _fetch_srcs(self, vals: List[Value], idx_lists: List[List[int]],
                    i: int, tags: List[str], sizes: List[int]):
        srcs = []
        for v, idxs, tag, size in zip(vals, idx_lists, tags, sizes):
            if v.addr is None:
                d = v.data if v.data.ndim == 0 else v.data.ravel()[idxs[i]]
                srcs.append((SRC_IMM, d.item() if hasattr(d, "item") else d))
            else:
                r = self.m.emit_load(int(v.addr.ravel()[idxs[i]]), tag, size)
                srcs.append((SRC_REG, r))
        return srcs

    # ------------------------------------------------- elementwise family
    def _elementwise(self, op: str, invals: List[Value], out_data: np.ndarray
                     ) -> Value:
        m = self.m
        out_data = np.asarray(out_data)
        out_addr = m.alloc(out_data.shape, out_data.dtype)
        tag = _dtype_tag(out_data.dtype)
        osize = _itemsize(out_data.dtype)
        n = out_data.size
        if n == 1:
            # scalar fast path: pointer-heavy kernels (LCS, mcf) lower almost
            # every jaxpr equation to one committed instruction, so the
            # broadcast/ravel/tolist mirrors below dominate their trace time
            m.emit_scalar(op, tag, invals, out_addr.item(), osize)
            return Value(out_data, out_addr)
        # broadcast source addr/data maps to the output shape; plain-list
        # mirrors make the per-element emission loop scalar-cheap.  Sources
        # already output-shaped (the common case) skip the broadcast;
        # size-1 sources splat without touching numpy per element.
        srcs_flat = []
        for v in invals:
            data = np.asarray(v.data)
            if data.shape == out_data.shape:
                flat_d = data.ravel().tolist()
            elif data.size == 1:
                flat_d = [data.ravel()[0].item()] * n
            else:
                flat_d = np.broadcast_to(data, out_data.shape).ravel().tolist()
            if v.addr is None:
                flat_a = None
            elif v.addr.shape == out_data.shape:
                flat_a = v.addr.ravel().tolist()
            elif v.addr.size == 1:
                flat_a = [int(v.addr.ravel()[0])] * n
            else:
                flat_a = np.broadcast_to(v.addr,
                                         out_data.shape).ravel().tolist()
            srcs_flat.append((flat_d, flat_a, _dtype_tag(data.dtype),
                              _itemsize(data.dtype)))
        oaddr_flat = out_addr.ravel().tolist()
        emit_overhead = m.emit_loop_overhead
        emit_load, emit_op, emit_store = m.emit_load, m.emit_op, m.emit_store
        for i in range(n):
            emit_overhead()
            srcs = []
            for data, addr, stag, ssize in srcs_flat:
                if addr is None:
                    srcs.append((SRC_IMM, data[i]))
                else:
                    srcs.append((SRC_REG, emit_load(addr[i], stag, ssize)))
            rd = emit_op(op, tag, srcs)
            emit_store(oaddr_flat[i], rd, tag, osize)
        return Value(out_data, out_addr)

    # ----------------------------------------------------------- reduction
    def _reduce(self, op: str, inval: Value, axes: Tuple[int, ...],
                out_data: np.ndarray, init_imm) -> Value:
        """Sequential accumulation — acc stays in a register (Fig. 4c chains)."""
        m = self.m
        out_data = np.asarray(out_data)
        x = np.asarray(inval.data)
        tag = _dtype_tag(out_data.dtype)
        osize = _itemsize(out_data.dtype)
        ssize = _itemsize(x.dtype)
        keep = [a for a in range(x.ndim) if a not in axes]
        perm = keep + list(axes)
        red_n = int(np.prod([x.shape[a] for a in axes])) if axes else 1
        xa = (np.transpose(inval.addr, perm).reshape(-1, red_n).tolist()
              if inval.addr is not None else None)
        xd = np.transpose(x, perm).reshape(-1, red_n)
        xd_l = xd.tolist()
        out_addr = m.alloc(out_data.shape, out_data.dtype)
        oaddr_flat = out_addr.ravel().tolist()
        emit_overhead = m.emit_loop_overhead
        emit_load, emit_op, emit_store = m.emit_load, m.emit_op, m.emit_store
        for i in range(xd.shape[0]):
            acc = emit_op("mov", tag, ((SRC_IMM, init_imm),))
            row_a = xa[i] if xa is not None else None
            row_d = xd_l[i]
            for j in range(red_n):
                emit_overhead()
                if row_a is None:
                    src = (SRC_IMM, row_d[j])
                else:
                    src = (SRC_REG, emit_load(row_a[j], tag, ssize))
                acc = emit_op(op, tag, ((SRC_REG, acc), src), dst=acc)
            emit_store(oaddr_flat[i], acc, tag, osize)
        return Value(out_data, out_addr)

    def _argreduce(self, cmp_np, inval: Value, axis: int, out_data: np.ndarray
                   ) -> Value:
        m = self.m
        x = np.asarray(inval.data)
        perm = [a for a in range(x.ndim) if a != axis] + [axis]
        red_n = x.shape[axis]
        xa = (np.transpose(inval.addr, perm).reshape(-1, red_n)
              if inval.addr is not None else None)
        xd = np.transpose(x, perm).reshape(-1, red_n)
        out_data = np.asarray(out_data)
        out_addr = m.alloc(out_data.shape, out_data.dtype)
        oaddr_flat = out_addr.ravel()
        tag = _dtype_tag(x.dtype)
        ssize = _itemsize(x.dtype)
        for i in range(xd.shape[0]):
            best = m.emit_op("mov", tag, ((SRC_IMM, xd[i, 0].item()),)) \
                if xa is None else m.emit_load(int(xa[i, 0]), tag, ssize)
            bidx = m.emit_op("mov", "i", ((SRC_IMM, 0),))
            for j in range(1, red_n):
                m.emit_loop_overhead()
                if xa is None:
                    src = (SRC_IMM, xd[i, j].item())
                    cur = m.emit_op("mov", tag, (src,))
                else:
                    cur = m.emit_load(int(xa[i, j]), tag, ssize)
                c = m.emit_op("cmp", tag, ((SRC_REG, cur), (SRC_REG, best)))
                best = m.emit_op("sel", tag, ((SRC_REG, c), (SRC_REG, cur),
                                              (SRC_REG, best)), dst=best)
                bidx = m.emit_op("sel", "i", ((SRC_REG, c), (SRC_IMM, j),
                                              (SRC_REG, bidx)), dst=bidx)
            m.emit_store(int(oaddr_flat[i]), bidx, "i",
                         _itemsize(out_data.dtype))
        return Value(out_data, out_addr)

    # -------------------------------------------------------- dot_general
    def _dot_general(self, a: Value, b: Value, dnums, out_data: np.ndarray
                     ) -> Value:
        m = self.m
        (lc, rc), (lb, rb) = dnums
        A, B = np.asarray(a.data), np.asarray(b.data)

        def order(x, batch, contract):
            keep = [i for i in range(x.ndim) if i not in batch + contract]
            return list(batch) + keep + list(contract)

        pa, pb = order(A, tuple(lb), tuple(lc)), order(B, tuple(rb), tuple(rc))
        nb = int(np.prod([A.shape[i] for i in lb])) if lb else 1
        K = int(np.prod([A.shape[i] for i in lc])) if lc else 1
        Mm = A.size // (nb * K)
        Nn = B.size // (nb * K)
        Ad = np.transpose(A, pa).reshape(nb, Mm, K)
        Bd = np.transpose(B, pb).reshape(nb, Nn, K)
        Aa = (np.transpose(a.addr, pa).reshape(nb, Mm, K)
              if a.addr is not None else None)
        Ba = (np.transpose(b.addr, pb).reshape(nb, Nn, K)
              if b.addr is not None else None)
        out_data = np.asarray(out_data)
        out_addr = m.alloc(out_data.shape, out_data.dtype)
        oaddr = out_addr.reshape(nb, Mm, Nn)
        tag = _dtype_tag(out_data.dtype)
        asz, bsz = _itemsize(A.dtype), _itemsize(B.dtype)
        osize = _itemsize(out_data.dtype)
        Ad_l, Bd_l = Ad.tolist(), Bd.tolist()
        Aa_l = Aa.tolist() if Aa is not None else None
        Ba_l = Ba.tolist() if Ba is not None else None
        oaddr_l = oaddr.tolist()
        emit_overhead = m.emit_loop_overhead
        emit_load, emit_op, emit_store = m.emit_load, m.emit_op, m.emit_store
        for bi in range(nb):
            for i in range(Mm):
                a_row = Aa_l[bi][i] if Aa_l is not None else None
                ad_row = Ad_l[bi][i]
                for j in range(Nn):
                    b_row = Ba_l[bi][j] if Ba_l is not None else None
                    bd_row = Bd_l[bi][j]
                    acc = emit_op("mov", tag, ((SRC_IMM, 0),))
                    for k in range(K):
                        emit_overhead()
                        sa = ((SRC_REG, emit_load(a_row[k], tag, asz))
                              if a_row is not None else (SRC_IMM, ad_row[k]))
                        sb = ((SRC_REG, emit_load(b_row[k], tag, bsz))
                              if b_row is not None else (SRC_IMM, bd_row[k]))
                        prod = emit_op("mul", tag, (sa, sb))
                        acc = emit_op("add", tag,
                                      ((SRC_REG, acc), (SRC_REG, prod)),
                                      dst=acc)
                    emit_store(oaddr_l[bi][i][j], acc, tag, osize)
        return Value(out_data, out_addr)

    # ------------------------------------------------------- copy helpers
    def _copy_to_new_buffer(self, src: Value, out_data: np.ndarray) -> Value:
        """Materializing copy (concat / pad / dynamic slices): load+store."""
        m = self.m
        out_data = np.asarray(out_data)
        out_addr = m.alloc(out_data.shape, out_data.dtype)
        tag = _dtype_tag(out_data.dtype)
        size = _itemsize(out_data.dtype)
        sa = src.addr.ravel() if src.addr is not None else None
        sd = np.asarray(src.data).ravel()
        oa = out_addr.ravel()
        for i in range(out_data.size):
            m.emit_loop_overhead()
            if sa is None:
                r = m.emit_op("mov", tag, ((SRC_IMM, sd[i].item()),))
            else:
                r = m.emit_load(int(sa[i]), tag, size)
            m.emit_store(int(oa[i]), r, tag, size)
        return Value(out_data, out_addr)

    # ------------------------------------------------------------- gather
    def _gather_pointer_chase(self, operand: Value, out_data: np.ndarray,
                              gathered_addrs: np.ndarray,
                              index_srcs: Optional[Value]) -> Value:
        """Emit idx-load + address-arith + data-load per gathered element."""
        m = self.m
        out_data = np.asarray(out_data)
        out_addr = m.alloc(out_data.shape, out_data.dtype)
        tag = _dtype_tag(out_data.dtype)
        size = _itemsize(out_data.dtype)
        ia = (index_srcs.addr.ravel() if index_srcs is not None
              and index_srcs.addr is not None else None)
        id_flat = (np.asarray(index_srcs.data).ravel()
                   if index_srcs is not None else None)
        ga = gathered_addrs.ravel()
        oa = out_addr.ravel()
        n_idx = len(id_flat) if id_flat is not None else 0
        for i in range(out_data.size):
            m.emit_loop_overhead()
            # the index value itself is loaded (pointer chasing), then one
            # address-arith op, then the dependent data load
            if ia is not None:
                ri = m.emit_load(int(ia[i % n_idx]), "i", 4)
                m.emit_op("agen", "i", ((SRC_REG, ri), (SRC_IMM, 0)))
            r = m.emit_load(int(ga[i]), tag, size)
            m.emit_store(int(oa[i]), r, tag, size)
        return Value(out_data, out_addr)

    # ================================================================ eqns
    def eqn(self, eqn, invals: List[Value]) -> List[Value]:
        prim = eqn.primitive.name
        params = eqn.params

        # ---- elementwise binaries / unaries (hottest dispatch first: every
        # branch below keys on disjoint prim names, so order is free) -------
        ew = _EW_BINOP.get(prim)
        if ew is not None:
            op, np_fn = ew
            out = np_fn(np.asarray(invals[0].data), np.asarray(invals[1].data))
            out = np.asarray(out, dtype=eqn.outvars[0].aval.dtype)
            return [self._elementwise(op, invals, out)]
        ew = _EW_UNOP.get(prim)
        if ew is not None:
            op, np_fn = ew
            out = np_fn(np.asarray(invals[0].data))
            out = np.asarray(out, dtype=eqn.outvars[0].aval.dtype)
            return [self._elementwise(op, invals, out)]

        # ---- views: no instructions --------------------------------------
        if prim in ("reshape", "squeeze", "expand_dims"):
            shape = params.get("new_sizes") or params.get("shape") or \
                eqn.outvars[0].aval.shape
            v = invals[0]
            return [Value(np.asarray(v.data).reshape(shape),
                          v.addr.reshape(shape) if v.addr is not None else None)]
        if prim == "dynamic_slice":
            operand, *starts = invals
            sizes = params["slice_sizes"]
            st = [int(s.data) for s in starts]
            st = [max(0, min(s, operand.data.shape[i] - sizes[i]))
                  for i, s in enumerate(st)]
            sl = tuple(slice(s, s + z) for s, z in zip(st, sizes))
            v = invals[0]
            # runtime offset: the slice is a view, address-arith is implicit
            return [Value(np.asarray(v.data)[sl],
                          v.addr[sl] if v.addr is not None else None)]
        if prim == "select_n":
            # pure element selection — numpy is bit-exact with XLA here, and
            # skipping the per-eqn dispatch matters inside scan/while bodies
            pred, *cases = invals
            pd = np.asarray(pred.data)
            cds = [np.asarray(c.data) for c in cases]
            if pd.dtype == bool and len(cds) == 2:
                out = np.where(pd, cds[1], cds[0])
            elif len(cds) < 32:                    # np.choose's arity limit
                out = np.choose(pd.astype(np.int64), cds)
            else:
                out = jax.lax.select_n(pd, *cds)
            return [self._elementwise("sel", [pred] + list(cases),
                                      np.asarray(out))]
        if prim == "broadcast_in_dim":
            shape = params["shape"]
            bdims = params["broadcast_dimensions"]
            v = invals[0]
            src = np.asarray(v.data)
            expand = [1] * len(shape)
            for i, d in enumerate(bdims):
                expand[d] = src.shape[i]
            data = np.broadcast_to(src.reshape(expand), shape)
            addr = (np.broadcast_to(v.addr.reshape(expand), shape)
                    if v.addr is not None else None)
            return [Value(data, addr)]
        if prim == "convert_element_type":
            new_dt = params["new_dtype"]
            v = invals[0]
            out = np.asarray(v.data).astype(new_dt)
            if v.addr is None:
                return [Value(out, None)]
            # conversion happens in-register per element (mov)
            return [self._elementwise("mov", [v], out)]

        # ---- call-like: inline ------------------------------------------
        if prim in _CALL_PRIMS:
            sub = params.get("jaxpr") or params.get("call_jaxpr")
            if hasattr(sub, "jaxpr"):
                return self.run(sub.jaxpr, sub.consts, list(invals))
            return self.run(sub, (), list(invals))

        # ---- control flow ------------------------------------------------
        if prim == "while":
            return self._while(eqn, invals)
        if prim == "scan":
            return self._scan(eqn, invals)
        if prim == "cond":
            return self._cond(eqn, invals)

        if prim == "transpose":
            perm = params["permutation"]
            v = invals[0]
            return [Value(np.transpose(v.data, perm),
                          np.transpose(v.addr, perm) if v.addr is not None else None)]
        if prim == "rev":
            dims = params["dimensions"]
            v = invals[0]
            sl = tuple(slice(None, None, -1) if i in dims else slice(None)
                       for i in range(np.asarray(v.data).ndim))
            return [Value(np.asarray(v.data)[sl],
                          v.addr[sl] if v.addr is not None else None)]
        if prim == "slice":
            v = invals[0]
            sl = tuple(slice(b, e, s) for b, e, s in
                       zip(params["start_indices"], params["limit_indices"],
                           params["strides"] or [1] * len(params["start_indices"])))
            return [Value(np.asarray(v.data)[sl],
                          v.addr[sl] if v.addr is not None else None)]
        if prim in ("stop_gradient", "copy"):
            return [invals[0]]

        if prim == "iota":
            shape = eqn.outvars[0].aval.shape
            dt = eqn.outvars[0].aval.dtype
            dim = params.get("dimension", 0)
            n = shape[dim] if shape else 0
            base = np.arange(n, dtype=dt)
            expand = [1] * len(shape)
            expand[dim] = n
            data = np.broadcast_to(base.reshape(expand), shape)
            return [Value(data, None)]                  # generated: immediates

        # ---- select / clamp ----------------------------------------------
        if prim == "clamp":
            lo, x, hi = invals
            out = np.clip(np.asarray(x.data), np.asarray(lo.data),
                          np.asarray(hi.data))
            return [self._elementwise("sel", [lo, x, hi], np.asarray(out))]

        if prim == "integer_pow":
            y = params["y"]
            out = np.power(np.asarray(invals[0].data), y)
            return [self._elementwise("mul", invals, out)]

        # ---- reductions -----------------------------------------------------
        if prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                    "reduce_and", "reduce_or"):
            axes = tuple(params["axes"])
            x = np.asarray(invals[0].data)
            np_fn = {"reduce_sum": np.sum, "reduce_max": np.max,
                     "reduce_min": np.min, "reduce_prod": np.prod,
                     "reduce_and": np.all, "reduce_or": np.any}[prim]
            out = np.asarray(np_fn(x, axis=axes),
                             dtype=eqn.outvars[0].aval.dtype)
            op = {"reduce_sum": "add", "reduce_max": "max", "reduce_min": "min",
                  "reduce_prod": "mul", "reduce_and": "and",
                  "reduce_or": "or"}[prim]
            init = {"add": 0, "max": float("-inf") if x.dtype.kind == "f" else np.iinfo(x.dtype).min,
                    "min": float("inf") if x.dtype.kind == "f" else np.iinfo(x.dtype).max,
                    "mul": 1, "and": True, "or": False}[op]
            return [self._reduce(op, invals[0], axes, out, init)]
        if prim in ("argmax", "argmin"):
            axis = params["axes"][0]
            np_fn = np.argmax if prim == "argmax" else np.argmin
            out = np.asarray(np_fn(np.asarray(invals[0].data), axis=axis),
                             dtype=eqn.outvars[0].aval.dtype)
            cmp = np.greater if prim == "argmax" else np.less
            return [self._argreduce(cmp, invals[0], axis, out)]
        if prim == "cumsum":
            # sequential scan along axis: acc chains (variant c)
            axis = params["axis"]
            x = np.asarray(invals[0].data)
            out = np.cumsum(x, axis=axis).astype(eqn.outvars[0].aval.dtype)
            return [self._elementwise("add", [invals[0]], out)]
        if prim in ("cummax", "cummin"):
            axis = params["axis"]
            fn = np.maximum.accumulate if prim == "cummax" else np.minimum.accumulate
            out = fn(np.asarray(invals[0].data), axis=axis)
            return [self._elementwise("max", [invals[0]], out)]

        # ---- matmul ---------------------------------------------------------
        if prim == "dot_general":
            dnums = params["dimension_numbers"]
            A, B = np.asarray(invals[0].data), np.asarray(invals[1].data)
            out = jax.lax.dot_general(A, B, dnums)  # shape/value oracle (on CPU)
            out = np.asarray(out, dtype=eqn.outvars[0].aval.dtype)
            return [self._dot_general(invals[0], invals[1], dnums, out)]

        # ---- data movement --------------------------------------------------
        if prim == "concatenate":
            dim = params["dimension"]
            datas = [np.asarray(v.data) for v in invals]
            out = np.concatenate(datas, axis=dim)
            # one materializing copy; source addresses stacked as views
            srcs_addr = []
            for v, d in zip(invals, datas):
                srcs_addr.append(v.addr if v.addr is not None
                                 else np.full(d.shape, -1, np.int64))
            src_addr = np.concatenate(srcs_addr, axis=dim)
            merged = Value(out, None)
            if all(v.addr is None for v in invals):
                return [merged]
            fake = Value(out, src_addr)
            # elements with addr -1 come from immediates: emit mov+store
            return [self._concat_copy(fake, out)]
        if prim == "pad":
            v, pv = invals
            cfgp = params["padding_config"]
            out = np.asarray(jax.lax.pad(np.asarray(v.data),
                                         np.asarray(pv.data), cfgp))
            fake = self._pad_addr_view(v, pv, cfgp, out)
            return [self._concat_copy(fake, out)]

        if prim == "gather":
            operand, indices = invals
            out = np.asarray(_jitted_gather(
                params["dimension_numbers"], params["slice_sizes"],
                params.get("mode"))(np.asarray(operand.data),
                                    np.asarray(indices.data)))
            if operand.addr is None:
                return [self._copy_to_new_buffer(Value(out, None), out)]
            # gather flat element ids (int32, x64-safe), then map to addresses
            ids = np.arange(np.asarray(operand.data).size,
                            dtype=np.int32).reshape(np.asarray(operand.data).shape)
            gids = np.asarray(_jitted_gather(
                params["dimension_numbers"], params["slice_sizes"],
                jax.lax.GatherScatterMode.CLIP)(ids,
                                                np.asarray(indices.data)))
            gaddr = operand.addr.ravel()[gids.ravel()].reshape(out.shape)
            return [self._gather_pointer_chase(operand, out, gaddr, indices)]
        if prim in ("scatter", "scatter-add", "scatter_add"):
            return [self._scatter(eqn, invals)]

        if prim == "dynamic_update_slice":
            operand, update, *starts = invals
            st = [int(np.asarray(s.data)) for s in starts]
            od = np.asarray(operand.data)
            ud = np.asarray(update.data)
            st = [max(0, min(s, od.shape[i] - ud.shape[i]))
                  for i, s in enumerate(st)]
            out = od.copy()
            sl = tuple(slice(s, s + z) for s, z in zip(st, ud.shape))
            out[sl] = ud
            if operand.addr is None:
                base = self.m.materialize(Value(od, None))
            else:
                base = operand
            # in-place update: store the update elements into the base buffer
            self._store_region(base, update, sl)
            new = Value(out, base.addr)
            return [new]

        if prim in ("sort",):
            # small sorts appear in argsort-based code; price as n log n cmp+sel
            xs = [np.asarray(v.data) for v in invals]
            outs = jax.lax.sort(xs, dimension=params.get("dimension", -1),
                                num_keys=params.get("num_keys", 1))
            outs = outs if isinstance(outs, (list, tuple)) else [outs]
            res = []
            for v, o in zip(invals, outs):
                res.append(self._copy_to_new_buffer(v, np.asarray(o)))
            return res

        if prim in ("random_seed", "random_wrap", "random_bits", "random_unwrap"):
            # PRNG lowering: price as elementwise int ops on the output
            out_aval = eqn.outvars[0].aval
            out = np.zeros(out_aval.shape, dtype=np.uint32)
            return [Value(out, None)]

        raise NotImplementedError(
            f"trace VM: unsupported primitive '{prim}' "
            f"(params={list(params)}) — extend core/trace.py or rewrite the workload")

    # ------------------------------------------------------- concat helper
    def _concat_copy(self, fake: Value, out: np.ndarray) -> Value:
        m = self.m
        out_addr = m.alloc(out.shape, out.dtype)
        tag = _dtype_tag(out.dtype)
        size = _itemsize(out.dtype)
        sa = fake.addr.ravel()
        sd = out.ravel()
        oa = out_addr.ravel()
        for i in range(out.size):
            m.emit_loop_overhead()
            if sa[i] < 0:
                r = m.emit_op("mov", tag, ((SRC_IMM, sd[i].item()),))
            else:
                r = m.emit_load(int(sa[i]), tag, size)
            m.emit_store(int(oa[i]), r, tag, size)
        return Value(out, out_addr)

    def _pad_addr_view(self, v: Value, pv: Value, cfgp, out: np.ndarray) -> Value:
        addr = np.full(out.shape, -1, np.int64)
        sl = tuple(slice(lo, lo + (s - 1) * (st + 1) + 1, st + 1)
                   for (lo, hi, st), s in zip(cfgp, np.asarray(v.data).shape))
        if v.addr is not None:
            addr[sl] = v.addr
        return Value(out, addr)

    def _store_region(self, base: Value, update: Value, sl) -> None:
        m = self.m
        tgt_addr = base.addr[sl]
        ud = np.asarray(update.data)
        tag = _dtype_tag(ud.dtype)
        size = _itemsize(ud.dtype)
        ua = update.addr.ravel() if update.addr is not None else None
        udf = ud.ravel()
        ta = tgt_addr.ravel()
        for i in range(ud.size):
            m.emit_loop_overhead()
            if ua is None:
                r = m.emit_op("mov", tag, ((SRC_IMM, udf[i].item()),))
            else:
                r = m.emit_load(int(ua[i]), tag, size)
            m.emit_store(int(ta[i]), r, tag, size)

    def _scatter(self, eqn, invals: List[Value]) -> Value:
        operand, indices, updates = invals
        dnums = eqn.params["dimension_numbers"]
        is_add = eqn.primitive.name in ("scatter-add", "scatter_add")
        od = np.asarray(operand.data)
        idx = np.asarray(indices.data)
        ud = np.asarray(updates.data)
        base = operand if operand.addr is not None else self.m.materialize(operand)
        # destination flat ids via a marker scatter (x64-safe int32 trick);
        # duplicate destinations keep the last writer — pricing approximation.
        marker = np.asarray(_jitted_scatter(
            False, dnums, jax.lax.GatherScatterMode.CLIP)(
            np.full(od.shape, -1, np.int32), idx,
            np.arange(ud.size, dtype=np.int32).reshape(ud.shape)))
        dest_flat = np.full(ud.size, -1, np.int64)
        mk = marker.ravel()
        sel = mk >= 0
        dest_flat[mk[sel]] = np.nonzero(sel)[0]
        if is_add:
            res = np.asarray(_jitted_scatter(
                True, dnums, jax.lax.GatherScatterMode.CLIP)(od, idx, ud))
        else:
            # plain scatter: the marker already resolved the written cells
            # (and their last writer), so the result is one fancy-index
            # assignment — element movement only, bit-exact with the lax
            # scatter the marker came from
            res = od.copy()
            res.ravel()[np.nonzero(sel)[0]] = ud.ravel()[mk[sel]]
            res = np.asarray(res)
        m = self.m
        tag = _dtype_tag(ud.dtype)
        size = _itemsize(ud.dtype)
        ua = updates.addr.ravel() if updates.addr is not None else None
        udf = ud.ravel()
        ia = indices.addr.ravel() if indices.addr is not None else None
        baddr = base.addr.ravel()
        for i in range(ud.size):
            if dest_flat[i] < 0:
                continue
            m.emit_loop_overhead()
            if ia is not None:
                m.emit_load(int(ia[i % ia.size]), "i", 4)
                m.emit_op("agen", "i", ((SRC_IMM, 0),))
            if ua is None:
                r = m.emit_op("mov", tag, ((SRC_IMM, udf[i].item()),))
            else:
                r = m.emit_load(int(ua[i]), tag, size)
            tgt = int(baddr[dest_flat[i]])
            if is_add:
                rold = m.emit_load(tgt, tag, size)
                r = m.emit_op("add", tag, ((SRC_REG, rold), (SRC_REG, r)))
            m.emit_store(tgt, r, tag, size)
        return Value(res, base.addr)

    # ------------------------------------------------------- control flow
    def _while(self, eqn, invals: List[Value]) -> List[Value]:
        params = eqn.params
        cond_j, body_j = params["cond_jaxpr"], params["body_jaxpr"]
        nc, nb = params["cond_nconsts"], params["body_nconsts"]
        cconsts = invals[:nc]
        bconsts = invals[nc:nc + nb]
        carry = list(invals[nc + nb:])
        it = 0
        self.m.push_loop(key=("while", id(body_j.jaxpr)))
        try:
            while True:
                pred = self.run(cond_j.jaxpr, cond_j.consts, cconsts + carry)[0]
                self.m.emit_branch()
                if not bool(np.asarray(pred.data)):
                    break
                carry = self.run(body_j.jaxpr, body_j.consts, bconsts + carry)
                self.m.next_iteration()
                it += 1
                if it > 1_000_000:
                    raise RuntimeError("while loop runaway in trace VM")
        finally:
            self.m.pop_loop()
        return carry

    def _scan(self, eqn, invals: List[Value]) -> List[Value]:
        params = eqn.params
        j = params["jaxpr"]
        n_consts, n_carry = params["num_consts"], params["num_carry"]
        length = params["length"]
        consts = invals[:n_consts]
        carry = list(invals[n_consts:n_consts + n_carry])
        xs = invals[n_consts + n_carry:]
        ys_acc: List[List[Value]] = None
        order = range(length - 1, -1, -1) if params.get("reverse") else range(length)
        self.m.push_loop(key=("scan", id(j.jaxpr)))
        try:
            for t in order:
                x_t = []
                for x in xs:
                    d = np.asarray(x.data)[t]
                    a = x.addr[t] if x.addr is not None else None
                    x_t.append(Value(d, a))
                self.m.emit_branch()
                outs = self.run(j.jaxpr, j.consts, consts + carry + x_t)
                carry = outs[:n_carry]
                ys = outs[n_carry:]
                if ys_acc is None:
                    ys_acc = [[] for _ in ys]
                for acc, y in zip(ys_acc, ys):
                    acc.append(y)
                self.m.next_iteration()
        finally:
            self.m.pop_loop()
        ys_out: List[Value] = []
        for acc in (ys_acc or []):
            if params.get("reverse"):
                acc = acc[::-1]
            data = np.stack([np.asarray(v.data) for v in acc])
            if all(v.addr is not None for v in acc):
                addr = np.stack([v.addr for v in acc])
            else:
                addr = None
            ys_out.append(Value(data, addr))
        return carry + ys_out

    def _cond(self, eqn, invals: List[Value]) -> List[Value]:
        branches = eqn.params["branches"]
        idx = int(np.asarray(invals[0].data))
        idx = max(0, min(idx, len(branches) - 1))
        self.m.emit_branch()
        br = branches[idx]
        return self.run(br.jaxpr, br.consts, list(invals[1:]))


# ======================================================================
# Public API
# ======================================================================
@dataclasses.dataclass
class StructuralTrace:
    """Geometry-independent half of a traced program: the structural
    columns plus the interpreter's concrete outputs.  One of these is
    built per workload; :func:`attach_cache_results` replays its memory
    stream through a cache hierarchy to produce the (much cheaper)
    per-geometry :class:`TraceResult`."""
    columns: ColumnarTrace
    outputs: List[np.ndarray]

    @property
    def n_instructions(self) -> int:
        return len(self.columns)


class TraceResult:
    """One traced (program, cache geometry) pair: the columnar CIQ with
    memory-response columns filled, the replayed cache hierarchy (for its
    statistics), and the program outputs.  ``rut`` / ``iht`` are derived
    views, reconstructed vectorized on first access."""

    __slots__ = ("trace", "cache", "outputs", "structural")

    def __init__(self, trace: ColumnarTrace, cache: CacheHierarchy,
                 outputs: List[np.ndarray],
                 structural: Optional[StructuralTrace] = None):
        self.trace = trace
        self.cache = cache
        self.outputs = outputs
        self.structural = structural

    @property
    def rut(self) -> Dict[int, List[int]]:
        return self.trace.rut

    @property
    def iht(self) -> Dict[int, List[Tuple[int, int]]]:
        return self.trace.iht

    @property
    def n_instructions(self) -> int:
        return len(self.trace)

    def mem_accesses(self) -> int:
        return self.trace.mem_accesses()


def trace_structural(fn: Callable, *args, n_regs: int = 24,
                     limits: TraceLimits = TraceLimits()) -> StructuralTrace:
    """Lower ``fn(*args)`` to the structural instruction columns (no cache
    model involved — the stream is identical under every geometry)."""
    closed = jax.make_jaxpr(fn)(*args)
    machine = Machine(n_regs=n_regs, limits=limits)
    interp = TraceInterpreter(machine)
    arg_vals = [machine.store_const(np.asarray(a))
                for a in jax.tree_util.tree_leaves(args)]
    outs = interp.run(closed.jaxpr, closed.consts, arg_vals)
    return StructuralTrace(machine.b.finish(machine.n_regs),
                           [np.asarray(v.data) for v in outs])


def attach_cache_results(st: StructuralTrace,
                         cache_levels: Tuple[CacheConfig, ...] = (L1_32K,
                                                                  L2_256K)
                         ) -> TraceResult:
    """Replay the structural trace's memory stream through a fresh cache
    hierarchy, producing the per-geometry level/hit/bank/MSHR columns —
    byte-identical to recording the accesses at emission time, at a
    fraction of the cost of re-interpreting the program."""
    return attach_cache_results_batch(st, [cache_levels])[0]


def attach_cache_results_batch(st: StructuralTrace,
                               geometries: Sequence[Tuple[CacheConfig, ...]]
                               ) -> List[TraceResult]:
    """Replay one structural trace under many cache geometries.

    The structural columns are shared; each geometry only needs its own
    level/hit/bank/MSHR columns.  Under ``EVA_CIM_ACCEL=jax`` every
    geometry comes out of one batched accelerator replay
    (:func:`repro.core.accel.replay_columns`, differentially tested
    bit-exact against :meth:`CacheHierarchy.replay`); the numpy path —
    and any batch the accelerator declines — replays per geometry."""
    from repro.core import accel

    ct = st.columns
    mem_idx = np.flatnonzero(ct.mem_mask)
    addrs = ct.addr[mem_idx]
    is_writes = ct.op[mem_idx] == OP_STORE
    batched = accel.replay_columns(addrs, is_writes, list(geometries))
    out = []
    for gi, cache_levels in enumerate(geometries):
        hier = CacheHierarchy(cache_levels)
        if batched is not None and batched[gi] is not None:
            lvl, hit, bank, mshr, counters = batched[gi]
            hier.restore_counters(counters)   # sets stay cold, like the
        else:                                 # store's rehydration path
            lvl, hit, bank, mshr = hier.replay(addrs, is_writes)
        level_col = np.zeros(ct.n, np.int8)
        hit_col = np.full(ct.n, -1, np.int8)
        bank_col = np.full(ct.n, -1, np.int16)
        mshr_col = np.zeros(ct.n, bool)
        level_col[mem_idx] = lvl
        hit_col[mem_idx] = hit
        bank_col[mem_idx] = bank
        mshr_col[mem_idx] = mshr
        out.append(TraceResult(ct.with_mem_results(level_col, hit_col,
                                                   bank_col, mshr_col),
                               hier, st.outputs, structural=st))
    return out


def trace_program(fn: Callable, *args,
                  cache_levels: Tuple[CacheConfig, ...] = (L1_32K, L2_256K),
                  n_regs: int = 24,
                  limits: TraceLimits = TraceLimits()) -> TraceResult:
    """Run ``fn(*args)`` on the trace VM; returns the CIQ + probe tables.

    ``args`` are treated as memory-resident program inputs (like benchmark
    data loaded before the region of interest); jaxpr literals and iota
    lower to immediates.
    """
    return attach_cache_results(trace_structural(fn, *args, n_regs=n_regs,
                                                 limits=limits),
                                cache_levels)
