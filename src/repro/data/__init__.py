from repro.data.pipeline import (DataConfig, ShardedTokenPipeline,
                                 write_synthetic_corpus)
