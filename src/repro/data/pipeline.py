"""Deterministic, host-sharded, seekable token pipeline.

Production shape: a corpus is a set of binary shards of int32 tokens; each
host reads only its shard slice (``host_id``/``num_hosts``), batches are
cut deterministically from a counter so that (a) every host produces the
same global batch layout without communication, and (b) restart-from-step-k
is exact — the pipeline is a pure function of (config, step), the property
fault tolerance needs (no data-order drift after preemption).

Without a corpus on disk, a seeded synthetic stream provides the same
interface (and the same seekability) for smoke tests and CPU examples.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    corpus_dir: Optional[str] = None     # None => synthetic stream
    host_id: int = 0
    num_hosts: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def write_synthetic_corpus(path: str, *, vocab_size: int, n_tokens: int,
                           n_shards: int = 4, seed: int = 7) -> None:
    """Materialize a reproducible binary corpus (one .bin per shard)."""
    d = pathlib.Path(path)
    d.mkdir(parents=True, exist_ok=True)
    rng = np.random.default_rng(seed)
    per = n_tokens // n_shards
    # a Markov-ish stream so models have something learnable
    trans = rng.integers(0, vocab_size, (vocab_size,), dtype=np.int32)
    for s in range(n_shards):
        toks = np.empty((per,), np.int32)
        t = rng.integers(0, vocab_size)
        for i in range(per):
            t = trans[t] if rng.random() < 0.7 else rng.integers(0, vocab_size)
            toks[i] = t
        (d / f"shard_{s:05d}.bin").write_bytes(toks.tobytes())


class ShardedTokenPipeline:
    """Deterministic batches: ``batch_at(step)`` is pure in (config, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._tokens: Optional[np.ndarray] = None
        if cfg.corpus_dir is not None:
            shards = sorted(pathlib.Path(cfg.corpus_dir).glob("shard_*.bin"))
            if not shards:
                raise FileNotFoundError(f"no shards under {cfg.corpus_dir}")
            mine = shards[cfg.host_id::cfg.num_hosts]
            self._tokens = np.concatenate([
                np.frombuffer(p.read_bytes(), np.int32) for p in mine])

    # ------------------------------------------------------------ access
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The host's slice of global batch ``step`` (tokens + labels)."""
        cfg = self.cfg
        B, S = cfg.host_batch, cfg.seq_len
        if self._tokens is None:
            rng = np.random.default_rng(
                (cfg.seed, step, cfg.host_id))
            toks = rng.integers(0, cfg.vocab_size, (B, S + 1), dtype=np.int32)
        else:
            n = len(self._tokens) - (S + 1)
            rng = np.random.default_rng((cfg.seed, step))
            starts = rng.integers(0, n, (cfg.global_batch,))
            mine = starts[cfg.host_id * B:(cfg.host_id + 1) * B]
            toks = np.stack([self._tokens[s:s + S + 1] for s in mine])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
