"""Distribution utilities: logical-axis sharding rules over a device mesh."""
from repro.dist import sharding

__all__ = ["sharding"]
