"""Logical-axis sharding: rules mapping model-semantic axis names to mesh axes.

Model code annotates activations with *logical* names only —
``shard(x, "batch", "seq", "embed")`` — and stays mesh-agnostic.  A launch
site builds a rule table with :func:`make_rules` (logical name -> mesh axis
or ``None``) and activates it with :func:`use_rules`; outside an active
context ``shard`` is the identity, so single-device tests and the trace VM
never touch jax sharding machinery.

Parameter / optimizer / input shardings are shape-driven rather than
per-architecture tables: ``param_specs`` partitions each leaf's largest
mesh-divisible dimension across the model axis (embeddings split on vocab,
FFN weights on d_ff, ...), ``opt_state_specs`` additionally spreads the
remaining replicated dimension across the data axis (ZeRO-1-style moment
sharding), and ``batch_input_shardings`` splits the leading batch dimension
across the data axis.  Every rule degrades to replication when a dimension
does not divide evenly, so reduced CPU configs lower unchanged.

Usage::

    from repro.dist import sharding

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = sharding.make_rules(cfg, mesh)            # logical -> mesh axes
    with sharding.use_rules(mesh, rules):
        out = model(params, batch)        # shard() calls now constrain

    pspecs = sharding.param_specs(cfg, mesh, params_shape)
    ospecs = sharding.opt_state_specs(cfg, mesh, params_shape, pspecs)
    inputs = sharding.batch_input_shardings(mesh, batch_spec, rules)

The trace VM and single-device tests never enter ``use_rules``, so every
``shard`` annotation is the identity there — the same model code runs on
the Eva-CiM analysis pipeline and on an 8-device mesh unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical activation axes that map onto the model-parallel mesh axis
_MODEL_AXES = ("heads", "kv_heads", "dff", "vocab", "expert", "embed_out")
# logical axes that stay replicated (sequence / feature dims)
_REPLICATED = ("seq", "embed", "cap")

_state = threading.local()


def _ctx() -> Optional[Tuple[Mesh, Dict[str, Optional[str]]]]:
    stack = getattr(_state, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Dict[str, Optional[str]]):
    """Activate ``rules`` for all :func:`shard` calls in this thread."""
    stack = getattr(_state, "stack", None)
    if stack is None:
        stack = _state.stack = []
    stack.append((mesh, rules))
    try:
        yield
    finally:
        stack.pop()


def _axis_size(mesh: Mesh, axis: Optional[str]) -> int:
    if axis is None:
        return 1
    return int(dict(mesh.shape).get(axis, 1))


def make_rules(cfg, mesh: Mesh, shape=None, strategy: str = "auto"
               ) -> Dict[str, Optional[str]]:
    """Logical-name -> mesh-axis table for ``cfg`` on ``mesh``.

    ``strategy`` "auto"/"2d" uses (data, model) when both exist;
    "data" forces pure data parallelism (model axes replicated).
    """
    axes = dict(mesh.shape)
    data = "data" if axes.get("data", 1) > 1 else None
    model = "model" if axes.get("model", 1) > 1 else None
    if strategy == "data":
        model = None
    rules: Dict[str, Optional[str]] = {"batch": data}
    for name in _MODEL_AXES:
        rules[name] = model
    for name in _REPLICATED:
        rules[name] = None
    return rules


def shard(x, *names: Optional[str]):
    """Constrain ``x``'s sharding by logical axis names (identity when no
    rules are active or a mapped mesh axis does not divide the dim)."""
    ctx = _ctx()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(names) != x.ndim:
        raise ValueError(f"shard(): {len(names)} axis names for a "
                         f"{x.ndim}-d array of shape {x.shape}")
    spec, used = [], set()
    for dim, name in zip(x.shape, names):
        axis = rules.get(name) if name else None
        if axis is None or axis in used or dim % _axis_size(mesh, axis):
            spec.append(None)
        else:
            spec.append(axis)
            used.add(axis)
    if not any(spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------- specs
def _leaf_spec(shape: Tuple[int, ...], axis: Optional[str], size: int) -> P:
    """Partition the largest ``size``-divisible dim of ``shape`` on ``axis``
    (ties pick the trailing dim: output features / vocab)."""
    if axis is None or size <= 1 or len(shape) < 1:
        return P()
    best = None
    for i, d in enumerate(shape):
        if d >= size and d % size == 0 and (best is None or d >= shape[best]):
            best = i
    if best is None:
        return P()
    spec = [None] * len(shape)
    spec[best] = axis
    return P(*spec)


def param_specs(cfg, mesh: Mesh, params_shape, strategy: str = "auto"):
    """PartitionSpec tree for a params pytree (tensor parallelism)."""
    rules = make_rules(cfg, mesh, strategy=strategy)
    axis = rules.get("vocab")                     # the model axis, if enabled
    size = _axis_size(mesh, axis)
    return jax.tree_util.tree_map(
        lambda leaf: _leaf_spec(tuple(leaf.shape), axis, size), params_shape)


def opt_state_specs(cfg, mesh: Mesh, params_shape, pspecs,
                    strategy: str = "auto"):
    """ZeRO-1-style specs for optimizer moments: keep the tensor-parallel
    split and spread one replicated dim across the data axis."""
    data = "data" if _axis_size(mesh, "data") > 1 else None
    dsize = _axis_size(mesh, data)

    def widen(leaf, spec: P):
        shape = tuple(leaf.shape)
        entries = list(spec) + [None] * (len(shape) - len(spec))
        if data is None or data in entries:
            return P(*entries) if any(entries) else P()
        best = None
        for i, d in enumerate(shape):
            if entries[i] is None and d >= dsize and d % dsize == 0 \
                    and (best is None or d >= shape[best]):
                best = i
        if best is not None:
            entries[best] = data
        return P(*entries) if any(entries) else P()

    return jax.tree_util.tree_map(widen, params_shape, pspecs)


def named(mesh: Mesh, specs):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda s: isinstance(s, P))


def batch_input_shardings(mesh: Mesh, batch_spec, rules):
    """Shard the leading (batch) dim of every input leaf on the data axis."""
    axis = rules.get("batch")
    size = _axis_size(mesh, axis)

    def leaf(l):
        shape = tuple(l.shape)
        if axis and shape and shape[0] >= size and shape[0] % size == 0:
            return NamedSharding(mesh, P(axis, *([None] * (len(shape) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(leaf, batch_spec)


def cache_specs(cfg, mesh: Mesh, cache_shape, rules):
    """Specs for stacked decode caches: leaves are (layers, batch, ...) —
    shard the batch dim (axis 1) on the data axis when it divides."""
    axis = rules.get("batch")
    size = _axis_size(mesh, axis)

    def leaf(l):
        shape = tuple(l.shape)
        if axis and len(shape) >= 2 and shape[1] >= size and shape[1] % size == 0:
            spec = [None] * len(shape)
            spec[1] = axis
            return P(*spec)
        return P()

    return jax.tree_util.tree_map(leaf, cache_shape)
