"""repro.dse — parallel design-space exploration for Eva-CiM.

The paper's headline use-case (§VI-D/E) is sweeping cache configurations,
CiM levels, and device technologies to locate the designs with the best
energy/performance trade-off.  This package turns the ad-hoc loops of the
early examples into a subsystem:

  * :mod:`repro.dse.space`   — typed sweep specification (cross-product
    enumeration with named presets for the paper's swept values, host-CPU
    axis included),
  * :mod:`repro.dse.engine`  — executor with a layered analysis cache
    (trace/IDG once per workload+cache, candidate selection once per
    offload config, pricing per point) and thread/process fan-out,
  * :mod:`repro.dse.backends` — pluggable analysis pipelines behind the
    engine (analyze → select → price): the paper's CiM trace/IDG path
    (:class:`CimBackend`, the default) and the TPU-mode jaxpr/HLO fusion
    path (:class:`TpuBackend`) share the engine, cache, store, and
    reporting,
  * :mod:`repro.dse.store`   — persistent content-addressed artifact store
    extending the analysis cache across processes and CLI invocations,
  * :mod:`repro.dse.results` — structured records, JSON/markdown reports,
  * :mod:`repro.dse.pareto`  — Pareto-frontier extraction over arbitrary
    objective sets (non-finite objective values never reach a frontier),
  * :mod:`repro.dse.adaptive` — frontier-driven iterative refinement:
    price a coarse seed, then re-enumerate only the axis neighborhoods of
    non-dominated points instead of the full cross-product.

Quickstart::

    from repro.dse import DSEEngine, SweepSpace

    space = SweepSpace(workloads=("KM", "BFS"),
                       caches=("32K+256K", "64K+2M"),
                       cim_levels=("L1_only", "both"),
                       techs=("sram", "fefet"),
                       hosts=("A9-1GHz", "inorder-1GHz"))
    results = DSEEngine(store="~/.cache/eva-cim").run(space)
    print(results.best("energy_improvement", workload="KM").config_label)
    print(results.to_markdown())
"""
from repro.core.host_model import HOST_PRESETS
from repro.core.tpu_model import TPU_PRESETS
from repro.dse.adaptive import (AdaptiveDSE, AdaptiveResult, RoundEvent,
                                RoundInfo, coarse_seed)
from repro.dse.backends import (AnalysisBackend, CimBackend, TpuBackend,
                                TpuSelection, TpuWorkloadAnalysis,
                                arch_fingerprint)
from repro.dse.engine import AnalysisCache, DSEEngine
from repro.dse.pareto import (dominates, frontier_stable, objective_vector,
                              pareto_front)
from repro.dse.results import SweepRecord, SweepResults
from repro.dse.space import (CACHE_PRESETS, CIM_SETS, LEVEL_PRESETS,
                             CacheOption, HostOption, SweepPoint, SweepSpace,
                             TpuOption, neighborhood, parse_bytes,
                             tpu_neighbors)
from repro.dse.store import (AnalysisStore, StoreFormatError,
                             workload_fingerprint)

__all__ = [
    "AdaptiveDSE", "AdaptiveResult", "AnalysisBackend", "AnalysisCache",
    "AnalysisStore", "CimBackend", "DSEEngine", "RoundEvent", "RoundInfo",
    "StoreFormatError",
    "TpuBackend",
    "TpuSelection", "TpuWorkloadAnalysis", "arch_fingerprint", "coarse_seed",
    "dominates", "frontier_stable", "neighborhood", "objective_vector",
    "pareto_front", "parse_bytes", "tpu_neighbors", "SweepRecord",
    "SweepResults", "CACHE_PRESETS", "CIM_SETS", "HOST_PRESETS",
    "LEVEL_PRESETS", "TPU_PRESETS", "CacheOption", "HostOption", "SweepPoint",
    "SweepSpace", "TpuOption", "workload_fingerprint",
]
