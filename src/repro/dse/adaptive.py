"""Adaptive, frontier-driven design-space refinement.

The paper's DSE figures price full cross-products — fine for the §VI-D/E
grids, hopeless as axes multiply (the 5-axis space is already
``|W|·|C|·|L|·|T|·|H|`` points).  But the question those sweeps answer is
not "what does every point cost"; it is "where is the energy/performance
frontier".  :class:`AdaptiveDSE` exploits that: price a *coarse* seed,
extract the per-workload Pareto frontier, then iteratively re-enumerate
only the **axis neighborhoods** of non-dominated points
(:func:`repro.dse.space.neighborhood`: adjacent cache geometries,
neighboring techs/hosts, CiM-level supersets) — for at most ``max_rounds``
rounds or until the frontier stops moving, whichever comes first.

Three properties make the loop cheap and honest:

  * **Canonical dedup.**  Every candidate is keyed by
    :attr:`~repro.dse.space.SweepPoint.key` (hashable now that
    :class:`~repro.core.host_model.HostModel` is) and priced at most once
    per run, however many frontier neighborhoods propose it.
  * **Warm rounds.**  Rounds price through one
    :class:`~repro.dse.engine.DSEEngine`, so the layered
    :class:`~repro.dse.engine.AnalysisCache` /
    :class:`~repro.dse.store.AnalysisStore` stack applies: a refinement
    round over an already-analyzed ``(workload, cache)`` pair does zero
    trace builds, and with a warm persistent store *every* round does.
  * **Finite frontiers.**  :func:`~repro.dse.pareto.pareto_front` excludes
    non-finite objective values, so one degenerate record can never steer
    refinement into garbage regions.

Usage::

    from repro.dse import AdaptiveDSE, SweepSpace

    full = SweepSpace(workloads=("KM", "BFS"),
                      caches=("32K+256K", "64K+256K", "64K+2M"),
                      cim_levels=("L1_only", "L2_only", "both"),
                      techs=("sram", "fefet"))
    adaptive = AdaptiveDSE(full).run()        # default coarse seed
    print(adaptive.summary())
    for rec in adaptive.frontier:
        print(rec.config_label)

``adaptive.results`` is an ordinary merged
:class:`~repro.dse.results.SweepResults` (each record's ``round`` column
says which refinement round priced it), so all existing reporting works
unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import (Dict, Iterator, List, Optional, Sequence, Set, Tuple,
                    Union)

from repro import obs
from repro.dse.engine import DSEEngine
from repro.dse.pareto import Objective, frontier_stable
from repro.dse.results import SweepRecord, SweepResults
from repro.dse.space import SweepPoint, SweepSpace, neighborhood


def coarse_seed(space: SweepSpace) -> List[SweepPoint]:
    """Default seed for :class:`AdaptiveDSE`: the cheapest corner of the
    cross-product from which every point of ``space`` is reachable by
    neighborhood moves.

    All workloads (frontiers are per-workload — every workload needs a
    starting point), the space's *first* cache geometry / tech / CiM-set /
    host / TPU option (adjacency walks reach the rest), and the space's
    minimal CiM level sets (every level set not strictly containing
    another — level moves only go up, so the seed must start at the bottom
    of the superset lattice)."""
    level_tuples = space._level_tuples()
    minimal = [lv for lv in level_tuples
               if not any(set(other) < set(lv) for other in level_tuples)]
    points: List[SweepPoint] = []
    for w in space.workloads:
        for lv in minimal:
            points.append(SweepPoint(
                index=len(points), workload=w, cache=space.caches[0],
                cim_levels=lv, tech=space.techs[0],
                cim_set=space.cim_sets[0], host=space.hosts[0],
                tpu=space.tpus[0]))
    return points


@dataclasses.dataclass(frozen=True)
class RoundInfo:
    """Cost/effect accounting of one refinement round."""
    round: int                 # 0 = coarse seed
    n_candidates: int          # points proposed (seed size / neighborhoods)
    n_priced: int              # survived dedup and were actually priced
    frontier_size: int         # per-workload frontier after this round
    stable: bool               # frontier unchanged vs the previous round
    stats: Dict[str, int]      # this round's engine counter deltas
    elapsed_s: float


@dataclasses.dataclass(frozen=True)
class RoundEvent:
    """One completed refinement round, emitted as it lands.

    The incremental unit of :meth:`AdaptiveDSE.run_iter` — everything a
    streaming consumer (the DSE service's NDJSON responses, a progress
    bar) needs to report the round without waiting for the run to finish:
    the round's cost accounting, the frontier *after* the round, and the
    merged results so far.  ``results`` is the same accumulating object a
    final :class:`AdaptiveResult` wraps, not a copy.
    """
    info: RoundInfo
    frontier: List[SweepRecord]       # per-workload frontier after the round
    results: SweepResults             # merged results through this round


@dataclasses.dataclass
class AdaptiveResult:
    """Everything one adaptive run produced."""
    results: SweepResults             # all priced points, rounds merged
    rounds: List[RoundInfo]
    frontier: List[SweepRecord]       # final per-workload Pareto frontier
    objectives: Tuple[Objective, ...]
    space_size: int                   # |full cross-product|

    @property
    def n_priced(self) -> int:
        return len(self.results)

    @property
    def savings(self) -> float:
        """How many times fewer points than the full cross-product."""
        return self.space_size / max(1, self.n_priced)

    def summary(self) -> str:
        lines = [f"adaptive DSE: {self.n_priced}/{self.space_size} points "
                 f"priced ({self.savings:.1f}x fewer), "
                 f"{len(self.rounds)} rounds, "
                 f"frontier size {len(self.frontier)}"]
        for r in self.rounds:
            lines.append(
                f"  round {r.round}: {r.n_priced}/{r.n_candidates} new "
                f"points, frontier {r.frontier_size}, "
                f"trace_builds {r.stats.get('trace_builds', 0)}, "
                f"{r.elapsed_s:.2f}s"
                + (" [stable]" if r.stable else ""))
        return "\n".join(lines)

    def to_markdown(self) -> str:
        """Merged multi-round report (adds the round-provenance column)."""
        return self.results.to_markdown(
            columns=("workload", "cache", "cim_levels", "tech", "host",
                     "round", "energy_improvement", "speedup"),
            pareto_objectives=self.objectives)


class AdaptiveDSE:
    """Frontier-driven iterative refinement over a :class:`SweepSpace`.

    ``space`` is the design *universe*: refinement only ever prices points
    whose axis values appear in it, so the result is always comparable to
    (and typically a small subset of) the exhaustive ``space.points()``
    sweep.  ``engine`` defaults to a fresh thread-pool
    :class:`~repro.dse.engine.DSEEngine`; pass one with a ``store`` to
    make rounds nearly free on warm artifacts.  ``max_rounds`` bounds the
    refinement rounds *after* the seed; the loop also stops as soon as the
    frontier is stable across a round (same design points, by
    :attr:`~repro.dse.space.SweepPoint.key`) or a round proposes nothing
    new.
    """

    def __init__(self, space: SweepSpace,
                 engine: Optional[DSEEngine] = None,
                 objectives: Sequence[Objective] = ("energy_improvement",
                                                    "speedup"),
                 max_rounds: int = 8):
        if max_rounds < 0:
            raise ValueError("max_rounds must be >= 0")
        self.space = space
        self.engine = engine or DSEEngine()
        self.objectives = tuple(objectives)
        self.max_rounds = max_rounds
        # per-axis membership of the declared design universe — O(1) checks
        # without materializing the cross-product this module exists to
        # avoid (the grid is only ever *counted*, via len(space))
        self._axis_values = (
            frozenset(space.workloads),
            frozenset(c.levels for c in space.caches),
            frozenset(space._level_tuples()),
            frozenset(space.techs),
            frozenset(space.cim_sets),
            frozenset(space.hosts),
            frozenset(space.tpus),
        )

    # ------------------------------------------------------------ helpers
    def _in_space(self, p: SweepPoint) -> bool:
        w, caches, levels, techs, sets_, hosts, tpus = self._axis_values
        return (p.workload in w and p.cache.levels in caches
                and p.cim_levels in levels and p.tech in techs
                and p.cim_set in sets_ and p.host in hosts
                and p.tpu in tpus)

    def _dedup(self, candidates: Sequence[SweepPoint],
               seen: Set[Tuple]) -> List[SweepPoint]:
        """In-universe candidates not yet priced, analysis-key-grouped
        (adjacent points share trace artifacts / process-pool chunks) with
        first-seen order preserved within a group."""
        groups: Dict[Tuple, List[SweepPoint]] = {}
        for p in candidates:
            if p.key in seen or not self._in_space(p):
                continue
            seen.add(p.key)
            groups.setdefault(p.analysis_key, []).append(p)
        return [p for group in groups.values() for p in group]

    # ---------------------------------------------------------------- run
    def run(self, seed: Optional[Union[SweepSpace, Sequence[SweepPoint]]]
            = None) -> AdaptiveResult:
        """Seed → price → frontier → refine loop.

        ``seed`` may be a coarse :class:`SweepSpace`, an explicit point
        list, or ``None`` for :func:`coarse_seed`.  Drains
        :meth:`run_iter` — streaming consumers iterate that directly and
        get each round as it completes."""
        rounds: List[RoundInfo] = []
        last: Optional[RoundEvent] = None
        for event in self.run_iter(seed):
            rounds.append(event.info)
            last = event
        if last is None:                       # empty seed
            return AdaptiveResult(results=SweepResults(records=[]),
                                  rounds=[], frontier=[],
                                  objectives=self.objectives,
                                  space_size=len(self.space))
        return AdaptiveResult(results=last.results, rounds=rounds,
                              frontier=last.frontier,
                              objectives=self.objectives,
                              space_size=len(self.space))

    def run_iter(self, seed: Optional[Union[SweepSpace,
                                            Sequence[SweepPoint]]] = None
                 ) -> Iterator[RoundEvent]:
        """Generator form of :meth:`run`: yield a :class:`RoundEvent` the
        moment each refinement round's pricing completes — the DSE
        service streams these as NDJSON lines while later rounds are
        still running.  Same loop, same stopping rules, same records."""
        if seed is None:
            candidates: List[SweepPoint] = coarse_seed(self.space)
        elif isinstance(seed, SweepSpace):
            candidates = seed.points()
        else:
            candidates = list(seed)

        outside = [p for p in candidates if not self._in_space(p)]
        if outside:
            raise ValueError(
                f"{len(outside)} seed point(s) lie outside the design "
                f"space (e.g. {outside[0].label!r}); every seed axis value "
                f"must appear in the AdaptiveDSE space — silently dropping "
                f"them would shrink coverage with no warning")

        seen: Set[Tuple] = set()
        priced_points: List[SweepPoint] = []   # aligned with merged records
        merged: Optional[SweepResults] = None
        prev_frontier: Optional[List[SweepRecord]] = None

        for rnd in range(self.max_rounds + 1):
            fresh = self._dedup(candidates, seen)
            if not fresh:
                break                          # nothing new to explore
            # the span closes before the yield: a generator must not hold
            # an open span across a suspension (the consumer's own spans
            # would nest under it and the contextvar reset would cross
            # frames), so each round is traced as a closed unit
            with obs.span("adaptive.round", cat="adaptive", round=rnd,
                          n_candidates=len(candidates),
                          n_fresh=len(fresh)) as rsp:
                res = self.engine.run(fresh)
                res = SweepResults(
                    records=[dataclasses.replace(r, round=rnd)
                             for r in res.records],
                    stats=res.stats, elapsed_s=res.elapsed_s)
                merged = res if merged is None else merged.merge(res)
                priced_points.extend(fresh)

                frontier = merged.pareto(self.objectives)
                # design identity, not objective values: two designs that
                # price identically still count as frontier movement
                stable = frontier_stable(
                    prev_frontier, frontier, self.objectives,
                    key=lambda r: priced_points[r.index].key)
                rsp.set(frontier_size=len(frontier), stable=stable)
            yield RoundEvent(
                info=RoundInfo(
                    round=rnd, n_candidates=len(candidates),
                    n_priced=len(fresh), frontier_size=len(frontier),
                    stable=stable, stats=res.stats,
                    elapsed_s=res.elapsed_s),
                frontier=frontier, results=merged)
            if stable:
                break
            prev_frontier = frontier
            candidates = [nb for rec in frontier
                          for nb in neighborhood(priced_points[rec.index],
                                                 self.space)]
