"""Pluggable analysis backends — the analyze → select → price split as an API.

Eva-CiM's claim is that *one tool chain* answers "does this workload
benefit, at which memory level, with which technology" — and the DSE
engine's three-phase pipeline (expensive config-independent analysis, cheap
per-config selection, trivial pricing) is not specific to the CiM
trace/IDG pipeline at all.  This module names that split:

  :class:`AnalysisBackend`   — the protocol: ``analyze`` (layer 1, once per
  analysis key), ``select`` (layer 2, once per hardware/threshold config),
  ``price`` (per point, never cached), composed by ``evaluate``;

  :class:`CimBackend`        — the paper's pipeline, extracted from the
  engine without behavior change: ``trace_program``/``analyze_trace`` via
  the :class:`~repro.dse.engine.AnalysisCache` CiM layers, Algorithm-1
  candidate selection, ``profile_system`` pricing;

  :class:`TpuBackend`        — the TPU-mode adaptation (DESIGN.md §3): one
  jaxpr/HLO analysis per (workload, shape) —
  :func:`~repro.core.hlo.fusion_candidates` over the arch registry's
  reduced train step plus :func:`~repro.core.hlo_cost.analyze_hlo` over its
  lowered HLO — then per-:class:`~repro.dse.space.TpuOption` fusion
  selection (``min_saved_bytes`` threshold + VMEM fit) and roofline/energy
  pricing on a :class:`~repro.core.tpu_model.TpuChip`.

Both backends run through the same :class:`~repro.dse.engine.DSEEngine`
(``DSEEngine(backend=TpuBackend())``), the same
:class:`~repro.dse.results.SweepResults` reporting, the same persistent
:class:`~repro.dse.store.AnalysisStore` (artifacts are namespaced by
backend name + version stamp, so one cache directory serves both), and the
same :class:`~repro.dse.adaptive.AdaptiveDSE` refinement loop
(:func:`~repro.dse.space.tpu_neighbors` supplies the backend-aware moves).
"""
from __future__ import annotations

import abc
import dataclasses
import hashlib
from typing import Any, Dict, Optional, Sequence, Tuple

from repro import obs
from repro.core.host_model import HostModel
from repro.core.profiler import profile_system
from repro.core.sampling.spec import SAMPLING_VERSION, SamplingSpec
from repro.core.tpu_model import TpuChip, roofline_terms, step_energy_pj
from repro.dse.results import SweepRecord
from repro.dse.space import HostOption, SweepPoint, TpuOption

# Version stamp of the TPU analysis/selection/pricing semantics, mixed into
# every persisted TPU artifact key (the TPU analogue of
# core.trace.TRACE_VM_VERSION + core.offload.ANALYSIS_VERSION).  Bump it
# when fusion_candidates/analyze_hlo interpretation, the selection rule, or
# the artifact schema changes: old TPU artifacts become unreachable while
# every other backend's stay warm.
TPU_ANALYSIS_VERSION = 1


class AnalysisBackend(abc.ABC):
    """One pipeline behind the engine: analyze → select → price.

    ==========  ==============================  ===========================
    phase       memoized by                     CiM / TPU incarnation
    ==========  ==============================  ===========================
    analyze     layer 1 (workload + geometry)   trace+IDG  /  jaxpr+HLO
    select      layer 2 (+ per-config knobs)    Algorithm 1  /  fusion thr
    price       never (cheap, fanned out)       profile_system / roofline
    ==========  ==============================  ===========================

    Backends are small frozen dataclasses: picklable (they ride to
    ``executor="process"`` workers) and stateless — all memoization lives
    in the :class:`~repro.dse.engine.AnalysisCache` they are handed, all
    persistence in the :class:`~repro.dse.store.AnalysisStore` behind it.

    ``name`` namespaces persisted artifacts; ``version`` stamps them (a
    bump invalidates this backend's store entries and no one else's).
    """

    name: str = "abstract"

    @property
    def version(self) -> int:
        return 0

    # ------------------------------------------------------------- phases
    @abc.abstractmethod
    def analyze(self, cache, point: SweepPoint) -> Any:
        """Layer-1 artifact for ``point`` (built once per analysis key)."""

    @abc.abstractmethod
    def select(self, cache, point: SweepPoint, analysis: Any) -> Any:
        """Layer-2 artifact (built once per selection-relevant config)."""

    @abc.abstractmethod
    def price(self, point: SweepPoint, analysis: Any, selection: Any,
              host: HostModel) -> SweepRecord:
        """One priced record — pure function of the two artifacts."""

    # ---------------------------------------------------------- composite
    def evaluate(self, cache, point: SweepPoint,
                 host: HostModel) -> SweepRecord:
        if obs.tracer() is None:           # keep the untraced path bare
            analysis = self.analyze(cache, point)
            selection = self.select(cache, point, analysis)
            return self.price(point, analysis, selection, host)
        with obs.span("backend.evaluate", cat="engine", backend=self.name,
                      workload=point.workload, point=point.label):
            with obs.span("backend.analyze", cat="analysis",
                          backend=self.name, workload=point.workload):
                analysis = self.analyze(cache, point)
            with obs.span("backend.select", cat="select",
                          backend=self.name, workload=point.workload):
                selection = self.select(cache, point, analysis)
            with obs.span("backend.price", cat="price", backend=self.name,
                          workload=point.workload):
                return self.price(point, analysis, selection, host)

    def warm(self, cache, point: SweepPoint) -> None:
        """Build the layer-1 artifact ahead of the pricing fan-out (the
        engine warms each analysis key serially for deterministic build
        order and exactly one expensive pass per key)."""
        self.analyze(cache, point)

    def warm_many(self, cache, points: Sequence[SweepPoint]) -> None:
        """Warm one representative point per analysis key.

        The engine hands over the whole key set at once so backends can
        batch across it; the default is the serial per-key warm."""
        for p in points:
            self.warm(cache, p)


# ======================================================================
# CiM — the paper's pipeline, extracted from the engine unchanged
# ======================================================================
@dataclasses.dataclass(frozen=True)
class CimBackend(AnalysisBackend):
    """Eva-CiM's trace → Algorithm-1 selection → McPAT/DESTINY pricing.

    A thin naming of what ``DSEEngine`` always did: the layer-1/2 memo
    logic (including the persistent-store integration and its version
    stamps, ``TRACE_VM_VERSION`` / ``ANALYSIS_VERSION``) stays in
    :class:`~repro.dse.engine.AnalysisCache`, so records, counters, and
    fig14–17 artifacts are identical to the pre-backend engine.  The
    layer-1 artifact is a columnar
    :class:`~repro.core.trace.TraceResult`: ``analyze`` per (workload,
    geometry) costs one access-stream replay after the first geometry
    (the structural interpretation is shared), and ``price`` is a
    vectorized column scan.

    ``sampling`` (default exact) swaps the whole pipeline for its sampled
    counterpart (:mod:`repro.core.sampling.pipeline`): ``analyze`` becomes
    skim → plan → windowed trace (persisted once per (workload, sampling
    key), independent of geometry) plus one warm-chained replay per
    geometry, ``select`` runs Algorithm 1 per sampled window, and
    ``price`` returns the cluster-weighted estimate with bootstrap CI
    columns.  Exact mode touches none of the sampled code paths —
    records, counters, and cache keys are byte-for-byte the pre-sampling
    ones.
    """

    sampling: SamplingSpec = SamplingSpec()

    name = "cim"

    @property
    def version(self) -> int:
        from repro.core.trace import TRACE_VM_VERSION
        return TRACE_VM_VERSION

    @property
    def variant(self) -> Optional[str]:
        """Memo-key discriminator for engines/services that share one
        process-wide cache across differently-configured backends:
        ``None`` for exact (the pre-sampling identity), else the
        sampling key."""
        return None if self.sampling.is_exact else self.sampling.key()

    def analyze(self, cache, point: SweepPoint):
        if self.sampling.is_exact:
            return cache.trace(point.workload, point.cache)
        return self._sampled_analysis(cache, point, self.sampling)

    def warm_many(self, cache, points: Sequence[SweepPoint]) -> None:
        """Batch the warm pass per workload: under ``EVA_CIM_ACCEL=jax``
        all cache geometries of one workload replay in a single vmapped
        kernel launch (:meth:`AnalysisCache.replay_group`).  Sampled
        backends always take the serial path — the skim/window pass, not
        the replay, dominates, and it runs once per workload either
        way."""
        from repro.core import accel
        if (self.sampling.is_exact and accel.enabled()
                and hasattr(cache, "replay_group")):
            by_wl: Dict[str, list] = {}
            for p in points:
                by_wl.setdefault(p.workload, []).append(p.cache)
            for wl, caches in by_wl.items():
                cache.replay_group(wl, caches)
            return
        for p in points:
            self.warm(cache, p)

    def select(self, cache, point: SweepPoint, analysis):
        if self.sampling.is_exact:
            return cache.offload(point.workload, point.cache,
                                 point.offload_config())
        from repro.core.sampling import pipeline as spl
        cfg = point.offload_config()
        return cache.artifact(
            2, ("cim.sampled", point.workload, self.sampling.key(),
                point.cache.levels, cfg),
            lambda: spl.select_sampled(analysis, cfg))

    def price(self, point: SweepPoint, analysis, selection,
              host: HostModel) -> SweepRecord:
        if point.host is not None:               # host axis: point overrides
            host = point.host.model
            name = point.host.name
        else:
            # collision-safe label for a custom engine-default model too
            name = HostOption.of(host).name
        if not self.sampling.is_exact:
            from repro.core.sampling import pipeline as spl
            est = spl.price_sampled(analysis, selection, self.sampling,
                                    tech=point.tech, host=host)
            return self._record_from_estimate(point, est, host, name)
        result, reshaped = selection
        rep = profile_system(analysis, tech=point.tech, host=host,
                             offload=result, reshaped=reshaped)
        return SweepRecord.from_report(point, rep, host=host, host_name=name)

    # ------------------------------------------------------- sampled path
    def _sampled_structural(self, cache, workload: str, spec: SamplingSpec):
        from repro.core.sampling import pipeline as spl
        skey = spec.key()
        return cache.artifact(
            1, ("cim.sampled", workload, skey),
            lambda: spl.sampled_structural(workload, spec),
            store_spec={"backend": "cim.sampled", "version": self.version,
                        "sampling_version": SAMPLING_VERSION,
                        "workload": workload, "sampling": skey})

    def _sampled_analysis(self, cache, point: SweepPoint,
                          spec: SamplingSpec):
        from repro.core.sampling import pipeline as spl
        ss = self._sampled_structural(cache, point.workload, spec)
        # per-geometry replay is memo-only: cheap to rebuild, and the
        # artifact holds a live CacheHierarchy
        return cache.artifact(
            1, ("cim.sampled.geo", point.workload, spec.key(),
                point.cache.levels),
            lambda: spl.attach_sampled(ss, point.cache.levels))

    def _record_from_estimate(self, point: SweepPoint, est, host: HostModel,
                              host_name: str) -> SweepRecord:
        t, m, ci = est.totals, est.metrics, est.ci
        return SweepRecord(
            index=point.index, workload=point.workload,
            cache=point.cache.name,
            cim_levels="+".join(point.cim_levels),
            tech=point.tech, cim_set=point.cim_set, host=host_name,
            energy_improvement=m["energy_improvement"],
            speedup=m["speedup"], macr=m["macr"], macr_l1=m["macr_l1"],
            base_energy_pj=t["base_energy"], cim_energy_pj=t["cim_energy"],
            base_cycles=t["base_cycles"], cim_cycles=t["cim_cycles"],
            base_runtime_ms=host.runtime_ms(t["base_cycles"]),
            cim_runtime_ms=host.runtime_ms(t["cim_cycles"]),
            processor_ratio=m["processor_ratio"],
            cache_ratio=m["cache_ratio"],
            n_instructions=int(round(t["n_instructions"])),
            n_mem_accesses=int(round(t["mem_accesses"])),
            n_candidates=int(round(t["n_candidates"])),
            n_cim_ops=int(round(t["n_cim_ops"])),
            backend=self.name, sampling=self.sampling.key(),
            energy_improvement_ci=ci["energy_improvement"],
            speedup_ci=ci["speedup"], macr_ci=ci["macr"])

    def evaluate(self, cache, point: SweepPoint,
                 host: HostModel) -> SweepRecord:
        rec = super().evaluate(cache, point, host)
        spec = self.sampling
        if spec.is_exact or not spec.target_ci:
            return rec
        # CI-driven refinement: double the window budget (<= 3 times)
        # until the energy estimate's relative CI half-width meets the
        # target.  Each refined spec has its own cache identity, so
        # re-evaluations of the same point converge to cache hits.
        for _ in range(3):
            rel = (rec.energy_improvement_ci
                   / max(abs(rec.energy_improvement), 1e-9))
            if rel <= spec.target_ci:
                break
            spec = dataclasses.replace(spec, budget=spec.budget * 2)
            refined = dataclasses.replace(self, sampling=spec)
            rec = AnalysisBackend.evaluate(refined, cache, point, host)
        return rec


# ======================================================================
# TPU — jaxpr/HLO fusion analysis, threshold selection, roofline pricing
# ======================================================================
@dataclasses.dataclass(frozen=True)
class TpuCandidate:
    """One VMEM-fusable chain, reduced to the numbers selection/pricing
    need (the jaxpr itself is not persisted)."""
    n_ops: int
    input_bytes: int
    output_bytes: int
    saved_bytes: int

    @property
    def workset_bytes(self) -> int:
        """Resident footprint of the fused kernel: live inputs + outputs +
        the intermediates it keeps in VMEM (saved_bytes counts each
        intermediate's eliminated store+load, i.e. twice its size)."""
        return self.input_bytes + self.output_bytes + self.saved_bytes // 2


@dataclasses.dataclass(frozen=True)
class TpuWorkloadAnalysis:
    """Layer-1 TPU artifact: everything per-(workload, shape) and
    config-independent — picklable, so it persists like a CiM trace."""
    workload: str
    batch: int
    seq_len: int
    flops: float                   # trip-count-aware HLO matmul FLOPs
    total_bytes: int               # jaxpr tensor traffic if nothing fuses
    collective_bytes: float        # per-device collective bytes (0 off-mesh)
    hlo_bytes: float               # HLO top-level op footprint (reporting)
    n_eqns: int
    candidates: Tuple[TpuCandidate, ...]


@dataclasses.dataclass(frozen=True)
class TpuSelection:
    """Layer-2 TPU artifact: which candidates a TpuOption realizes."""
    n_accepted: int
    accepted_ops: int
    saved_bytes: int
    min_saved_bytes: int
    vmem_bytes: float


@dataclasses.dataclass(frozen=True)
class TpuBackend(AnalysisBackend):
    """TPU-mode Eva-CiM: "does this model step benefit from VMEM-resident
    fusion, on which chip, at which aggressiveness".

    Workload names are arch ids from :data:`repro.configs.registry.ARCHS`;
    ``analyze`` traces the arch's *reduced* train step once per
    (workload, batch, seq_len): ``jax.make_jaxpr`` →
    :func:`~repro.core.hlo.fusion_candidates` for the fusable chains, and
    a (compile-free) ``jit(...).lower()`` →
    :func:`~repro.core.hlo_cost.analyze_hlo` for trip-count-aware FLOPs.
    ``select`` realizes the candidates that clear the point's
    :class:`~repro.dse.space.TpuOption` ``min_saved_bytes`` threshold *and*
    fit its (possibly scaled) VMEM.  ``price`` compares the unfused and
    fused steps under the option's chip: roofline bound time
    (:func:`~repro.core.tpu_model.roofline_terms`) and step energy
    (:func:`~repro.core.tpu_model.step_energy_pj`, with the eliminated HBM
    traffic re-priced as VMEM traffic rather than dropped).

    ``default_tpu`` prices points with no ``tpu`` axis value, mirroring
    the engine-default host of the CiM path.
    """

    batch: int = 2
    seq_len: int = 32
    default_tpu: TpuOption = TpuOption.of("v5e")

    name = "tpu"

    @property
    def version(self) -> int:
        return TPU_ANALYSIS_VERSION

    # ------------------------------------------------------------ layer 1
    def _layer1_spec(self, workload: str) -> Dict:
        return {"backend": self.name, "version": self.version,
                "workload": workload,
                "fingerprint": arch_fingerprint(workload),
                "shape": [self.batch, self.seq_len]}

    def analyze(self, cache, point: SweepPoint) -> TpuWorkloadAnalysis:
        key = ("tpu", point.workload, self.batch, self.seq_len)
        return cache.artifact(
            1, key, lambda: self._analyze(point.workload),
            store_spec=self._layer1_spec(point.workload))

    def _analyze(self, workload: str) -> TpuWorkloadAnalysis:
        import jax                         # late: keep repro.dse importable
        import jax.numpy as jnp
        from repro.configs.base import TrainConfig
        from repro.configs.registry import reduced_config
        from repro.core.hlo import fusion_candidates
        from repro.core.hlo_cost import analyze_hlo
        from repro.models import inputs as minputs
        from repro.train import steps as steps_mod

        cfg = reduced_config(workload)
        rng = jax.random.PRNGKey(0)
        state = jax.eval_shape(lambda r: steps_mod.init_train_state(r, cfg),
                               rng)
        batch = minputs.make_train_batch(rng, cfg, batch=self.batch,
                                         seq_len=self.seq_len)
        step = steps_mod.make_train_step(cfg, TrainConfig())
        zeros = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), state)
        jx = jax.make_jaxpr(step)(zeros, batch)
        rep = fusion_candidates(jx)
        cost = analyze_hlo(jax.jit(step).lower(zeros, batch)
                           .as_text(dialect="hlo"))
        return TpuWorkloadAnalysis(
            workload=workload, batch=self.batch, seq_len=self.seq_len,
            flops=cost.flops, total_bytes=rep.total_bytes,
            collective_bytes=cost.collective_total, hlo_bytes=cost.bytes,
            n_eqns=len(jx.jaxpr.eqns),
            candidates=tuple(
                TpuCandidate(c.n_ops, c.input_bytes, c.output_bytes,
                             c.saved_bytes) for c in rep.candidates))

    # ------------------------------------------------------------ layer 2
    def _option(self, point: SweepPoint) -> TpuOption:
        return point.tpu if point.tpu is not None else self.default_tpu

    def select(self, cache, point: SweepPoint,
               analysis: TpuWorkloadAnalysis) -> TpuSelection:
        opt = self._option(point)
        vmem = opt.effective_chip().vmem_bytes
        key = ("tpu", analysis.workload, analysis.batch, analysis.seq_len,
               opt.min_saved_bytes, vmem)
        return cache.artifact(
            2, key, lambda: self._select(analysis, opt.min_saved_bytes, vmem))

    @staticmethod
    def _select(analysis: TpuWorkloadAnalysis, min_saved_bytes: int,
                vmem_bytes: float) -> TpuSelection:
        accepted = [c for c in analysis.candidates
                    if c.saved_bytes >= min_saved_bytes
                    and c.workset_bytes <= vmem_bytes]
        return TpuSelection(
            n_accepted=len(accepted),
            accepted_ops=sum(c.n_ops for c in accepted),
            saved_bytes=sum(c.saved_bytes for c in accepted),
            min_saved_bytes=min_saved_bytes, vmem_bytes=vmem_bytes)

    # ------------------------------------------------------------ pricing
    def price(self, point: SweepPoint, analysis: TpuWorkloadAnalysis,
              selection: TpuSelection, host: HostModel) -> SweepRecord:
        opt = self._option(point)
        chip = opt.effective_chip()
        base_bytes = float(analysis.total_bytes)
        fused_bytes = base_bytes - selection.saved_bytes
        coll = analysis.collective_bytes
        base = roofline_terms(analysis.flops, base_bytes, coll, 1, chip=chip)
        fused = roofline_terms(analysis.flops, fused_bytes, coll, 1,
                               chip=chip)
        base_e = step_energy_pj(analysis.flops, base_bytes, coll, 1,
                                chip=chip)
        fused_e = step_energy_pj(analysis.flops, fused_bytes, coll, 1,
                                 chip=chip)
        # eliminated HBM round-trips still move through VMEM — re-priced,
        # not free (the Eva-CiM analogue: CiM ops still cost array energy)
        fused_total = (fused_e["total_pj"]
                       + selection.saved_bytes * chip.pj_per_vmem_byte)
        macr = (selection.saved_bytes / base_bytes) if base_bytes else 0.0
        # "cycles" columns hold the roofline bound in ns (1 GHz convention),
        # so runtime_ms = cycles / 1e9 * 1e3 matches the CiM records' shape
        return SweepRecord(
            index=point.index, workload=point.workload,
            cache=opt.chip_label, cim_levels="VMEM", tech="tpu",
            cim_set=opt.threshold_label, host="-",
            energy_improvement=(base_e["total_pj"] / fused_total
                                if fused_total else 1.0),
            speedup=base.bound_s / fused.bound_s if fused.bound_s else 1.0,
            macr=macr, macr_l1=macr,
            base_energy_pj=base_e["total_pj"], cim_energy_pj=fused_total,
            base_cycles=base.bound_s * 1e9, cim_cycles=fused.bound_s * 1e9,
            base_runtime_ms=base.bound_s * 1e3,
            cim_runtime_ms=fused.bound_s * 1e3,
            processor_ratio=(base_e["compute_pj"] / base_e["total_pj"]
                             if base_e["total_pj"] else 0.0),
            cache_ratio=(base_e["hbm_pj"] / base_e["total_pj"]
                         if base_e["total_pj"] else 0.0),
            n_instructions=analysis.n_eqns,
            n_mem_accesses=int(analysis.total_bytes),
            n_candidates=len(analysis.candidates),
            n_cim_ops=selection.accepted_ops,
            backend=self.name)


_ARCH_FINGERPRINTS: Dict[str, str] = {}


def arch_fingerprint(workload: str) -> str:
    """Content hash of a TPU workload: the arch id + its *reduced config*
    (every field that shapes the traced step).  Editing a config — layer
    count, widths, MoE/SSM structure — invalidates the persisted analysis;
    unknown archs degrade to a name-only fingerprint."""
    cached = _ARCH_FINGERPRINTS.get(workload)
    if cached is not None:
        return cached
    spec = ""
    try:
        from repro.configs.registry import reduced_config
        spec = repr(reduced_config(workload))
    except Exception:  # noqa: BLE001 — unknown arch / unimportable configs
        spec = ""
    digest = hashlib.sha256(f"{workload}\n{spec}".encode()).hexdigest()[:16]
    _ARCH_FINGERPRINTS[workload] = digest
    return digest
