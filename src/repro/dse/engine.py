"""Sweep executor: memoized trace analysis + fanned-out per-config pricing.

The Eva-CiM pipeline splits cleanly into phases with very different
costs and very different dependence on the swept axes (timings: columnar
core, mid-size Table-IV workload):

  ========================  =====================  ========================
  phase                     depends on             cost
  ========================  =====================  ========================
  structural trace          workload only          ~100 ms (trace VM, once)
  cache replay + flow       + cache geometry       ~20 ms each (columns)
  candidate selection       + cim_levels/cim_set   partition ~100 ms once,
                                                   placement ~ms per config
  pricing (energy/cycles)   + tech, host           ~ms (np.bincount)
  ========================  =====================  ========================

:class:`AnalysisCache` memoizes the layers by their exact dependence
keys — including a per-*workload* structural-trace memo above layer 1, so
a Fig. 14 geometry sweep interprets each program once and only replays
its access stream per geometry — a Fig. 16 technology sweep re-runs
*nothing* but pricing, and a Fig. 15 level sweep re-runs placement only
(the structural candidate partition is shared through the columnar
trace's memo; see :mod:`repro.core.offload`).  Backing the
cache with a persistent :class:`~repro.dse.store.AnalysisStore`
(``AnalysisCache(store=...)`` / ``DSEEngine(store=...)``) extends both
memo layers across *processes*: repeated CLI sweeps and spawned
``executor="process"`` workers load the artifacts from disk instead of
re-tracing.  The :class:`DSEEngine` walks a
:class:`~repro.dse.space.SweepSpace` in deterministic order, warms the
cache once per analysis key, and fans the cheap pricing phase out over a
worker pool ("thread", "process", or "serial") — results always come back
in SweepPoint order regardless of executor scheduling.

The three-phase split itself is owned by a pluggable
:class:`~repro.dse.backends.AnalysisBackend` (``DSEEngine(backend=...)``):
the table above describes the default CiM pipeline
(:class:`~repro.dse.backends.CimBackend`), while
:class:`~repro.dse.backends.TpuBackend` runs the same engine/cache/store
machinery over jaxpr/HLO fusion analyses of the arch registry's train
steps (generic artifacts memoized via :meth:`AnalysisCache.artifact`).
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import os
import pathlib
import shutil
import tempfile
import threading
import time
import weakref
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro import obs
from repro.core.host_model import DEFAULT_HOST, HostModel
from repro.core.offload import (OffloadConfig, OffloadResult, TraceAnalysis,
                                analyze_trace, rehydrate_analysis)
from repro.core.reshape import ReshapedTrace, reshape
from repro.core.trace import (StructuralTrace, TraceResult,
                              attach_cache_results,
                              attach_cache_results_batch, trace_structural)
from repro.dse.backends import AnalysisBackend, CimBackend
from repro.dse.results import SweepRecord, SweepResults
from repro.dse.space import CacheOption, SweepPoint, SweepSpace
from repro.dse.store import AnalysisStore


class AnalysisCache:
    """Layered memo of the config-independent sweep artifacts.

    Layer 1 — ``(workload, cache)``  -> traced program + IDG/flow tables.
    Layer 2 — ``(layer-1 key, offload config)`` -> selected candidates +
    reshaped trace.  Hit/build counters are exposed for tests and reports
    (the "trace analysis ran exactly once per workload" guarantee).

    ``store`` (an :class:`~repro.dse.store.AnalysisStore` or a directory
    path) layers an on-disk lookup between the in-memory memo and a fresh
    build: misses consult the store first, and every artifact built here is
    persisted, so the build counters stay an honest measure of *global*
    analysis work — a warm store means ``trace_builds == 0`` even in a new
    process.
    """

    def __init__(self, store: Optional[Union[AnalysisStore, str,
                                             pathlib.Path]] = None):
        if store is not None and not isinstance(store, AnalysisStore):
            store = AnalysisStore(store)
        self.store = store
        self._lock = threading.RLock()
        self._structural: Dict[str, StructuralTrace] = {}  # lint: guarded-by(_lock)
        self._traces: Dict[Tuple, TraceResult] = {}        # lint: guarded-by(_lock)
        self._analyses: Dict[Tuple, TraceAnalysis] = {}    # lint: guarded-by(_lock)
        self._offloads: Dict[Tuple, Tuple[OffloadResult, ReshapedTrace]] = {}  # lint: guarded-by(_lock)
        self._blobs: Dict[Tuple, Any] = {}  # generic backend artifacts; lint: guarded-by(_lock)
        self._key_locks: Dict[Tuple, threading.Lock] = {}  # lint: guarded-by(_lock)
        self.trace_builds = 0    # lint: guarded-by(_lock)
        self.trace_hits = 0      # lint: guarded-by(_lock)
        self.offload_builds = 0  # lint: guarded-by(_lock)
        self.offload_hits = 0    # lint: guarded-by(_lock)
        self.replay_batches = 0  # lint: guarded-by(_lock)

    def _key_lock(self, key: Tuple) -> threading.Lock:
        """Per-key build lock: concurrent misses on one key build once."""
        with self._lock:
            lk = self._key_locks.get(key)
            if lk is None:
                lk = self._key_locks[key] = threading.Lock()
            return lk

    def _prune_lock(self, key: Tuple) -> None:
        """Release a build lock's table entry once its layer completed.

        The lock exists to serialize the *first* build of a key; after the
        artifact is memoized every later lookup is a plain memo hit, so
        keeping one ``threading.Lock`` per (workload, cache, offload) key
        alive forever only leaks memory across long adaptive runs.
        Threads already blocked on the popped lock still hold a reference
        and proceed normally — they just find the memo populated."""
        with self._lock:
            self._key_locks.pop(key, None)

    # ------------------------------------------------------------ layer 1
    def _structural_trace(self, workload: str) -> StructuralTrace:
        """The geometry-independent trace, interpreted once per workload —
        every cache geometry of a sweep replays its access stream instead
        of re-running the trace VM."""
        from repro.workloads import build          # late: keep core importable
        skey = ("structural", workload)
        with obs.span("cache.trace_vm", cat="trace", workload=workload) as sp:
            with self._key_lock(skey):
                try:
                    with self._lock:
                        st = self._structural.get(workload)
                    if st is None:
                        sp.set(source="build")
                        fn, args = build(workload)
                        st = trace_structural(fn, *args)
                        with self._lock:
                            self._structural[workload] = st
                    else:
                        sp.set(source="memo")
                    return st
                finally:
                    self._prune_lock(skey)

    def trace(self, workload: str, cache: CacheOption) -> TraceResult:
        key = (workload, cache.levels)             # full geometry, not name
        with obs.span("cache.trace", cat="replay", workload=workload,
                      cache=cache.name) as sp, self._key_lock(key):
            try:
                with self._lock:
                    hit = self._traces.get(key)
                    if hit is not None:
                        self.trace_hits += 1
                        sp.set(source="memo", layer=1)
                        return hit
                if self.store is not None:
                    loaded = self.store.load_layer1(workload, cache.levels)
                    if loaded is not None:
                        tr, flow = loaded
                        with self._lock:
                            self._traces[key] = tr
                            if tr.structural is not None \
                                    and workload not in self._structural:
                                self._structural[workload] = tr.structural
                            if flow is not None and key not in self._analyses:
                                self._analyses[key] = rehydrate_analysis(tr,
                                                                         flow)
                        sp.set(source="store", layer=1)
                        return tr
                with self._lock:
                    self.trace_builds += 1
                sp.set(source="build", layer=1)
                tr = attach_cache_results(self._structural_trace(workload),
                                          cache.levels)
                with self._lock:
                    self._traces[key] = tr
                if self.store is not None:
                    self.store.save_layer1(workload, cache.levels, tr)
                return tr
            finally:
                self._prune_lock(key)

    def replay_group(self, workload: str,
                     caches: Sequence[CacheOption]) -> None:
        """Warm layer 1 for every geometry of one workload at once.

        The numpy path (or a single-geometry group) degrades to per-key
        :meth:`trace` calls.  Under ``EVA_CIM_ACCEL=jax`` all geometries
        still missing from memo *and* store are replayed in ONE batched
        accelerator call (:func:`~repro.core.trace.attach_cache_results_batch`
        vmaps the cache state machine across the batch), so a sweep's N
        geometries cost one kernel launch instead of N replays —
        ``replay_batches`` counts those launches.  Counter semantics match
        :meth:`trace`: memo hits bump ``trace_hits``, store loads bump
        neither, and each geometry actually replayed bumps
        ``trace_builds``."""
        uniq: List[CacheOption] = []
        seen = set()
        for c in caches:
            if c.levels not in seen:
                seen.add(c.levels)
                uniq.append(c)
        from repro.core import accel
        if not accel.enabled() or len(uniq) <= 1:
            for c in uniq:
                self.trace(workload, c)
            return
        gkey = ("replay_group", workload) + tuple(c.levels for c in uniq)
        with obs.span("cache.replay_batch", cat="replay", workload=workload,
                      n_geometries=len(uniq)) as gsp, self._key_lock(gkey):
            try:
                missing: List[CacheOption] = []
                for c in uniq:
                    key = (workload, c.levels)
                    with self._lock:
                        if key in self._traces:
                            self.trace_hits += 1
                            continue
                    if self.store is not None:
                        loaded = self.store.load_layer1(workload, c.levels)
                        if loaded is not None:
                            tr, flow = loaded
                            with self._lock:
                                self._traces[key] = tr
                                if tr.structural is not None \
                                        and workload not in self._structural:
                                    self._structural[workload] = tr.structural
                                if flow is not None \
                                        and key not in self._analyses:
                                    self._analyses[key] = \
                                        rehydrate_analysis(tr, flow)
                            continue
                    missing.append(c)
                gsp.set(n_replayed=len(missing),
                        source="build" if missing else "memo")
                if not missing:
                    return
                st = self._structural_trace(workload)
                trs = attach_cache_results_batch(st,
                                                 [c.levels for c in missing])
                with self._lock:
                    self.trace_builds += len(missing)
                    self.replay_batches += 1
                    for c, tr in zip(missing, trs):
                        self._traces[(workload, c.levels)] = tr
                if self.store is not None:
                    for c, tr in zip(missing, trs):
                        self.store.save_layer1(workload, c.levels, tr)
            finally:
                self._prune_lock(gkey)

    def trace_analysis(self, workload: str, cache: CacheOption
                       ) -> TraceAnalysis:
        """IDG/flow artifacts for a trace, built lazily on first use —
        callers that only need the raw trace never pay for the flow index."""
        key = (workload, cache.levels)
        with obs.span("cache.idg", cat="analysis", workload=workload,
                      cache=cache.name) as sp, \
                self._key_lock(("analysis",) + key):
            try:
                with self._lock:
                    hit = self._analyses.get(key)
                if hit is not None:
                    sp.set(source="memo")
                    return hit
                tr = self.trace(workload, cache)
                with self._lock:           # a store hit may have rehydrated it
                    hit = self._analyses.get(key)
                if hit is not None:
                    sp.set(source="store")
                    return hit
                sp.set(source="build")
                analysis = analyze_trace(tr)
                with self._lock:
                    self._analyses[key] = analysis
                if self.store is not None:
                    # upgrade the layer-1 artifact in place: trace + flow
                    self.store.save_layer1(workload, cache.levels, tr,
                                           flow=analysis.flow)
                return analysis
            finally:
                self._prune_lock(("analysis",) + key)

    # ------------------------------------------------------------ layer 2
    def offload(self, workload: str, cache: CacheOption,
                cfg: OffloadConfig) -> Tuple[OffloadResult, ReshapedTrace]:
        # the frozen OffloadConfig is hashable-by-value: using it directly
        # keeps the key complete if new knobs are ever added to it
        key = (workload, cache.levels, cfg)
        with obs.span("cache.select", cat="select", workload=workload,
                      cache=cache.name) as sp, self._key_lock(key):
            try:
                with self._lock:
                    hit = self._offloads.get(key)
                    if hit is not None:
                        self.offload_hits += 1
                        sp.set(source="memo", layer=2)
                        return hit
                if self.store is not None:
                    loaded = self.store.load_layer2(workload, cache.levels,
                                                    cfg)
                    if loaded is not None:
                        with self._lock:
                            self._offloads[key] = loaded
                        sp.set(source="store", layer=2)
                        return loaded
                with self._lock:
                    self.offload_builds += 1
                sp.set(source="build", layer=2)
                analysis = self.trace_analysis(workload, cache)
                result = analysis.select(cfg)
                reshaped = reshape(analysis.trace, result)
                with self._lock:
                    self._offloads[key] = (result, reshaped)
                if self.store is not None:
                    self.store.save_layer2(workload, cache.levels, cfg,
                                           result, reshaped)
                return result, reshaped
            finally:
                self._prune_lock(key)

    # ---------------------------------------------------- generic artifacts
    def artifact(self, layer: int, key: Tuple, build: Callable[[], Any],
                 store_spec: Optional[dict] = None) -> Any:
        """Backend-agnostic layered memo (see :mod:`repro.dse.backends`).

        ``layer`` picks the counter pair the lookup accounts under — 1 for
        the expensive analysis phase (``trace_builds``/``trace_hits``), 2
        for selection (``offload_builds``/``offload_hits``) — so non-CiM
        backends report cost through the exact counters tests and sweep
        reports already assert on.  ``store_spec`` (a JSON-able key spec
        that must include the backend's name + version stamp) additionally
        persists the artifact through the
        :class:`~repro.dse.store.AnalysisStore`: store loads count as
        neither build nor memo hit, mirroring the CiM layers, so
        ``trace_builds == 0`` still means "a warm run did no analysis
        work".  Per-key build locks: concurrent misses build once."""
        builds, hits = (("trace_builds", "trace_hits") if layer == 1
                        else ("offload_builds", "offload_hits"))
        full_key = (layer,) + key
        with obs.span(f"cache.artifact.l{layer}",
                      cat=("analysis" if layer == 1 else "select"),
                      layer=layer, key=str(key[:2])) as sp, \
                self._key_lock(("blob",) + full_key):
            try:
                with self._lock:
                    if full_key in self._blobs:
                        setattr(self, hits, getattr(self, hits) + 1)
                        sp.set(source="memo")
                        return self._blobs[full_key]
                if self.store is not None and store_spec is not None:
                    payload = self.store.load_blob(layer, store_spec)
                    if payload is not None:
                        value = payload["artifact"]
                        with self._lock:
                            self._blobs[full_key] = value
                        sp.set(source="store")
                        return value
                with self._lock:
                    setattr(self, builds, getattr(self, builds) + 1)
                sp.set(source="build")
                value = build()
                with self._lock:
                    self._blobs[full_key] = value
                if self.store is not None and store_spec is not None:
                    self.store.save_blob(layer, store_spec,
                                         {"artifact": value})
                return value
            finally:
                self._prune_lock(("blob",) + full_key)

    def stats(self) -> Dict[str, int]:
        out = {"trace_builds": self.trace_builds,
               "trace_hits": self.trace_hits,
               "offload_builds": self.offload_builds,
               "offload_hits": self.offload_hits,
               "replay_batches": self.replay_batches}
        if self.store is not None:
            out.update(self.store.stats())
        return out


# ======================================================================
# Engine
# ======================================================================
# Per-process worker caches for "process" mode, keyed by the store they
# route through (workers of one run all see the same store, but a process
# pool can outlive one engine/run).
_WORKER_CACHES: Dict[Tuple[Optional[str], Optional[int]], AnalysisCache] = {}


def _worker_chunk(points: Sequence[SweepPoint], host: HostModel,
                  backend: AnalysisBackend,
                  store_root: Optional[str] = None,
                  store_version: Optional[int] = None,
                  trace_ctx: Optional[obs.TraceContext] = None
                  ) -> Tuple[List[SweepRecord], Dict[str, int], List[Dict]]:
    """Price a run of points inside one process-pool worker.

    Workers route every analysis miss through the shared on-disk
    :class:`~repro.dse.store.AnalysisStore` at ``store_root``: the first
    worker to need a key builds it once and publishes the artifact, every
    other process (and every later run) loads it — one *global* analysis
    per key, not one per worker.  ``backend`` is the engine's (pickled
    along: backends are small frozen dataclasses).  Returns the records
    plus this chunk's delta of the cache+store counters, so the parent can
    report true build totals across all workers, plus the finished span
    dicts collected under ``trace_ctx`` (empty when the parent was not
    tracing) for the coordinator's tracer to :func:`repro.obs.ingest`."""
    cache_key = (store_root, store_version)
    cache = _WORKER_CACHES.get(cache_key)
    if cache is None:
        store = (AnalysisStore(store_root, version=store_version)
                 if store_root is not None else None)
        cache = _WORKER_CACHES[cache_key] = AnalysisCache(store=store)
    before = cache.stats()
    spans: List[Dict] = []
    if trace_ctx is not None:
        # spans land in a worker-local tracer keyed to this pid; drain()
        # ships exactly this chunk's spans (workers run chunks serially)
        worker_tracer = obs.enable()
        with obs.attach(trace_ctx):
            with obs.span("worker.chunk", cat="engine",
                          workload=points[0].workload,
                          n_points=len(points), pid=os.getpid()):
                records = [backend.evaluate(cache, p, host) for p in points]
        spans, _ = worker_tracer.drain()
    else:
        records = [backend.evaluate(cache, p, host) for p in points]
    delta = {k: v - before.get(k, 0) for k, v in cache.stats().items()
             if not k.startswith("store_bytes")}   # gauges, not counters
    return records, delta, spans


class DSEEngine:
    """Parallel design-space-exploration executor.

    ``executor``:
      * ``"thread"`` (default) — one shared :class:`AnalysisCache`; pricing
        fans out over threads (pricing is numpy/dict-walking, mostly
        GIL-bound, but trace analysis never repeats: exactly one per
        (workload, cache) per engine).
      * ``"process"`` — points are chunked by analysis key and each chunk
        runs in a spawned worker process (full CPU parallelism across
        workloads).  Workers share artifacts through an on-disk
        :class:`~repro.dse.store.AnalysisStore` — the engine's ``store``
        if it has one, else a per-engine scratch store — so every analysis
        key is built exactly once *globally*, including across repeated
        ``run()`` calls.  Spawn semantics apply: call it from a real
        module (under ``if __name__ == "__main__":`` in scripts), not
        stdin.
      * ``"serial"`` — no pool at all; useful for debugging and exact
        cost accounting.

    ``store`` — a persistent :class:`~repro.dse.store.AnalysisStore` (or a
    directory path) shared across processes and invocations; shorthand for
    ``cache=AnalysisCache(store=...)``.

    ``host`` — the default :class:`~repro.core.host_model.HostModel` used
    to price points that do not carry their own (a
    ``SweepSpace(hosts=...)`` axis overrides it per point).

    ``backend`` — the :class:`~repro.dse.backends.AnalysisBackend` that
    owns the analyze → select → price split behind this engine; defaults
    to the paper's CiM pipeline
    (:class:`~repro.dse.backends.CimBackend`).  Pass
    ``TpuBackend()`` to sweep :class:`~repro.dse.space.TpuOption` axes
    over the arch registry's train steps instead — same engine, caching,
    executors, and reporting.
    """

    def __init__(self, cache: Optional[AnalysisCache] = None,
                 host: HostModel = DEFAULT_HOST,
                 executor: str = "thread",
                 max_workers: Optional[int] = None,
                 store: Optional[Union[AnalysisStore, str,
                                       pathlib.Path]] = None,
                 backend: Optional[AnalysisBackend] = None):
        if executor not in ("thread", "process", "serial"):
            raise ValueError(f"unknown executor {executor!r}")
        if cache is not None and store is not None:
            raise ValueError("pass either cache= or store= (to combine them, "
                             "build AnalysisCache(store=...) yourself)")
        self.analysis = cache or AnalysisCache(store=store)
        self.host = host
        self.backend = backend or CimBackend()
        self.executor = executor
        self.max_workers = max_workers or min(8, os.cpu_count() or 1)
        self._scratch_store: Optional[AnalysisStore] = None

    def _worker_store(self) -> AnalysisStore:
        """Store handed to process workers: the engine's persistent one, or
        a lazily created per-engine scratch directory (cleaned up with the
        engine) so multi-process sweeps never rebuild an analysis key —
        not across workers, and not across repeated ``run()`` calls."""
        if self.analysis.store is not None:
            return self.analysis.store
        if self._scratch_store is None:
            tmp = tempfile.mkdtemp(prefix="evacim-scratch-store-")
            self._scratch_store = AnalysisStore(tmp)
            weakref.finalize(self, shutil.rmtree, tmp, True)
        return self._scratch_store

    # ------------------------------------------------------------ pieces
    def evaluate(self, point: SweepPoint) -> SweepRecord:
        """Price one design point (memoized analysis)."""
        return self.backend.evaluate(self.analysis, point, self.host)

    @staticmethod
    def _chunks(points: Sequence[SweepPoint]) -> List[List[SweepPoint]]:
        """Contiguous runs sharing one analysis key (enumeration order is
        workload-major, so one pass suffices)."""
        chunks: List[List[SweepPoint]] = []
        for p in points:
            if chunks and chunks[-1][0].analysis_key == p.analysis_key:
                chunks[-1].append(p)
            else:
                chunks.append([p])
        return chunks

    # -------------------------------------------------------------- run
    def run(self, space: Union[SweepSpace, Sequence[SweepPoint]]
            ) -> SweepResults:
        """Price a full :class:`~repro.dse.space.SweepSpace` — or any
        explicit subset of points (adaptive refinement rounds price exactly
        the new neighborhood, not a cross-product).  A point sequence is
        re-indexed to its position in the sequence, so record order always
        matches input order and repeated incremental calls compose; the
        returned ``stats`` are this call's counter deltas (per-round cost
        accounting comes for free)."""
        t0 = time.perf_counter()
        if isinstance(space, SweepSpace):
            points = space.points()
        else:
            points = [dataclasses.replace(p, index=i)
                      for i, p in enumerate(space)]
        records: List[Optional[SweepRecord]] = [None] * len(points)
        stats_before = self.analysis.stats()

        worker_stats: Optional[Dict[str, int]] = None
        with obs.span("dse.run", cat="engine", executor=self.executor,
                      backend=self.backend.name, n_points=len(points)):
            if self.executor == "serial":
                for p in points:
                    records[p.index] = self.evaluate(p)
            elif self.executor == "process":
                chunks = self._chunks(points)
                store = self._worker_store()
                trace_ctx = obs.current()    # pickled into every chunk
                # spawn, not fork: the parent holds live jax/XLA threads
                ctx = multiprocessing.get_context("spawn")
                with ProcessPoolExecutor(max_workers=self.max_workers,
                                         mp_context=ctx) as pool:
                    futs = [pool.submit(_worker_chunk, c, self.host,
                                        self.backend, str(store.root),
                                        store.version, trace_ctx)
                            for c in chunks]
                    worker_stats = {}
                    for fut in futs:
                        recs, delta, spans = fut.result()
                        obs.ingest(spans)
                        for rec in recs:
                            records[rec.index] = rec
                        for k, v in delta.items():
                            worker_stats[k] = worker_stats.get(k, 0) + v
                # workers wrote behind this process's back: re-walk the store
                # so the byte gauges below reflect their artifacts
                if self.analysis.store is not None:
                    self.analysis.store.invalidate_usage_cache()
            else:
                # warm the analysis cache serially (deterministic build
                # order, exactly one expensive analysis pass per key), then
                # fan out; the backend sees the whole key set at once so it
                # can batch — under EVA_CIM_ACCEL=jax the CiM warm path
                # replays all of a workload's geometries in one vmapped
                # kernel launch
                warm_keys = [c[0] for c in self._chunks(points)]
                with obs.span("engine.warm", cat="engine",
                              n_keys=len(warm_keys)):
                    self.backend.warm_many(self.analysis, warm_keys)
                trace_ctx = obs.current()
                if trace_ctx is None:
                    eval_fn = self.evaluate
                else:
                    # contextvars don't follow submit(): re-attach the run
                    # context in each pool thread so spans parent correctly
                    def eval_fn(point: SweepPoint) -> SweepRecord:
                        with obs.attach(trace_ctx):
                            return self.evaluate(point)
                with ThreadPoolExecutor(max_workers=self.max_workers) as pool:
                    for rec in pool.map(eval_fn, points):
                        records[rec.index] = rec

        # stats cover THIS run only, whatever the executor: thread/serial
        # report the shared-cache counter delta, process mode the summed
        # per-worker deltas (each chunk is one analysis key, so they agree)
        stats_after = self.analysis.stats()
        stats = worker_stats if worker_stats is not None else {
            k: v - stats_before.get(k, 0) for k, v in stats_after.items()}
        # store_bytes_* are gauges (current on-disk footprint), not
        # counters — report the absolute value, never a delta
        for k, v in stats_after.items():
            if k.startswith("store_bytes"):
                stats[k] = v
        return SweepResults(records=list(records), stats=stats,
                            elapsed_s=time.perf_counter() - t0)
