"""Sweep executor: memoized trace analysis + fanned-out per-config pricing.

The Eva-CiM pipeline splits cleanly into two phases with very different
costs and very different dependence on the swept axes:

  ========================  =====================  ========================
  phase                     depends on             cost
  ========================  =====================  ========================
  trace + IDG/flow index    workload, cache geom   seconds (trace VM)
  candidate selection       + cim_levels/cim_set   ~100 ms (Algorithm 1)
  pricing (energy/cycles)   + tech, host           ~100 ms (linear scan)
  ========================  =====================  ========================

:class:`AnalysisCache` memoizes the first two layers by their exact
dependence keys, so a Fig. 16 technology sweep re-runs *nothing* but
pricing, and a Fig. 15 level sweep re-runs selection only.  The
:class:`DSEEngine` walks a :class:`~repro.dse.space.SweepSpace` in
deterministic order, warms the cache once per analysis key, and fans the
cheap pricing phase out over a worker pool ("thread", "process", or
"serial") — results always come back in SweepPoint order regardless of
executor scheduling.
"""
from __future__ import annotations

import multiprocessing
import os
import threading
import time
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.host_model import DEFAULT_HOST, HostModel
from repro.core.offload import (OffloadConfig, OffloadResult, TraceAnalysis,
                                analyze_trace)
from repro.core.profiler import profile_system
from repro.core.reshape import ReshapedTrace, reshape
from repro.core.trace import TraceResult, trace_program
from repro.dse.results import SweepRecord, SweepResults
from repro.dse.space import CacheOption, SweepPoint, SweepSpace


class AnalysisCache:
    """Layered memo of the config-independent sweep artifacts.

    Layer 1 — ``(workload, cache)``  -> traced program + IDG/flow tables.
    Layer 2 — ``(layer-1 key, offload config)`` -> selected candidates +
    reshaped trace.  Hit/build counters are exposed for tests and reports
    (the "trace analysis ran exactly once per workload" guarantee).
    """

    def __init__(self):
        self._traces: Dict[Tuple, TraceResult] = {}
        self._analyses: Dict[Tuple, TraceAnalysis] = {}
        self._offloads: Dict[Tuple, Tuple[OffloadResult, ReshapedTrace]] = {}
        self._lock = threading.RLock()
        self._key_locks: Dict[Tuple, threading.Lock] = {}
        self.trace_builds = 0
        self.trace_hits = 0
        self.offload_builds = 0
        self.offload_hits = 0

    def _key_lock(self, key: Tuple) -> threading.Lock:
        """Per-key build lock: concurrent misses on one key build once."""
        with self._lock:
            lk = self._key_locks.get(key)
            if lk is None:
                lk = self._key_locks[key] = threading.Lock()
            return lk

    # ------------------------------------------------------------ layer 1
    def trace(self, workload: str, cache: CacheOption) -> TraceResult:
        from repro.workloads import build          # late: keep core importable
        key = (workload, cache.levels)             # full geometry, not name
        with self._key_lock(key):
            with self._lock:
                hit = self._traces.get(key)
                if hit is not None:
                    self.trace_hits += 1
                    return hit
                self.trace_builds += 1
            fn, args = build(workload)
            tr = trace_program(fn, *args, cache_levels=cache.levels)
            with self._lock:
                self._traces[key] = tr
            return tr

    def trace_analysis(self, workload: str, cache: CacheOption
                       ) -> TraceAnalysis:
        """IDG/flow artifacts for a trace, built lazily on first use —
        callers that only need the raw trace never pay for the flow index."""
        key = (workload, cache.levels)
        with self._key_lock(("analysis",) + key):
            with self._lock:
                hit = self._analyses.get(key)
            if hit is not None:
                return hit
            analysis = analyze_trace(self.trace(workload, cache))
            with self._lock:
                self._analyses[key] = analysis
            return analysis

    # ------------------------------------------------------------ layer 2
    def offload(self, workload: str, cache: CacheOption,
                cfg: OffloadConfig) -> Tuple[OffloadResult, ReshapedTrace]:
        # the frozen OffloadConfig is hashable-by-value: using it directly
        # keeps the key complete if new knobs are ever added to it
        key = (workload, cache.levels, cfg)
        with self._key_lock(key):
            with self._lock:
                hit = self._offloads.get(key)
                if hit is not None:
                    self.offload_hits += 1
                    return hit
                self.offload_builds += 1
            analysis = self.trace_analysis(workload, cache)
            result = analysis.select(cfg)
            reshaped = reshape(analysis.trace, result)
            with self._lock:
                self._offloads[key] = (result, reshaped)
            return result, reshaped

    def stats(self) -> Dict[str, int]:
        return {"trace_builds": self.trace_builds,
                "trace_hits": self.trace_hits,
                "offload_builds": self.offload_builds,
                "offload_hits": self.offload_hits}


# ======================================================================
# Engine
# ======================================================================
_WORKER_CACHE: Optional[AnalysisCache] = None   # per-process, for "process"


def _worker_chunk(points: Sequence[SweepPoint], host: HostModel
                  ) -> Tuple[List[SweepRecord], Dict[str, int]]:
    """Price a run of points inside one process-pool worker (the worker
    keeps its own AnalysisCache across chunks, so one trace per workload
    *per worker* — chunks are grouped by analysis key to preserve that).
    Returns the records plus this chunk's delta of the cache counters, so
    the parent can report true build totals across all workers."""
    global _WORKER_CACHE
    if _WORKER_CACHE is None:
        _WORKER_CACHE = AnalysisCache()
    before = _WORKER_CACHE.stats()
    records = [_evaluate(_WORKER_CACHE, p, host) for p in points]
    delta = {k: v - before[k] for k, v in _WORKER_CACHE.stats().items()}
    return records, delta


def _evaluate(cache: AnalysisCache, point: SweepPoint, host: HostModel
              ) -> SweepRecord:
    tr = cache.trace(point.workload, point.cache)
    result, reshaped = cache.offload(point.workload, point.cache,
                                     point.offload_config())
    rep = profile_system(tr, tech=point.tech, host=host,
                         offload=result, reshaped=reshaped)
    return SweepRecord.from_report(point, rep)


class DSEEngine:
    """Parallel design-space-exploration executor.

    ``executor``:
      * ``"thread"`` (default) — one shared :class:`AnalysisCache`; pricing
        fans out over threads (pricing is numpy/dict-walking, mostly
        GIL-bound, but trace analysis never repeats: exactly one per
        (workload, cache) per engine).
      * ``"process"`` — points are chunked by analysis key and each chunk
        runs in a spawned worker process with a per-process cache (full
        CPU parallelism across workloads, at most one analysis per key
        per worker).  Spawn semantics apply: call it from a real module
        (under ``if __name__ == "__main__":`` in scripts), not stdin.
      * ``"serial"`` — no pool at all; useful for debugging and exact
        cost accounting.
    """

    def __init__(self, cache: Optional[AnalysisCache] = None,
                 host: HostModel = DEFAULT_HOST,
                 executor: str = "thread",
                 max_workers: Optional[int] = None):
        if executor not in ("thread", "process", "serial"):
            raise ValueError(f"unknown executor {executor!r}")
        self.analysis = cache or AnalysisCache()
        self.host = host
        self.executor = executor
        self.max_workers = max_workers or min(8, os.cpu_count() or 1)

    # ------------------------------------------------------------ pieces
    def evaluate(self, point: SweepPoint) -> SweepRecord:
        """Price one design point (memoized analysis)."""
        return _evaluate(self.analysis, point, self.host)

    @staticmethod
    def _chunks(points: Sequence[SweepPoint]) -> List[List[SweepPoint]]:
        """Contiguous runs sharing one analysis key (enumeration order is
        workload-major, so one pass suffices)."""
        chunks: List[List[SweepPoint]] = []
        for p in points:
            if chunks and chunks[-1][0].analysis_key == p.analysis_key:
                chunks[-1].append(p)
            else:
                chunks.append([p])
        return chunks

    # -------------------------------------------------------------- run
    def run(self, space: SweepSpace) -> SweepResults:
        t0 = time.perf_counter()
        points = space.points()
        records: List[Optional[SweepRecord]] = [None] * len(points)
        stats_before = self.analysis.stats()

        worker_stats: Optional[Dict[str, int]] = None
        if self.executor == "serial":
            for p in points:
                records[p.index] = self.evaluate(p)
        elif self.executor == "process":
            chunks = self._chunks(points)
            # spawn, not fork: the parent holds live jax/XLA threads
            ctx = multiprocessing.get_context("spawn")
            with ProcessPoolExecutor(max_workers=self.max_workers,
                                     mp_context=ctx) as pool:
                futs = [pool.submit(_worker_chunk, c, self.host)
                        for c in chunks]
                worker_stats = {}
                for fut in futs:
                    recs, delta = fut.result()
                    for rec in recs:
                        records[rec.index] = rec
                    for k, v in delta.items():
                        worker_stats[k] = worker_stats.get(k, 0) + v
        else:
            # warm the analysis cache serially (deterministic build order,
            # exactly one trace pass per key), then fan pricing out
            for chunk in self._chunks(points):
                head = chunk[0]
                self.analysis.trace(head.workload, head.cache)
            with ThreadPoolExecutor(max_workers=self.max_workers) as pool:
                for rec in pool.map(self.evaluate, points):
                    records[rec.index] = rec

        # stats cover THIS run only, whatever the executor: thread/serial
        # report the shared-cache counter delta, process mode the summed
        # per-worker deltas (each chunk is one analysis key, so they agree)
        stats = worker_stats if worker_stats is not None else {
            k: v - stats_before[k] for k, v in self.analysis.stats().items()}
        return SweepResults(records=list(records), stats=stats,
                            elapsed_s=time.perf_counter() - t0)
