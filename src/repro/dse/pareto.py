"""Pareto-frontier extraction over arbitrary objectives.

The DSE engine's reporting question — "which design points are *worth*
anything?" — is multi-objective: the paper trades energy improvement
against speedup (and, implicitly, area/technology).  A point is kept iff
no other point is at least as good on every objective and strictly better
on one (:func:`dominates` over sign-normalized vectors).

Works on any records (dataclasses, dicts, plain objects): objectives are
named attributes/keys, each maximized by default or minimized when given
as ``(name, "min")``.  Output is deterministic — input order is preserved
— and duplicate-valued points are all kept (they dominate each other
weakly but strictly dominate nothing).

Non-finite objective values (NaN, ±inf) are **excluded** from every
frontier: NaN compares false both ways, so a degenerate record would
otherwise sit on every frontier forever (it neither dominates nor is
dominated), and an ``inf`` record would flush everything else off it.
Either failure mode poisons frontier-driven refinement
(:mod:`repro.dse.adaptive`), which prices the *neighborhoods* of frontier
points — so a frontier may only ever contain fully finite records.

Usage::

    from repro.dse import pareto_front

    front = pareto_front(results.records,
                         ("energy_improvement", "speedup"))
    cheap = pareto_front(rows, (("cim_energy_pj", "min"), "speedup"))

:meth:`repro.dse.results.SweepResults.pareto` wraps this per-workload (a
KM design point should not dominate a BFS one).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable, List, Optional, Sequence, Tuple, Union

Objective = Union[str, Tuple[str, str]]


@functools.lru_cache(maxsize=256)
def _parse_cached(objectives: Tuple[Objective, ...]
                  ) -> Tuple[Tuple[str, float], ...]:
    out = []
    for o in objectives:
        if isinstance(o, str):
            out.append((o, 1.0))
        else:
            name, direction = o
            if direction not in ("max", "min"):
                raise ValueError(f"objective direction must be 'max' or "
                                 f"'min', got {direction!r}")
            out.append((name, 1.0 if direction == "max" else -1.0))
    if not out:
        raise ValueError("need at least one objective")
    return tuple(out)


def _parse(objectives: Sequence[Objective]) -> Tuple[Tuple[str, float], ...]:
    """Normalized (name, sign) pairs — memoized, so per-item callers of
    :func:`objective_vector` don't re-validate the objective spec each
    time.  Non-str entries pass through whole so the cached parser's
    2-unpack still rejects malformed arities like ("cost", "min", "?")."""
    return _parse_cached(tuple(o if isinstance(o, str) else tuple(o)
                               for o in objectives))


def _value(item: Any, name: str) -> float:
    if isinstance(item, dict):
        return float(item[name])
    return float(getattr(item, name))


def _signed(item: Any, parsed: Sequence[Tuple[str, float]]
            ) -> Tuple[float, ...]:
    return tuple(sign * _value(item, name) for name, sign in parsed)


def objective_vector(item: Any, objectives: Sequence[Objective]
                     ) -> Tuple[float, ...]:
    """Signed objective values (higher is always better after signing)."""
    return _signed(item, _parse(objectives))


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True iff signed-vector ``a`` Pareto-dominates ``b`` (>= everywhere,
    > somewhere)."""
    return all(x >= y for x, y in zip(a, b)) and any(x > y
                                                     for x, y in zip(a, b))


def pareto_front(items: Sequence[Any],
                 objectives: Sequence[Objective] = ("energy_improvement",
                                                    "speedup")) -> List[Any]:
    """Non-dominated subset of ``items``, in input order.

    Records with any non-finite objective value (NaN, ±inf) are dropped
    before the scan — they can neither appear on the frontier nor dominate
    anything off it (see the module docstring for why).

    O(n^2) pairwise scan — sweep result sets are hundreds of points, not
    millions, and the simple scan keeps ties/duplicates handling obvious.
    """
    parsed = _parse(objectives)
    pool = [(it, vec) for it in items
            for vec in (_signed(it, parsed),)
            if all(math.isfinite(x) for x in vec)]
    out = []
    for i, (item, vi) in enumerate(pool):
        if not any(dominates(vj, vi)
                   for j, (_, vj) in enumerate(pool) if j != i):
            out.append(item)
    return out


def frontier_stable(prev: Optional[Sequence[Any]], new: Sequence[Any],
                    objectives: Sequence[Objective] = ("energy_improvement",
                                                       "speedup"),
                    key: Optional[Callable[[Any], Any]] = None) -> bool:
    """Termination predicate for frontier-driven refinement.

    True iff ``new`` is the same frontier as ``prev``: identical multisets
    of signed objective vectors, or identical ``key(item)`` sets when a
    ``key`` is given (use a design-point identity key to distinguish two
    different designs that happen to price identically).  ``prev=None``
    (no earlier round) is never stable.
    """
    if prev is None:
        return False
    if key is not None:
        return {key(it) for it in prev} == {key(it) for it in new}
    parsed = _parse(objectives)
    return (sorted(_signed(it, parsed) for it in prev)
            == sorted(_signed(it, parsed) for it in new))
