"""Pareto-frontier extraction over arbitrary objectives.

The DSE engine's reporting question — "which design points are *worth*
anything?" — is multi-objective: the paper trades energy improvement
against speedup (and, implicitly, area/technology).  A point is kept iff
no other point is at least as good on every objective and strictly better
on one (:func:`dominates` over sign-normalized vectors).

Works on any records (dataclasses, dicts, plain objects): objectives are
named attributes/keys, each maximized by default or minimized when given
as ``(name, "min")``.  Output is deterministic — input order is preserved
— and duplicate-valued points are all kept (they dominate each other
weakly but strictly dominate nothing).

Usage::

    from repro.dse import pareto_front

    front = pareto_front(results.records,
                         ("energy_improvement", "speedup"))
    cheap = pareto_front(rows, (("cim_energy_pj", "min"), "speedup"))

:meth:`repro.dse.results.SweepResults.pareto` wraps this per-workload (a
KM design point should not dominate a BFS one).
"""
from __future__ import annotations

from typing import Any, List, Sequence, Tuple, Union

Objective = Union[str, Tuple[str, str]]


def _parse(objectives: Sequence[Objective]) -> List[Tuple[str, float]]:
    out = []
    for o in objectives:
        if isinstance(o, str):
            out.append((o, 1.0))
        else:
            name, direction = o
            if direction not in ("max", "min"):
                raise ValueError(f"objective direction must be 'max' or "
                                 f"'min', got {direction!r}")
            out.append((name, 1.0 if direction == "max" else -1.0))
    if not out:
        raise ValueError("need at least one objective")
    return out


def _value(item: Any, name: str) -> float:
    if isinstance(item, dict):
        return float(item[name])
    return float(getattr(item, name))


def objective_vector(item: Any, objectives: Sequence[Objective]
                     ) -> Tuple[float, ...]:
    """Signed objective values (higher is always better after signing)."""
    return tuple(sign * _value(item, name)
                 for name, sign in _parse(objectives))


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True iff signed-vector ``a`` Pareto-dominates ``b`` (>= everywhere,
    > somewhere)."""
    return all(x >= y for x, y in zip(a, b)) and any(x > y
                                                     for x, y in zip(a, b))


def pareto_front(items: Sequence[Any],
                 objectives: Sequence[Objective] = ("energy_improvement",
                                                    "speedup")) -> List[Any]:
    """Non-dominated subset of ``items``, in input order.

    O(n^2) pairwise scan — sweep result sets are hundreds of points, not
    millions, and the simple scan keeps ties/duplicates handling obvious.
    """
    parsed = _parse(objectives)
    vecs = [tuple(sign * _value(it, name) for name, sign in parsed)
            for it in items]
    out = []
    for i, vi in enumerate(vecs):
        if not any(dominates(vj, vi) for j, vj in enumerate(vecs) if j != i):
            out.append(items[i])
    return out
