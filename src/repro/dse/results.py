"""Structured sweep results: flat records + JSON / markdown reporting.

One :class:`SweepRecord` per evaluated :class:`~repro.dse.space.SweepPoint`,
carrying the paper's reported metrics (energy improvement, speedup, MACR,
Table VI ratios) plus the raw energies/cycles so derived normalizations
(e.g. Fig. 16's "vs the SRAM non-CiM baseline") can be computed after the
sweep without re-running anything.  Records are plain floats/strings —
picklable across the process-pool boundary and JSON-able as-is — and each
carries the name of the host model it was priced under, so host-axis
sweeps (``SweepSpace(hosts=...)``) stay distinguishable all the way into
the Pareto/markdown reports.

:class:`SweepResults` wraps the record list (always in SweepPoint order,
whatever executor scheduling produced it) together with the run's cost
accounting: ``stats`` holds the analysis-cache build/hit counters — and,
when the engine is backed by a persistent
:class:`~repro.dse.store.AnalysisStore`, the store's hit/write counters —
which is how benchmarks *prove* a warm sweep did zero trace builds.
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib
from typing import Any, Dict, List, Optional, Sequence

from repro.core.host_model import DEFAULT_HOST, HostModel
from repro.core.profiler import SystemReport
from repro.dse.pareto import pareto_front
from repro.dse.space import SweepPoint


@dataclasses.dataclass(frozen=True)
class SweepRecord:
    """One priced design point (metrics are plain floats — picklable and
    JSON-able, no live trace/model objects)."""
    index: int
    workload: str
    cache: str
    cim_levels: str                      # "L1+L2" style
    tech: str
    cim_set: str
    host: str                            # host-model preset it was priced under
    energy_improvement: float
    speedup: float
    macr: float
    macr_l1: float
    base_energy_pj: float
    cim_energy_pj: float
    base_cycles: float
    cim_cycles: float
    base_runtime_ms: float               # cycles / host clock (freq_ghz)
    cim_runtime_ms: float
    processor_ratio: float
    cache_ratio: float
    n_instructions: int
    n_mem_accesses: int
    n_candidates: int
    n_cim_ops: int
    # provenance: which refinement round priced this point (0 = the coarse
    # seed sweep; one-shot sweeps leave it 0)
    round: int = 0
    # which analysis backend priced it ("cim" trace/IDG pipeline or "tpu"
    # jaxpr/HLO fusion pipeline — see repro.dse.backends); for TPU records
    # `cache` holds the chip label, `cim_levels` is "VMEM", `cim_set` the
    # fusion threshold, and the cycle columns the roofline bound in ns
    backend: str = "cim"
    # sampling identity: "exact", or the SamplingSpec.key() the metrics
    # were estimated under; sampled records carry bootstrap CI half-widths
    # for the three headline metrics (repro.core.sampling.estimate)
    sampling: str = "exact"
    energy_improvement_ci: float = 0.0
    speedup_ci: float = 0.0
    macr_ci: float = 0.0

    _SAMPLING_KEYS = ("sampling", "energy_improvement_ci", "speedup_ci",
                      "macr_ci")

    @classmethod
    def from_report(cls, point: SweepPoint, rep: SystemReport,
                    host: Optional[HostModel] = None,
                    host_name: Optional[str] = None) -> "SweepRecord":
        """``host`` is the model the report was priced under (wall-clock
        runtimes come from its clock); ``host_name`` overrides the record
        label (e.g. a HostOption's collision-safe name)."""
        if host is None:
            host = (point.host.model if point.host is not None
                    else DEFAULT_HOST)
        if host_name is None:
            host_name = (point.host.name if point.host is not None
                         else host.name)
        return cls(
            index=point.index,
            workload=point.workload,
            cache=point.cache.name,
            cim_levels="+".join(point.cim_levels),
            tech=point.tech,
            cim_set=point.cim_set,
            host=host_name,
            energy_improvement=rep.energy_improvement,
            speedup=rep.speedup,
            macr=rep.macr,
            macr_l1=rep.macr_l1,
            base_energy_pj=rep.base.total,
            cim_energy_pj=rep.cim.total,
            base_cycles=rep.base_cycles,
            cim_cycles=rep.cim_cycles,
            base_runtime_ms=host.runtime_ms(rep.base_cycles),
            cim_runtime_ms=host.runtime_ms(rep.cim_cycles),
            processor_ratio=rep.processor_ratio,
            cache_ratio=rep.cache_ratio,
            n_instructions=rep.n_instructions,
            n_mem_accesses=rep.n_mem_accesses,
            n_candidates=rep.n_candidates,
            n_cim_ops=rep.n_cim_ops,
        )

    def to_dict(self) -> Dict[str, Any]:
        """Exact records drop the sampling columns entirely, so every
        pre-sampling artifact (fig12–17 JSON, sweep reports) stays
        byte-identical; sampled records carry them."""
        d = dataclasses.asdict(self)
        if self.sampling == "exact":
            for k in self._SAMPLING_KEYS:
                del d[k]
        return d

    @property
    def config_label(self) -> str:
        return (f"{self.cache}/cim@{self.cim_levels}/{self.tech}"
                f"/{self.cim_set}/{self.host}")


_REPORT_COLUMNS = ("workload", "cache", "cim_levels", "tech", "host",
                   "energy_improvement", "speedup", "macr")


@dataclasses.dataclass
class SweepResults:
    """All records of one sweep, in SweepPoint order, plus run metadata."""
    records: List[SweepRecord]
    stats: Dict[str, int] = dataclasses.field(default_factory=dict)
    elapsed_s: float = 0.0

    def __len__(self) -> int:
        return len(self.records)

    def __iter__(self):
        return iter(self.records)

    # ------------------------------------------------------------- merging
    def merge(self, other: "SweepResults") -> "SweepResults":
        """Combine two result sets into one (multi-round reports).

        Records are concatenated and re-indexed to one contiguous 0..n-1
        sequence (each record's ``round`` tag keeps its provenance);
        ``stats`` counters are summed key-wise over the union of keys, so a
        merged report never under-counts work one side did and the other
        didn't (``to_markdown``'s ``trace_builds`` line stays the true
        total, not a ``'?'`` fallback); ``elapsed_s`` adds.  Neither input
        is mutated.  Used by :class:`repro.dse.adaptive.AdaptiveDSE` to
        accumulate refinement rounds.
        """
        records = [dataclasses.replace(r, index=i) for i, r in
                   enumerate(list(self.records) + list(other.records))]
        stats = dict(self.stats)
        for k, v in other.stats.items():
            stats[k] = stats.get(k, 0) + v
        return SweepResults(records=records, stats=stats,
                            elapsed_s=self.elapsed_s + other.elapsed_s)

    # ------------------------------------------------------------- queries
    def best(self, metric: str = "energy_improvement",
             workload: Optional[str] = None) -> SweepRecord:
        """Argmax record over ``metric`` (ties broken toward the earliest
        point).  Records with a non-finite metric (NaN, ±inf) are excluded
        — ``max()`` over NaN is order-dependent garbage — and all-NaN
        pools raise rather than return a degenerate winner."""
        pool = [r for r in self.records
                if workload is None or r.workload == workload]
        if not pool:
            raise ValueError(f"no records for workload={workload!r}")
        finite = [r for r in pool if math.isfinite(getattr(r, metric))]
        if not finite:
            raise ValueError(f"no finite {metric!r} values for "
                             f"workload={workload!r}")
        return max(finite, key=lambda r: (getattr(r, metric), -r.index))

    def group_by(self, field: str) -> Dict[str, List[SweepRecord]]:
        out: Dict[str, List[SweepRecord]] = {}
        for r in self.records:
            out.setdefault(getattr(r, field), []).append(r)
        return out

    def pareto(self, objectives: Sequence = ("energy_improvement", "speedup"),
               per_workload: bool = True) -> List[SweepRecord]:
        """Non-dominated records over ``objectives`` (maximized by default;
        see :func:`repro.dse.pareto.pareto_front` for (name, "min") pairs)."""
        if not per_workload:
            return pareto_front(self.records, objectives)
        out: List[SweepRecord] = []
        for recs in self.group_by("workload").values():
            out.extend(pareto_front(recs, objectives))
        return sorted(out, key=lambda r: r.index)

    # ----------------------------------------------------------- reporting
    def rows(self) -> List[Dict[str, Any]]:
        return [r.to_dict() for r in self.records]

    def to_json(self, path: Optional[pathlib.Path] = None) -> str:
        doc = {"stats": self.stats, "elapsed_s": round(self.elapsed_s, 3),
               "n_records": len(self.records), "records": self.rows()}
        text = json.dumps(doc, indent=1)
        if path is not None:
            pathlib.Path(path).write_text(text)
        return text

    def to_markdown(self, columns: Sequence[str] = _REPORT_COLUMNS,
                    pareto_objectives: Sequence = ("energy_improvement",
                                                   "speedup")) -> str:
        """Human-readable sweep report: full table + per-workload Pareto set."""
        def fmt(v: Any) -> str:
            return f"{v:.3f}" if isinstance(v, float) else str(v)

        lines = ["# DSE sweep report", "",
                 f"{len(self.records)} design points; "
                 f"{self.stats.get('trace_builds', '?')} trace analyses "
                 f"({self.stats.get('trace_hits', 0)} cache hits); "
                 f"{self.elapsed_s:.1f}s", "",
                 "| " + " | ".join(columns) + " |",
                 "|" + "|".join("---" for _ in columns) + "|"]
        for r in self.records:
            lines.append("| " + " | ".join(fmt(getattr(r, c))
                                           for c in columns) + " |")
        front = self.pareto(pareto_objectives)
        names = [o if isinstance(o, str) else o[0] for o in pareto_objectives]
        lines += ["", f"## Pareto frontier ({' vs '.join(names)}, "
                      "per workload)", ""]
        for r in front:
            vals = ", ".join(f"{n}={fmt(getattr(r, n))}" for n in names)
            lines.append(f"- **{r.workload}** {r.config_label}: {vals}")
        return "\n".join(lines) + "\n"
