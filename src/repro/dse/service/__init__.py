"""repro.dse.service — the DSE engine as a long-running daemon.

The production shape of the Eva-CiM engine (ROADMAP "DSE-as-a-service"):
instead of every consumer paying a cold process and a private cache, one
resident :class:`DSEService` owns a warm
:class:`~repro.dse.engine.AnalysisCache` per backend (optionally over a
shared persistent :class:`~repro.dse.store.AnalysisStore`) and serves
sweep/adaptive queries from many concurrent clients over HTTP/JSON:

  * :mod:`.server`       — :class:`DSEService` + stdlib
    ``ThreadingHTTPServer`` front end; NDJSON-streamed responses
    (adaptive rounds land line-by-line as they complete); the
    ``python -m repro.dse.service`` daemon entry point,
  * :mod:`.singleflight` — the coalescing primitive: concurrent requests
    whose canonical point keys overlap share one in-flight evaluation,
  * :mod:`.metrics`      — counters/gauges/latency histograms behind
    ``GET /metrics`` (cache + store hit rates ride along),
  * :mod:`.codec`        — JSON request validation ⇄ typed ``SweepSpace``,
  * :mod:`.client`       — stdlib-only client library
    (:class:`ServiceClient`), used by ``benchmarks/bench_service.py``.

Quickstart::

    PYTHONPATH=src python -m repro.dse.service --port 8321

    from repro.dse.service import ServiceClient
    client = ServiceClient("http://127.0.0.1:8321")
    reply = client.sweep(["KM"], techs=["sram", "fefet"])
    for event in client.adaptive_events(["KM"], caches=["32K+256K",
                                                        "64K+2M"]):
        print(event["event"])      # start, round..., result
"""
from repro.dse.service.client import (ServiceClient, ServiceError,
                                      SweepReply)
from repro.dse.service.codec import RequestError, parse_request
from repro.dse.service.metrics import MetricsRegistry
from repro.dse.service.server import (DSEService, make_server, main,
                                      running_server)
from repro.dse.service.singleflight import SingleFlight

__all__ = [
    "DSEService", "MetricsRegistry", "RequestError", "ServiceClient",
    "ServiceError", "SingleFlight", "SweepReply", "make_server", "main",
    "parse_request", "running_server",
]
