"""``python -m repro.dse.service`` — run the DSE daemon."""
from repro.dse.service.server import main

if __name__ == "__main__":
    raise SystemExit(main())
