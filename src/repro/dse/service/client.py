"""Client library for the DSE daemon — stdlib HTTP, streaming-aware.

:class:`ServiceClient` is the programmatic face of
``python -m repro.dse.service``: build a request dict (or let the helper
methods build it), POST it, and either collect the final result
(:meth:`sweep`) or iterate NDJSON events as the daemon emits them
(:meth:`stream` / :meth:`adaptive_events`) — an adaptive client sees
every ``round`` event, frontier included, while later rounds are still
pricing on the server.

Built on :mod:`http.client` so the daemon's consumers need nothing the
standard library doesn't ship; chunked transfer decoding and
line-buffered reads come for free from :class:`http.client.HTTPResponse`.
"""
from __future__ import annotations

import http.client
import json
import socket
import time
import urllib.parse
from typing import Dict, Iterator, List, Optional, Sequence, Union


class ServiceError(RuntimeError):
    """Non-2xx response, or an in-band ``error`` event from a stream."""

    def __init__(self, message: str, status: Optional[int] = None):
        super().__init__(message)
        self.status = status


class SweepReply:
    """The terminal ``result`` event, plus any ``round`` events that
    preceded it — one object whether the query was exhaustive or
    adaptive."""

    def __init__(self, events: List[Dict]):
        self.events = events
        self.rounds = [e for e in events if e.get("event") == "round"]
        finals = [e for e in events if e.get("event") == "result"]
        if not finals:
            raise ServiceError("stream ended without a result event")
        self.result = finals[-1]

    @property
    def records(self) -> List[Dict]:
        return self.result["records"]

    @property
    def frontier(self) -> List[Dict]:
        return self.result["frontier"]

    @property
    def stats(self) -> Dict:
        return self.result.get("stats", {})

    @property
    def trace_id(self) -> Optional[str]:
        """The server-side trace id from the ``start`` event (``None``
        when the daemon runs with tracing disabled); feed it to
        :meth:`ServiceClient.trace` for the request's span tree."""
        for e in self.events:
            if e.get("event") == "start":
                return e.get("trace_id")
        return None


class ServiceClient:
    """One daemon endpoint (``http://host:port``), any number of calls.

    A connection per call: the daemon is thread-per-request and the
    dominant cost is the sweep itself, so connection reuse buys nothing
    and per-call connections keep the client trivially thread-safe.
    """

    def __init__(self, base_url: str, timeout: float = 600.0):
        parsed = urllib.parse.urlsplit(base_url)
        if parsed.scheme != "http" or parsed.hostname is None:
            raise ValueError(f"expected an http://host:port URL, "
                             f"got {base_url!r}")
        self.host = parsed.hostname
        self.port = parsed.port or 80
        self.timeout = timeout

    # ------------------------------------------------------------ plumbing
    def _connect(self) -> http.client.HTTPConnection:
        return http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)

    def _get_json(self, path: str) -> Dict:
        conn = self._connect()
        try:
            conn.request("GET", path)
            resp = conn.getresponse()
            body = resp.read()
            if resp.status != 200:
                raise ServiceError(body.decode(errors="replace"),
                                   status=resp.status)
            return json.loads(body)
        finally:
            conn.close()

    def stream(self, request: Dict,
               endpoint: Optional[str] = None) -> Iterator[Dict]:
        """POST a request document, yield each NDJSON event as it arrives.

        ``endpoint`` defaults to the request's ``mode`` (``sweep`` /
        ``adaptive``).  An in-band ``error`` event raises
        :class:`ServiceError` after any earlier events were yielded.
        """
        endpoint = endpoint or request.get("mode", "sweep")
        conn = self._connect()
        try:
            body = json.dumps(request).encode()
            conn.request("POST", f"/v1/{endpoint}", body=body,
                         headers={"Content-Type": "application/json"})
            resp = conn.getresponse()
            if resp.status != 200:
                payload = resp.read().decode(errors="replace")
                try:
                    payload = json.loads(payload).get("error", payload)
                except (json.JSONDecodeError, AttributeError):
                    pass
                raise ServiceError(payload, status=resp.status)
            while True:
                line = resp.readline()
                if not line:
                    break
                line = line.strip()
                if not line:
                    continue
                event = json.loads(line)
                if event.get("event") == "error":
                    raise ServiceError(event.get("error", "server error"))
                yield event
        finally:
            conn.close()

    # ------------------------------------------------------------- queries
    def sweep(self, workloads: Sequence[str], *, backend: str = "cim",
              adaptive: bool = False, **axes) -> SweepReply:
        """Run a query and collect the full reply.

        ``axes`` pass through to the request document: ``caches``,
        ``cim_levels``, ``techs``, ``cim_sets``, ``hosts`` (CiM),
        ``tpus`` (TPU), ``objectives``/``max_rounds`` (adaptive).
        """
        request = {"workloads": list(workloads), "backend": backend,
                   "mode": "adaptive" if adaptive else "sweep"}
        request.update({k: v for k, v in axes.items() if v is not None})
        return SweepReply(list(self.stream(request)))

    def adaptive_events(self, workloads: Sequence[str], *,
                        backend: str = "cim", **axes) -> Iterator[Dict]:
        """Streaming adaptive query: yields ``start``, each ``round`` as
        its pricing completes, then the terminal ``result``."""
        request = {"workloads": list(workloads), "backend": backend,
                   "mode": "adaptive"}
        request.update({k: v for k, v in axes.items() if v is not None})
        return self.stream(request)

    # ------------------------------------------------------ observability
    def healthz(self) -> Dict:
        return self._get_json("/healthz")

    def metrics(self) -> Dict:
        return self._get_json("/metrics")

    def trace(self, trace_id: str) -> Dict:
        """The finished span tree of a recent request (404 →
        :class:`ServiceError`: the id fell out of the daemon's ring)."""
        return self._get_json(f"/v1/trace/{urllib.parse.quote(trace_id)}")

    def wait_ready(self, deadline_s: float = 15.0) -> Dict:
        """Block until the daemon answers ``/healthz`` (startup races in
        benchmarks/CI), raising :class:`ServiceError` on timeout."""
        deadline = time.monotonic() + deadline_s
        last: Union[Exception, None] = None
        while time.monotonic() < deadline:
            try:
                return self.healthz()
            except (ConnectionError, socket.timeout, OSError,
                    ServiceError) as exc:
                last = exc
                time.sleep(0.05)
        raise ServiceError(f"daemon at {self.host}:{self.port} not ready "
                           f"after {deadline_s}s: {last}")
