"""Wire codec: JSON request bodies ⇄ typed sweep objects.

One request document describes everything a sweep needs::

    {
      "backend": "cim",                    # or "tpu"
      "mode": "sweep",                     # or "adaptive"
      "workloads": ["KM", "BFS"],          # Table-IV names / arch ids
      "caches": ["32K+256K", "64K+2M"],    # presets (CiM axes)
      "cim_levels": ["L1_only", "both"],
      "techs": ["sram", "fefet"],
      "cim_sets": ["stt"],
      "hosts": ["A9-1GHz"],                # optional host axis
      "tpus": [{"chip": "v5e", "min_saved_bytes": "64K"}],   # TPU axis
      "objectives": ["energy_improvement", "speedup"],       # adaptive
      "max_rounds": 8                                        # adaptive
    }

Unknown axis values fail *here*, as a :class:`RequestError` the server
maps to HTTP 400 with the offending field named — a daemon must reject a
bad query loudly, not price a silently-defaulted space.  Validation
reuses the same registries the CLI checks against
(:data:`repro.workloads.WORKLOADS`, the arch registry, the
``SweepSpace`` preset tables), so CLI and service accept exactly the
same vocabulary.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.sampling.spec import SamplingSpec
from repro.dse.results import SweepRecord
from repro.dse.space import SweepSpace, TpuOption, parse_bytes
from repro.core.tpu_model import TPU_PRESETS

VALID_BACKENDS = ("cim", "tpu")
VALID_MODES = ("sweep", "adaptive")


class RequestError(ValueError):
    """A malformed or out-of-vocabulary request (HTTP 400)."""


def _str_tuple(doc: Dict, field: str,
               default: Optional[Sequence[str]] = None
               ) -> Optional[Tuple[str, ...]]:
    value = doc.get(field, default)
    if value is None:
        return None
    if (not isinstance(value, (list, tuple)) or not value
            or not all(isinstance(v, str) for v in value)):
        raise RequestError(f"{field!r} must be a non-empty list of strings")
    return tuple(value)


def _tpu_option(spec) -> TpuOption:
    if isinstance(spec, str):
        if spec not in TPU_PRESETS:
            raise RequestError(f"unknown TPU chip preset {spec!r}; "
                               f"known: {sorted(TPU_PRESETS)}")
        return TpuOption.of(spec)
    if not isinstance(spec, dict):
        raise RequestError("each 'tpus' entry must be a chip-preset string "
                           "or an object with a 'chip' field")
    chip = spec.get("chip")
    if chip not in TPU_PRESETS:
        raise RequestError(f"unknown TPU chip preset {chip!r}; "
                           f"known: {sorted(TPU_PRESETS)}")
    try:
        return TpuOption(
            chip=TPU_PRESETS[chip],
            min_saved_bytes=parse_bytes(spec.get("min_saved_bytes", 1 << 16)),
            vmem_scale=float(spec.get("vmem_scale", 1.0)),
            hbm_bw_scale=float(spec.get("hbm_bw_scale", 1.0)))
    except (TypeError, ValueError) as exc:
        raise RequestError(f"bad 'tpus' entry {spec!r}: {exc}") from exc


def parse_request(doc: Dict) -> Dict:
    """Validated request: backend, mode, space, adaptive options.

    Returns ``{"backend": str, "mode": str, "space": SweepSpace,
    "objectives": tuple, "max_rounds": int}``.
    """
    if not isinstance(doc, dict):
        raise RequestError("request body must be a JSON object")
    backend = doc.get("backend", "cim")
    if backend not in VALID_BACKENDS:
        raise RequestError(f"unknown backend {backend!r}; "
                           f"known: {list(VALID_BACKENDS)}")
    mode = doc.get("mode", "sweep")
    if mode not in VALID_MODES:
        raise RequestError(f"unknown mode {mode!r}; known: "
                           f"{list(VALID_MODES)}")

    # statistical sampling: "sampling" is either a SamplingSpec object
    # ({"mode": "phase", "interval": 2048, ...}) or the CLI string form
    # ("phase:interval=2048,budget=32"); CiM-only — the TPU pipeline has
    # no trace to sample
    sampling = SamplingSpec()
    if doc.get("sampling") is not None:
        if backend == "tpu":
            raise RequestError("'sampling' is meaningless with backend "
                               "'tpu'; the jaxpr/HLO analysis has no "
                               "instruction trace to sample")
        raw = doc["sampling"]
        try:
            sampling = (SamplingSpec.parse(raw) if isinstance(raw, str)
                        else SamplingSpec.from_dict(raw))
        except ValueError as exc:
            raise RequestError(f"bad 'sampling': {exc}") from exc

    workloads = _str_tuple(doc, "workloads")
    if workloads is None:
        raise RequestError("'workloads' is required")
    if backend == "cim":
        from repro.workloads import WORKLOADS
        unknown = [w for w in workloads if w.partition("@")[0]
                   not in WORKLOADS]
        if unknown:
            raise RequestError(f"unknown workload(s) {unknown}; "
                               f"known: {sorted(WORKLOADS)}")
        scaled = [w for w in workloads if "@" in w]
        for w in scaled:
            tail = w.partition("@")[2]
            if not tail.isdigit() or int(tail) < 1:
                raise RequestError(f"bad workload scale in {w!r}; "
                                   f"expected 'name@positive_int'")
        if scaled and sampling.is_exact:
            raise RequestError(
                f"loop-scaled workload(s) {scaled} ('name@scale') need "
                f"'sampling' — exact analysis only prices registry-sized "
                f"workloads")
    else:
        from repro.configs.registry import ARCHS
        unknown = [w for w in workloads if w not in ARCHS]
        if unknown:
            raise RequestError(f"unknown arch(s) {unknown}; "
                               f"known: {sorted(ARCHS)}")

    # CiM-only axes on a TPU request (and vice versa) are rejected, not
    # ignored — mirrors the examples/dse_cim.py CLI contract
    cim_axes = [f for f in ("caches", "cim_levels", "techs", "cim_sets",
                            "hosts") if doc.get(f) is not None]
    if backend == "tpu" and cim_axes:
        raise RequestError(f"CiM-only axes {cim_axes} are meaningless with "
                           f"backend 'tpu'; use 'tpus' "
                           f"(chip/min_saved_bytes)")
    if backend == "cim" and doc.get("tpus") is not None:
        raise RequestError("'tpus' is meaningless with backend 'cim'; "
                           "use caches/cim_levels/techs/cim_sets/hosts")

    try:
        if backend == "tpu":
            tpus = doc.get("tpus") or ["v5e"]
            if not isinstance(tpus, (list, tuple)) or not tpus:
                raise RequestError("'tpus' must be a non-empty list")
            space = SweepSpace(
                workloads=workloads,
                tpus=tuple(_tpu_option(t) for t in tpus))
        else:
            space = SweepSpace(
                workloads=workloads,
                caches=_str_tuple(doc, "caches") or ("32K+256K",),
                cim_levels=_str_tuple(doc, "cim_levels") or ("both",),
                techs=_str_tuple(doc, "techs") or ("sram",),
                cim_sets=_str_tuple(doc, "cim_sets") or ("stt",),
                hosts=_str_tuple(doc, "hosts") or (None,))
    except KeyError as exc:                    # unknown preset names
        raise RequestError(str(exc.args[0]) if exc.args else str(exc)) from exc

    objectives = _str_tuple(doc, "objectives",
                            ("energy_improvement", "speedup"))
    valid_metrics = {f.name for f in dataclasses.fields(SweepRecord)}
    bad = [o for o in objectives if o not in valid_metrics]
    if bad:
        raise RequestError(f"unknown objective(s) {bad}; objectives must be "
                           f"SweepRecord metric names")
    max_rounds = doc.get("max_rounds", 8)
    if not isinstance(max_rounds, int) or max_rounds < 0:
        raise RequestError("'max_rounds' must be a non-negative integer")

    return {"backend": backend, "mode": mode, "space": space,
            "objectives": objectives, "max_rounds": max_rounds,
            "sampling": sampling}


def records_json(records: Sequence[SweepRecord]) -> List[Dict]:
    """Records as strict-JSON dicts: non-finite floats become ``null``
    (``NaN`` is a Python-ism most JSON parsers reject, and a degenerate
    record must not poison a whole NDJSON stream)."""
    out = []
    for r in records:
        doc = r.to_dict()
        for k, v in doc.items():
            if isinstance(v, float) and not math.isfinite(v):
                doc[k] = None
        out.append(doc)
    return out
