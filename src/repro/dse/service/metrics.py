"""Observability plane of the DSE daemon — counters, gauges, histograms.

Everything the ``/metrics`` endpoint serves is a plain-JSON snapshot of
this registry plus the live cache/store counters the engine already
keeps.  The registry is deliberately tiny and stdlib-only:

* :class:`Counter` — monotonic (requests served, points coalesced),
* :class:`Gauge`   — instantaneous level (requests in flight),
* :class:`Histogram` — latency distribution: exact count/sum plus
  p50/p90/p99 estimated from a bounded reservoir of the most recent
  observations (a daemon cares about *recent* tail latency; an
  ever-growing exact quantile structure does not pay its way here).

All mutation goes through one registry lock — the hot path is a dict
lookup and a float add, contention is dwarfed by the work being
measured.  ``snapshot()`` returns plain ``dict``/``float`` values, so the
HTTP handler can ``json.dumps`` it directly.
"""
from __future__ import annotations

import collections
import threading
from typing import Deque, Dict, List, Optional


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, by: int = 1) -> None:
        self.value += by


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, by: int = 1) -> None:
        self.value += by

    def dec(self, by: int = 1) -> None:
        self.value -= by

    def set(self, value: float) -> None:
        self.value = value


def _pick(ordered: List[float], q: float) -> Optional[float]:
    """Nearest-rank quantile over an ascending list (``None`` if empty).

    The single quantile implementation: :meth:`Histogram.quantile` and
    :meth:`Histogram.snapshot` both route through it, each sorting the
    reservoir exactly once."""
    if not ordered:
        return None
    return ordered[min(len(ordered) - 1, max(0, round(q * (len(ordered) - 1))))]


class Histogram:
    """Latency summary: exact count/sum/max, reservoir quantiles."""

    __slots__ = ("count", "sum", "max", "_recent")

    def __init__(self, reservoir: int = 2048) -> None:
        self.count = 0
        self.sum = 0.0
        self.max = 0.0
        self._recent: Deque[float] = collections.deque(maxlen=reservoir)

    def observe(self, value: float) -> None:
        self.count += 1
        self.sum += value
        if value > self.max:
            self.max = value
        self._recent.append(value)

    def quantile(self, q: float) -> Optional[float]:
        return _pick(sorted(self._recent), q)

    def snapshot(self) -> Dict[str, Optional[float]]:
        ordered: List[float] = sorted(self._recent)
        return {"count": self.count, "sum": round(self.sum, 6),
                "mean": round(self.sum / self.count, 6) if self.count else None,
                "max": round(self.max, 6) if self.count else None,
                "p50": _pick(ordered, 0.50), "p90": _pick(ordered, 0.90),
                "p99": _pick(ordered, 0.99)}


class MetricsRegistry:
    """Named metric instruments, created on first use, one lock for all.

    Names are dotted paths (``"requests.sweep"``); ``snapshot()`` nests
    them back into a JSON-friendly tree, with histograms expanded to
    their summary dicts and quantiles rounded for readability.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}      # lint: guarded-by(_lock)
        self._gauges: Dict[str, Gauge] = {}          # lint: guarded-by(_lock)
        self._histograms: Dict[str, Histogram] = {}  # lint: guarded-by(_lock)

    # ------------------------------------------------------------ access
    def counter(self, name: str, by: int = 1) -> None:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            c.inc(by)

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            return g

    def gauge_inc(self, name: str, by: int = 1) -> None:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            g.inc(by)

    def gauge_dec(self, name: str, by: int = 1) -> None:
        self.gauge_inc(name, -by)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram()
            h.observe(value)

    def counter_value(self, name: str) -> int:
        with self._lock:
            c = self._counters.get(name)
            return c.value if c is not None else 0

    # ---------------------------------------------------------- snapshot
    @staticmethod
    def _nest(tree: Dict, name: str, value) -> None:
        """Nest one dotted metric name into the snapshot tree.

        Leaf/branch name clashes (a counter ``"a"`` next to a gauge
        ``"a.b"``, in either registration order) must not drop a metric:
        the clashing value is recorded at the top level under its
        *literal dotted name* instead of a nested path.  In the one case
        where even that key is taken — a dotless name whose slot already
        holds a branch — the literal key gets a ``"."`` suffix, so both
        the branch and the scalar survive the snapshot."""
        parts = name.split(".")
        node = tree
        clash = False
        for p in parts[:-1]:
            nxt = node.setdefault(p, {})
            if not isinstance(nxt, dict):        # prefix is already a leaf
                clash = True
                break
            node = nxt
        if not clash and isinstance(node.get(parts[-1]), dict):
            clash = True                         # name is already a branch
        if clash:
            key = name if not isinstance(tree.get(name), dict) else name + "."
            tree[key] = value
            return
        node[parts[-1]] = value

    def snapshot(self) -> Dict:
        out: Dict = {}
        with self._lock:
            for name, c in sorted(self._counters.items()):
                self._nest(out, name, c.value)
            for name, g in sorted(self._gauges.items()):
                self._nest(out, name, g.value)
            for name, h in sorted(self._histograms.items()):
                self._nest(out, name, h.snapshot())
        return out
