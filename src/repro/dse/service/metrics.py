"""Observability plane of the DSE daemon — counters, gauges, histograms.

Everything the ``/metrics`` endpoint serves is a plain-JSON snapshot of
this registry plus the live cache/store counters the engine already
keeps.  The registry is deliberately tiny and stdlib-only:

* :class:`Counter` — monotonic (requests served, points coalesced),
* :class:`Gauge`   — instantaneous level (requests in flight),
* :class:`Histogram` — latency distribution: exact count/sum plus
  p50/p90/p99 estimated from a bounded reservoir of the most recent
  observations (a daemon cares about *recent* tail latency; an
  ever-growing exact quantile structure does not pay its way here).

All mutation goes through one registry lock — the hot path is a dict
lookup and a float add, contention is dwarfed by the work being
measured.  ``snapshot()`` returns plain ``dict``/``float`` values, so the
HTTP handler can ``json.dumps`` it directly.
"""
from __future__ import annotations

import collections
import threading
from typing import Deque, Dict, List, Optional


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, by: int = 1) -> None:
        self.value += by


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, by: int = 1) -> None:
        self.value += by

    def dec(self, by: int = 1) -> None:
        self.value -= by

    def set(self, value: float) -> None:
        self.value = value


class Histogram:
    """Latency summary: exact count/sum/max, reservoir quantiles."""

    __slots__ = ("count", "sum", "max", "_recent")

    def __init__(self, reservoir: int = 2048) -> None:
        self.count = 0
        self.sum = 0.0
        self.max = 0.0
        self._recent: Deque[float] = collections.deque(maxlen=reservoir)

    def observe(self, value: float) -> None:
        self.count += 1
        self.sum += value
        if value > self.max:
            self.max = value
        self._recent.append(value)

    def quantile(self, q: float) -> Optional[float]:
        if not self._recent:
            return None
        ordered = sorted(self._recent)
        idx = min(len(ordered) - 1, max(0, round(q * (len(ordered) - 1))))
        return ordered[idx]

    def snapshot(self) -> Dict[str, Optional[float]]:
        ordered: List[float] = sorted(self._recent)

        def pick(q: float) -> Optional[float]:
            if not ordered:
                return None
            return ordered[min(len(ordered) - 1,
                               max(0, round(q * (len(ordered) - 1))))]

        return {"count": self.count, "sum": round(self.sum, 6),
                "mean": round(self.sum / self.count, 6) if self.count else None,
                "max": round(self.max, 6) if self.count else None,
                "p50": pick(0.50), "p90": pick(0.90), "p99": pick(0.99)}


class MetricsRegistry:
    """Named metric instruments, created on first use, one lock for all.

    Names are dotted paths (``"requests.sweep"``); ``snapshot()`` nests
    them back into a JSON-friendly tree, with histograms expanded to
    their summary dicts and quantiles rounded for readability.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}      # lint: guarded-by(_lock)
        self._gauges: Dict[str, Gauge] = {}          # lint: guarded-by(_lock)
        self._histograms: Dict[str, Histogram] = {}  # lint: guarded-by(_lock)

    # ------------------------------------------------------------ access
    def counter(self, name: str, by: int = 1) -> None:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            c.inc(by)

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            return g

    def gauge_inc(self, name: str, by: int = 1) -> None:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            g.inc(by)

    def gauge_dec(self, name: str, by: int = 1) -> None:
        self.gauge_inc(name, -by)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram()
            h.observe(value)

    def counter_value(self, name: str) -> int:
        with self._lock:
            c = self._counters.get(name)
            return c.value if c is not None else 0

    # ---------------------------------------------------------- snapshot
    @staticmethod
    def _nest(tree: Dict, name: str, value) -> None:
        parts = name.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
            if not isinstance(node, dict):       # leaf/branch name clash
                return
        node[parts[-1]] = value

    def snapshot(self) -> Dict:
        out: Dict = {}
        with self._lock:
            for name, c in sorted(self._counters.items()):
                self._nest(out, name, c.value)
            for name, g in sorted(self._gauges.items()):
                self._nest(out, name, g.value)
            for name, h in sorted(self._histograms.items()):
                self._nest(out, name, h.snapshot())
        return out
