"""The DSE daemon: one warm analysis substrate, many concurrent clients.

:class:`DSEService` owns the modeling state a cold CLI pays for on every
invocation — one :class:`~repro.dse.store.AnalysisStore` (optional, via
``cache_dir``) under one in-memory :class:`~repro.dse.engine.AnalysisCache`
*per backend* — and serves sweep/adaptive queries over HTTP/JSON from a
stdlib :class:`~http.server.ThreadingHTTPServer`.  Three layers of
dedup/memoization stack up, coarsest first:

1. **Record memo** — a priced :class:`~repro.dse.results.SweepRecord` per
   canonical ``(backend, SweepPoint.key)``: a repeated exhaustive sweep
   against a warm daemon re-prices *nothing* (bounded FIFO, ``memo_limit``).
2. **Single-flight** — concurrent requests whose point keys overlap share
   one in-flight evaluation per key (:mod:`.singleflight`): a key already
   running is never recomputed, the latecomer waits and receives the
   leader's record.
3. **Analysis cache/store** — the engine's layered memo (trace/IDG once
   per (workload, geometry), selection once per config) exactly as the
   CLI uses it, warm across every request the daemon ever serves.

Responses are NDJSON streams (``application/x-ndjson``, chunked): every
response is a sequence of one-line JSON events ending with a ``result``
event, and adaptive requests additionally emit a ``round`` event the
moment each refinement round completes — a client steering exploration
sees the frontier move *while* later rounds are still pricing.

Endpoints (see ``docs/architecture.md`` for the full table):

  ``POST /v1/sweep``     exhaustive cross-product  → ``start``, ``result``
  ``POST /v1/adaptive``  frontier-driven refinement → ``start``,
  ``round``\\*, ``result``
  ``GET  /metrics``      observability snapshot (JSON)
  ``GET  /healthz``      liveness + uptime

Run it::

    PYTHONPATH=src python -m repro.dse.service --port 8321 \\
        --cache-dir ~/.cache/eva-cim
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import json
import signal
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from repro import obs
from repro.core.host_model import DEFAULT_HOST, HostModel
from repro.dse.adaptive import AdaptiveDSE
from repro.dse.backends import AnalysisBackend, CimBackend, TpuBackend
from repro.dse.engine import AnalysisCache, DSEEngine
from repro.dse.results import SweepRecord, SweepResults
from repro.dse.service.codec import RequestError, parse_request, records_json
from repro.dse.service.metrics import MetricsRegistry
from repro.dse.service.singleflight import SingleFlight
from repro.dse.space import SweepPoint
from repro.dse.store import AnalysisStore


class _CoalescingEngine(DSEEngine):
    """A :class:`DSEEngine` whose per-point evaluation routes through the
    service's record memo + single-flight table.  Thread executor only:
    the daemon's worker threads are the fan-out, and process pools can't
    share an in-flight table."""

    def __init__(self, service: "DSEService", backend: AnalysisBackend,
                 cache: AnalysisCache, max_workers: int):
        super().__init__(cache=cache, executor="thread",
                         max_workers=max_workers, backend=backend)
        self._service = service

    def evaluate(self, point: SweepPoint) -> SweepRecord:
        return self._service.evaluate_point(self.backend, self.analysis,
                                            point, self.host)


class DSEService:
    """Warm modeling substrate + coalescing evaluator + metrics.

    ``cache_dir`` backs both backends' analysis caches with one shared
    persistent :class:`~repro.dse.store.AnalysisStore` (CiM and TPU
    artifacts are backend-namespaced and coexist); ``None`` keeps all
    state in memory for the daemon's lifetime.  ``memo_limit`` bounds the
    priced-record memo (FIFO eviction).  Thread-safe throughout — the
    HTTP server hands every request its own thread.

    ``tracing`` (default on) installs the process-global
    :mod:`repro.obs` tracer: every POST opens a root span whose
    ``trace_id`` is echoed in the NDJSON ``start`` event and the
    ``X-Trace-Id`` response header, and the finished span tree is served
    back by ``GET /v1/trace/<id>`` from a bounded ring of the last
    ``trace_buffer`` traces.
    """

    def __init__(self, cache_dir: Optional[str] = None,
                 max_workers: int = 4, memo_limit: int = 1 << 18,
                 host: HostModel = DEFAULT_HOST,
                 tracing: bool = True, trace_buffer: int = 64):
        self.started_at = time.time()
        self.metrics = MetricsRegistry()
        self.store: Optional[AnalysisStore] = (
            AnalysisStore(cache_dir) if cache_dir else None)
        self.host = host
        self.max_workers = max_workers
        self.memo_limit = memo_limit
        self._singleflight = SingleFlight()
        self._memo_lock = threading.Lock()
        self._memo: Dict[Tuple, SweepRecord] = {}  # lint: guarded-by(_memo_lock)
        self._backends: Dict[str, AnalysisBackend] = {"cim": CimBackend(),
                                                      "tpu": TpuBackend()}
        self._caches: Dict[str, AnalysisCache] = {
            name: AnalysisCache(store=self.store)
            for name in self._backends}
        self.trace_buffer = trace_buffer
        self._trace_lock = threading.Lock()
        self._traces: "collections.OrderedDict[str, List[Dict]]" = \
            collections.OrderedDict()  # lint: guarded-by(_trace_lock)
        # remember whether tracing was ours to turn on, so close()
        # restores the caller's state instead of clobbering it
        self._owns_tracer = tracing and obs.tracer() is None
        if tracing:
            obs.enable()

    def close(self) -> None:
        """Release service-owned globals (the tracer, if this service
        installed it).  Idempotent; the HTTP layer keeps working, new
        requests just stop producing spans."""
        if self._owns_tracer:
            self._owns_tracer = False
            obs.disable()

    # ------------------------------------------------------------ engines
    def engine(self, backend_name: str,
               backend_obj: Optional[AnalysisBackend] = None) -> DSEEngine:
        """A fresh engine view over the shared per-backend cache — cheap,
        one per request, so concurrent runs never share executor state.
        ``backend_obj`` substitutes a per-request configuration of the
        named backend (e.g. a sampled :class:`CimBackend`) while keeping
        the shared cache — artifact keys carry the sampling identity, so
        variants coexist in one cache without collisions."""
        return _CoalescingEngine(self, backend_obj
                                 or self._backends[backend_name],
                                 self._caches[backend_name],
                                 self.max_workers)

    # ----------------------------------------------------- point evaluation
    def evaluate_point(self, backend: AnalysisBackend, cache: AnalysisCache,
                       point: SweepPoint, host: HostModel) -> SweepRecord:
        """Memo → single-flight → backend pipeline, in that order.

        The memo key is the point's canonical design identity plus the
        backend name and its variant (the sampling key for sampled CiM
        backends — a sampled estimate must never satisfy an exact query,
        or vice versa) — ``index`` and ``round`` are positional metadata,
        re-stamped per request, so one priced record serves every request
        that ever asks for that design.
        """
        variant = getattr(backend, "variant", None)
        key = (backend.name, variant, point.key)
        self.metrics.counter("points.requested")
        if variant is not None:
            self.metrics.counter("points.sampled")
        with obs.span("service.point", cat="engine", backend=backend.name,
                      workload=point.workload) as sp:
            with self._memo_lock:
                hit = self._memo.get(key)
            if hit is not None:
                self.metrics.counter("points.memo_hits")
                sp.set(source="memo")
                return dataclasses.replace(hit, index=point.index, round=0)

            def build() -> SweepRecord:
                rec = backend.evaluate(cache, point, host)
                with self._memo_lock:
                    if len(self._memo) >= self.memo_limit:  # FIFO bound
                        self._memo.pop(next(iter(self._memo)))
                    self._memo[key] = rec
                self.metrics.counter("points.evaluated")
                return rec

            rec, coalesced = self._singleflight.do(key, build)
            if coalesced:
                self.metrics.counter("points.coalesced")
            sp.set(source="coalesced" if coalesced else "evaluated")
            return dataclasses.replace(rec, index=point.index, round=0)

    # ------------------------------------------------------------ queries
    def handle_query(self, doc: Dict,
                     trace_id: Optional[str] = None) -> Iterator[Dict]:
        """Parse + run one request, yielding NDJSON event dicts.

        ``start`` → (``round`` per adaptive refinement round) → ``result``.
        Raises :class:`~repro.dse.service.codec.RequestError` before the
        first yield for malformed requests (the HTTP layer maps it to a
        400 **before** committing to a streamed 200).  ``trace_id`` (the
        HTTP layer's root span, when tracing) is echoed in the ``start``
        event so streaming clients can fetch ``/v1/trace/<id>`` later.
        """
        req = parse_request(doc)
        space, backend = req["space"], req["backend"]
        sampling = req["sampling"]
        backend_obj = None
        if backend == "cim" and not sampling.is_exact:
            backend_obj = dataclasses.replace(self._backends["cim"],
                                              sampling=sampling)
        engine = self.engine(backend, backend_obj)
        start = {"event": "start", "backend": backend, "mode": req["mode"],
                 "n_points": len(space), "n_analyses": space.n_analyses()}
        if not sampling.is_exact:
            start["sampling"] = sampling.key()
        if trace_id is not None:
            start["trace_id"] = trace_id
        yield start
        if req["mode"] == "adaptive":
            adaptive = AdaptiveDSE(space, engine=engine,
                                   objectives=req["objectives"],
                                   max_rounds=req["max_rounds"])
            last = None
            for event in adaptive.run_iter():
                info = event.info
                yield {"event": "round", "round": info.round,
                       "n_candidates": info.n_candidates,
                       "n_priced": info.n_priced,
                       "frontier_size": info.frontier_size,
                       "stable": info.stable,
                       "elapsed_s": round(info.elapsed_s, 4),
                       "stats": info.stats,
                       "frontier": records_json(event.frontier)}
                last = event
            results = (last.results if last is not None
                       else SweepResults(records=[]))
            frontier = last.frontier if last is not None else []
            yield self._result_event(results, frontier,
                                     n_rounds=(last.info.round + 1
                                               if last else 0))
        else:
            results = engine.run(space)
            frontier = results.pareto(req["objectives"])
            yield self._result_event(results, frontier)

    @staticmethod
    def _result_event(results: SweepResults, frontier: List[SweepRecord],
                      **extra) -> Dict:
        return {"event": "result", "n_records": len(results),
                "elapsed_s": round(results.elapsed_s, 4),
                "stats": results.stats,
                "records": records_json(results.records),
                "frontier": records_json(frontier), **extra}

    # ------------------------------------------------------------- traces
    def finish_trace(self, trace_id: Optional[str]) -> None:
        """Drain a finished request's spans out of the tracer into the
        bounded ring buffer and roll their self-times into the metrics
        (``obs.spans`` counter + per-stage ``obs.stage_self_s`` gauges)."""
        t = obs.tracer()
        if t is None or trace_id is None:
            return
        spans = t.take(trace_id)
        if not spans:
            return
        with self._trace_lock:
            self._traces[trace_id] = spans
            while len(self._traces) > self.trace_buffer:
                self._traces.popitem(last=False)
        self.metrics.counter("obs.spans", len(spans))
        att = obs.stage_attribution(spans)
        for cat, st in att["stages"].items():
            self.metrics.gauge_inc(f"obs.stage_self_s.{cat}",
                                   round(st["self_s"], 6))

    def trace_tree(self, trace_id: str) -> Optional[Dict]:
        """The finished span tree of a recent request (or ``None``)."""
        with self._trace_lock:
            spans = self._traces.get(trace_id)
        if spans is None:
            return None
        return {"trace_id": trace_id, "n_spans": len(spans),
                "spans": obs.build_tree(spans)}

    # ------------------------------------------------------------ metrics
    def metrics_snapshot(self) -> Dict:
        doc = {
            "uptime_s": round(time.time() - self.started_at, 3),
            "service": self.metrics.snapshot(),
            "inflight_keys": self._singleflight.inflight(),
            "memo_records": len(self._memo),
            "cache": {},
        }
        svc = doc["service"].setdefault("points", {})
        requested = svc.get("requested", 0)
        evaluated = svc.get("evaluated", 0)
        svc.setdefault("coalesced", 0)
        svc.setdefault("memo_hits", 0)
        svc.setdefault("sampled", 0)
        # the headline number: how many point-prices one evaluation served
        doc["dedup_ratio"] = (round(requested / evaluated, 3)
                              if evaluated else None)
        for name, cache in self._caches.items():
            stats = cache.stats()
            layers = {}
            for layer, (b, h) in (("layer1", ("trace_builds", "trace_hits")),
                                  ("layer2", ("offload_builds",
                                              "offload_hits"))):
                builds, hits = stats.get(b, 0), stats.get(h, 0)
                layers[layer] = {
                    "builds": builds, "hits": hits,
                    "hit_rate": (round(hits / (hits + builds), 3)
                                 if hits + builds else None)}
            layers["replay_batches"] = stats.get("replay_batches", 0)
            doc["cache"][name] = layers
        from repro.core import accel
        doc["accel"] = {"backend": accel.backend(),
                        "jit_compiles": accel.jit_compiles()}
        t = obs.tracer()
        with self._trace_lock:
            buffered = len(self._traces)
        doc["obs"] = {"tracing": t is not None,
                      "buffered_traces": buffered,
                      "dropped_spans": t.dropped if t is not None else 0}
        if self.store is not None:
            doc["store"] = self.store.stats()
            doc["store"]["corrupt_drops"] = self.store.corrupt_drops
        return doc


# ======================================================================
# HTTP layer
# ======================================================================
class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    service: DSEService                     # set by make_server()
    quiet: bool = True

    # --------------------------------------------------------- plumbing
    def log_message(self, fmt: str, *args) -> None:     # noqa: N802
        if not self.quiet:
            super().log_message(fmt, *args)

    def _send_json(self, code: int, doc: Dict) -> None:
        body = json.dumps(doc).encode() + b"\n"
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _stream_ndjson(self, events: Iterator[Dict],
                       headers: Optional[Dict[str, str]] = None,
                       on_complete: Optional[Callable[[], None]] = None
                       ) -> None:
        """Chunked NDJSON: one event per line, flushed as produced, so a
        client sees each ``round`` while later rounds are still running.

        ``on_complete`` runs after the last event but *before* the
        terminal chunk — a client that saw the stream end is guaranteed
        its side effects (trace buffering) already happened."""
        self.send_response(200)
        self.send_header("Content-Type", "application/x-ndjson")
        self.send_header("Transfer-Encoding", "chunked")
        for name, value in (headers or {}).items():
            self.send_header(name, value)
        self.end_headers()

        def chunk(data: bytes) -> None:
            self.wfile.write(f"{len(data):X}\r\n".encode())
            self.wfile.write(data + b"\r\n")
            self.wfile.flush()

        try:
            for event in events:
                chunk(json.dumps(event).encode() + b"\n")
        except Exception as exc:  # noqa: BLE001 — stream must terminate
            # mid-stream failure: the status line is long gone, so the
            # error travels in-band as a terminal event line
            chunk(json.dumps({"event": "error",
                              "error": str(exc)}).encode() + b"\n")
        if on_complete is not None:
            on_complete()
        self.wfile.write(b"0\r\n\r\n")
        self.wfile.flush()

    # --------------------------------------------------------- endpoints
    def do_GET(self) -> None:               # noqa: N802
        t0 = time.perf_counter()
        path = self.path.split("?", 1)[0]
        if path == "/healthz":
            svc = self.service
            self._send_json(200, {
                "status": "ok",
                "uptime_s": round(time.time() - svc.started_at, 3),
                "backends": sorted(svc._backends)})
        elif path == "/metrics":
            self._send_json(200, self.service.metrics_snapshot())
        elif path.startswith("/v1/trace/"):
            trace_id = path.rsplit("/", 1)[1]
            tree = self.service.trace_tree(trace_id)
            if tree is None:
                self._send_json(404, {"error": f"no buffered trace "
                                               f"{trace_id!r} (finished "
                                               f"traces are kept in a "
                                               f"bounded ring)"})
                return
            self._send_json(200, tree)
            path = "/trace"                  # one metric series, not per-id
        else:
            self._send_json(404, {"error": f"unknown path {path!r}"})
            return
        self.service.metrics.counter(f"requests.{path.strip('/')}")
        self.service.metrics.observe(f"latency_s.{path.strip('/')}",
                                     time.perf_counter() - t0)

    def do_POST(self) -> None:              # noqa: N802
        path = self.path.split("?", 1)[0]
        endpoint = {"/v1/sweep": "sweep", "/v1/adaptive": "adaptive"}.get(path)
        if endpoint is None:
            self._send_json(404, {"error": f"unknown path {path!r}"})
            return
        svc = self.service
        t0 = time.perf_counter()
        svc.metrics.counter(f"requests.{endpoint}")
        svc.metrics.gauge_inc("inflight_requests")
        # the request's root span: everything the handler thread (and the
        # engine threads/processes it fans out to) does nests under it;
        # trace_id is None when tracing is off (NULL_SPAN)
        root = obs.span(f"http.{endpoint}", cat="service", endpoint=endpoint)
        trace_id = root.trace_id
        root.__enter__()
        finished = False

        def finish_request() -> None:
            # close the root span + buffer the trace exactly once, before
            # the client sees the stream terminate (so /v1/trace/<id>
            # resolves the moment a reply is fully read)
            nonlocal finished
            if not finished:
                finished = True
                root.__exit__(None, None, None)
                svc.finish_trace(trace_id)

        try:
            try:
                length = int(self.headers.get("Content-Length", "0"))
                doc = json.loads(self.rfile.read(length) or b"{}")
            except (ValueError, json.JSONDecodeError):
                svc.metrics.counter("requests.bad")
                self._send_json(400, {"error": "body must be valid JSON"})
                return
            doc["mode"] = endpoint           # the path, not the body, decides
            try:
                events = svc.handle_query(doc, trace_id=trace_id)
                first = next(events)         # parse errors surface here,
            except RequestError as exc:      # before the 200 is committed
                svc.metrics.counter("requests.bad")
                self._send_json(400, {"error": str(exc)})
                return
            self._stream_ndjson(
                _chain_first(first, events),
                headers=({"X-Trace-Id": trace_id} if trace_id else None),
                on_complete=finish_request)
        finally:
            finish_request()
            svc.metrics.gauge_dec("inflight_requests")
            svc.metrics.observe(f"latency_s.{endpoint}",
                                time.perf_counter() - t0)


def _chain_first(first: Dict, rest: Iterator[Dict]) -> Iterator[Dict]:
    yield first
    yield from rest


def make_server(service: DSEService, host: str = "127.0.0.1",
                port: int = 0, quiet: bool = True) -> ThreadingHTTPServer:
    """Bind a ready-to-run server (``port=0`` → ephemeral; read
    ``server.server_address``).  Call ``serve_forever()`` on it — in a
    thread for tests/benchmarks, directly for the daemon."""
    handler = type("BoundHandler", (_Handler,),
                   {"service": service, "quiet": quiet})
    server = ThreadingHTTPServer((host, port), handler)
    server.daemon_threads = True
    return server


@contextlib.contextmanager
def running_server(service: Optional[DSEService] = None,
                   host: str = "127.0.0.1", port: int = 0,
                   **service_kwargs):
    """In-process daemon for tests/benchmarks/examples::

        with running_server(cache_dir=tmp) as (url, service):
            ServiceClient(url).sweep(...)
    """
    service = service or DSEService(**service_kwargs)
    server = make_server(service, host=host, port=port)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    try:
        bound_host, bound_port = server.server_address[:2]
        yield f"http://{bound_host}:{bound_port}", service
    finally:
        server.shutdown()
        server.server_close()
        thread.join(timeout=10)
        service.close()      # restore the caller's tracing state


# ======================================================================
# Daemon entry point
# ======================================================================
def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.dse.service",
        description="Eva-CiM DSE daemon: sweep/adaptive queries over "
                    "HTTP/JSON with one warm analysis cache")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8321,
                    help="0 picks an ephemeral port (printed on startup)")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent AnalysisStore directory shared with "
                         "the CLI tools")
    ap.add_argument("--max-workers", type=int, default=4,
                    help="pricing fan-out threads per request")
    ap.add_argument("--verbose", action="store_true",
                    help="log every request to stderr")
    ap.add_argument("--no-trace", action="store_true",
                    help="disable per-request span tracing "
                         "(X-Trace-Id / GET /v1/trace/<id>)")
    args = ap.parse_args(argv)

    service = DSEService(cache_dir=args.cache_dir,
                         max_workers=args.max_workers,
                         tracing=not args.no_trace)
    server = make_server(service, host=args.host, port=args.port,
                         quiet=not args.verbose)
    bound_host, bound_port = server.server_address[:2]
    print(f"[dse.service] serving on http://{bound_host}:{bound_port} "
          f"(cache_dir={args.cache_dir or 'in-memory'})", flush=True)

    def _shutdown(signum, frame):
        print(f"[dse.service] signal {signum}: shutting down", flush=True)
        # shutdown() must come from another thread than serve_forever()
        threading.Thread(target=server.shutdown, daemon=True).start()

    signal.signal(signal.SIGTERM, _shutdown)
    signal.signal(signal.SIGINT, _shutdown)
    try:
        server.serve_forever()
    finally:
        server.server_close()
    print("[dse.service] clean shutdown", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
