"""Single-flight execution table — in-flight work shared across callers.

The daemon's coalescing guarantee ("never recompute a key already
running") is exactly Go's ``singleflight`` primitive: the first caller of
a key runs the build, every concurrent caller of the same key *waits on
the first caller's flight* instead of starting its own, and all of them
receive the one result.  The :class:`~repro.dse.engine.AnalysisCache`
already serializes the expensive layer-1/2 *analysis* builds per key;
this table extends the guarantee to whole point evaluations across
concurrent HTTP requests, and reports how much work it saved
(``coalesced`` — flights joined rather than started).

Failure semantics: an exception raised by the build propagates to the
leader *and* to every waiter of that flight (they were promised that
flight's result), but is never cached — the next caller after the flight
completes starts a fresh one, so a transient failure doesn't poison the
key forever.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Hashable, Tuple


class _Flight:
    __slots__ = ("event", "value", "error", "waiters")

    def __init__(self) -> None:
        self.event = threading.Event()
        self.value: Any = None
        self.error: BaseException | None = None
        self.waiters = 0


class SingleFlight:
    """``do(key, fn)`` — run ``fn`` once per key among concurrent callers.

    Returns ``(value, coalesced)``: ``coalesced`` is True when this call
    joined another caller's in-flight build instead of running its own.
    Counters (monotonic, read without locking for metrics snapshots):

    * ``started``   — flights this table actually executed,
    * ``coalesced`` — calls served by waiting on someone else's flight.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._flights: Dict[Hashable, _Flight] = {}  # lint: guarded-by(_lock)
        self.started = 0     # lint: guarded-by(_lock)
        self.coalesced = 0   # lint: guarded-by(_lock)

    def do(self, key: Hashable,
           fn: Callable[[], Any]) -> Tuple[Any, bool]:
        with self._lock:
            flight = self._flights.get(key)
            if flight is not None:
                flight.waiters += 1
                self.coalesced += 1
                leader = False
            else:
                flight = self._flights[key] = _Flight()
                self.started += 1
                leader = True
        if not leader:
            flight.event.wait()
            if flight.error is not None:
                raise flight.error
            return flight.value, True
        try:
            flight.value = fn()
        except BaseException as exc:       # propagate to leader + waiters
            flight.error = exc
            raise
        finally:
            with self._lock:
                self._flights.pop(key, None)
            flight.event.set()
        return flight.value, False

    def inflight(self) -> int:
        """Number of keys currently being built (metrics gauge)."""
        with self._lock:
            return len(self._flights)
