"""Typed sweep specification: the cross-product of the paper's design axes.

Eva-CiM's design space (§VI-D/E, Figs. 14–16) spans five orthogonal axes:

  * **workload**   — which benchmark program (Table IV),
  * **cache**      — L1/L2 geometry (Fig. 14's three configurations),
  * **cim_levels** — which cache levels host the CiM arrays (Fig. 15),
  * **tech**       — the device technology, SRAM vs FeFET (Fig. 16 /
                     Table III), plus the supported-op set it implies,
  * **host**       — the host-CPU model the CiM arrays are attached to
                     (§V-C/§VI-D host/CiM interaction; named presets in
                     :data:`repro.core.host_model.HOST_PRESETS`).

A :class:`SweepSpace` enumerates the full cross-product as a deterministic,
stable-ordered list of :class:`SweepPoint` records (workload-major, so all
points sharing one expensive trace analysis are adjacent).  Each point can
mint its own :class:`~repro.core.offload.OffloadConfig` for the selection
phase; everything else on the point — tech *and* host — is pricing-phase
input, so neither axis ever adds analysis work.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

from repro.core.cache import (CacheConfig, L1_32K, L1_64K, L2_256K, L2_2M)
from repro.core.device_model import TECHS
from repro.core.host_model import HOST_PRESETS, HostModel
from repro.core.isa import CIM_SET_FULL, CIM_SET_LOGIC, CIM_SET_STT
from repro.core.offload import OffloadConfig
from repro.core.tpu_model import TPU_PRESETS, TpuChip

# Named presets for the paper's swept values ---------------------------------
CACHE_PRESETS: Dict[str, Tuple[CacheConfig, ...]] = {
    "32K+256K": (L1_32K, L2_256K),
    "64K+256K": (L1_64K, L2_256K),
    "64K+2M": (L1_64K, L2_2M),
}
LEVEL_PRESETS: Dict[str, Tuple[str, ...]] = {
    "L1_only": ("L1",),
    "L2_only": ("L2",),
    "both": ("L1", "L2"),
}
CIM_SETS = {
    "logic": CIM_SET_LOGIC,
    "stt": CIM_SET_STT,
    "full": CIM_SET_FULL,
}

DEFAULT_CACHE = "32K+256K"       # trace_program's default (L1_32K, L2_256K)


@dataclasses.dataclass(frozen=True)
class CacheOption:
    """One named cache configuration (hierarchy geometry)."""
    name: str
    levels: Tuple[CacheConfig, ...]

    @classmethod
    def of(cls, spec: Union[str, "CacheOption", Tuple[CacheConfig, ...]]
           ) -> "CacheOption":
        if isinstance(spec, CacheOption):
            return spec
        if isinstance(spec, str):
            if spec not in CACHE_PRESETS:
                raise KeyError(f"unknown cache preset {spec!r}; "
                               f"known: {sorted(CACHE_PRESETS)}")
            return cls(spec, CACHE_PRESETS[spec])
        levels = tuple(spec)

        def size_name(c: CacheConfig) -> str:
            mb = 1024 * 1024
            return f"{c.size // mb}M" if c.size >= mb else f"{c.size // 1024}K"

        return cls("+".join(size_name(c) for c in levels), levels)


@dataclasses.dataclass(frozen=True)
class HostOption:
    """One named host-CPU configuration (pricing-phase axis value)."""
    name: str
    model: HostModel

    @classmethod
    def of(cls, spec: Union[str, "HostOption", HostModel]) -> "HostOption":
        if isinstance(spec, HostOption):
            return spec
        if isinstance(spec, HostModel):
            # a hand-built model may carry a preset's (default) name with
            # different constants — label it distinctly so records/reports
            # never conflate it with the real preset
            name = (spec.name if HOST_PRESETS.get(spec.name) == spec
                    else f"custom({spec.name})")
            return cls(name, spec)
        if spec not in HOST_PRESETS:
            raise KeyError(f"unknown host preset {spec!r}; "
                           f"known: {sorted(HOST_PRESETS)}")
        return cls(spec, HOST_PRESETS[spec])


def _fmt_bytes(n: int) -> str:
    """Compact power-of-two-ish byte label: 65536 -> '64K', 2**20 -> '1M'."""
    if n >= 1 << 20 and n % (1 << 20) == 0:
        return f"{n >> 20}M"
    if n >= 1 << 10 and n % (1 << 10) == 0:
        return f"{n >> 10}K"
    return str(n)


def parse_bytes(spec: Union[str, int]) -> int:
    """Inverse of the label format: '64K' -> 65536, '1M' -> 2**20, 4096 -> 4096."""
    if isinstance(spec, int):
        return spec
    s = spec.strip().upper()
    for suffix, shift in (("M", 20), ("K", 10)):
        if s.endswith(suffix):
            return int(s[:-1]) << shift
    return int(s)


@dataclasses.dataclass(frozen=True)
class TpuOption:
    """One TPU-mode hardware/fusion configuration (the backend-specific axis).

    The TPU analogue of the (cache geometry, cim_levels, tech) bundle: which
    chip the step is priced on, how aggressive VMEM fusion is (a candidate
    chain is only realized when it eliminates at least ``min_saved_bytes`` of
    HBM traffic), and optional what-if scaling of the two memory-system
    resources (``vmem_scale`` gates which candidates *fit*, a selection-phase
    input; ``hbm_bw_scale`` moves the roofline, a pricing-phase input).
    Frozen + hashable so TPU-carrying :class:`SweepPoint` dedup works.
    """
    chip: TpuChip
    min_saved_bytes: int = 1 << 16
    vmem_scale: float = 1.0
    hbm_bw_scale: float = 1.0

    @property
    def chip_label(self) -> str:
        base = next((k for k, v in TPU_PRESETS.items() if v == self.chip),
                    self.chip.name)
        if self.vmem_scale != 1.0:
            base += f"*vmem{self.vmem_scale:g}"
        if self.hbm_bw_scale != 1.0:
            base += f"*bw{self.hbm_bw_scale:g}"
        return base

    @property
    def threshold_label(self) -> str:
        return f"thr{_fmt_bytes(self.min_saved_bytes)}"

    @property
    def name(self) -> str:
        return f"{self.chip_label}/{self.threshold_label}"

    def effective_chip(self) -> TpuChip:
        """The chip with the what-if scalings applied (pricing input)."""
        if self.vmem_scale == 1.0 and self.hbm_bw_scale == 1.0:
            return self.chip
        return dataclasses.replace(
            self.chip, vmem_bytes=self.chip.vmem_bytes * self.vmem_scale,
            hbm_bw=self.chip.hbm_bw * self.hbm_bw_scale)

    @classmethod
    def of(cls, spec: Union[str, "TpuOption", TpuChip]) -> "TpuOption":
        if isinstance(spec, TpuOption):
            return spec
        if isinstance(spec, TpuChip):
            return cls(chip=spec)
        if spec not in TPU_PRESETS:
            raise KeyError(f"unknown TPU chip preset {spec!r}; "
                           f"known: {sorted(TPU_PRESETS)}")
        return cls(chip=TPU_PRESETS[spec])


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One fully-specified design point of the sweep."""
    index: int                       # position in the deterministic ordering
    workload: str
    cache: CacheOption
    cim_levels: Tuple[str, ...]
    tech: str
    cim_set: str = "stt"
    host: Optional[HostOption] = None    # None: the engine's default host
    tpu: Optional[TpuOption] = None      # None: CiM point (the default)

    @property
    def analysis_key(self) -> Tuple:
        """Key of the config-independent phase this point can reuse.

        Keyed by the full cache geometry (not the display name): two
        options with equal sizes but different associativity/banking must
        not share a memoized trace.  TPU-mode points share one jaxpr/HLO
        analysis per workload regardless of the (unused) CiM cache axis."""
        if self.tpu is not None:
            return (self.workload, "tpu")
        return (self.workload, self.cache.levels)

    @property
    def key(self) -> Tuple:
        """Canonical design identity — everything that affects pricing,
        *excluding* ``index`` (a point's position differs between the
        coarse sweep and a refinement round, but it is the same design)
        and the cache display name (geometry is the identity, two
        differently-labeled options with equal geometry price alike).
        This is the dedup key of adaptive refinement: a point is priced at
        most once per :class:`~repro.dse.adaptive.AdaptiveDSE` run however
        many neighborhoods propose it."""
        return (self.workload, self.cache.levels, self.cim_levels,
                self.tech, self.cim_set,
                None if self.host is None else (self.host.name,
                                                self.host.model),
                self.tpu)

    @property
    def label(self) -> str:
        if self.tpu is not None:
            return f"{self.workload}/{self.tpu.name}"
        lv = "+".join(self.cim_levels)
        base = (f"{self.workload}/{self.cache.name}/cim@{lv}"
                f"/{self.tech}/{self.cim_set}")
        return base if self.host is None else f"{base}/{self.host.name}"

    def offload_config(self) -> OffloadConfig:
        return OffloadConfig(cim_set=CIM_SETS[self.cim_set],
                             cim_levels=self.cim_levels)


@dataclasses.dataclass(frozen=True)
class SweepSpace:
    """Cross-product specification over the four design axes.

    Axis values accept preset *names* (strings) wherever one exists, so the
    common sweeps read like the paper:

        SweepSpace(workloads=("KM", "BFS"),
                   caches=("32K+256K", "64K+2M"),
                   cim_levels=("L1_only", "both"),
                   techs=("sram", "fefet"),
                   hosts=("A9-1GHz", "inorder-1GHz", "big-OoO-2GHz"))

    The ``hosts`` default of ``(None,)`` means "price with the engine's
    default host" — existing four-axis sweeps enumerate identically.
    """
    workloads: Tuple[str, ...]
    caches: Tuple[Union[str, CacheOption], ...] = (DEFAULT_CACHE,)
    cim_levels: Tuple[Union[str, Tuple[str, ...]], ...] = ("both",)
    techs: Tuple[str, ...] = ("sram",)
    cim_sets: Tuple[str, ...] = ("stt",)
    hosts: Tuple[Union[str, HostOption, HostModel, None], ...] = (None,)
    # backend-specific axis: TPU-mode chip/threshold options (None = CiM
    # point priced by the engine's backend default).  CiM sweeps leave it
    # at (None,) and enumerate identically to the five-axis form.
    tpus: Tuple[Union[str, TpuOption, TpuChip, None], ...] = (None,)

    def __post_init__(self):
        for t in self.techs:
            if t not in TECHS:
                raise KeyError(f"unknown tech {t!r}; known: {sorted(TECHS)}")
        for s in self.cim_sets:
            if s not in CIM_SETS:
                raise KeyError(f"unknown CiM op set {s!r}; "
                               f"known: {sorted(CIM_SETS)}")
        for lv in self._level_tuples():
            for name in lv:
                if name not in ("L1", "L2"):
                    raise KeyError(f"unknown cache level {name!r}")
        # materialize options eagerly so bad names fail at build time
        object.__setattr__(self, "caches",
                           tuple(CacheOption.of(c) for c in self.caches))
        object.__setattr__(self, "hosts",
                           tuple(None if h is None else HostOption.of(h)
                                 for h in self.hosts))
        object.__setattr__(self, "tpus",
                           tuple(None if t is None else TpuOption.of(t)
                                 for t in self.tpus))

    # ------------------------------------------------------------ helpers
    def _level_tuples(self) -> List[Tuple[str, ...]]:
        out = []
        for lv in self.cim_levels:
            if isinstance(lv, str):
                if lv not in LEVEL_PRESETS:
                    raise KeyError(f"unknown level preset {lv!r}; "
                                   f"known: {sorted(LEVEL_PRESETS)}")
                out.append(LEVEL_PRESETS[lv])
            else:
                out.append(tuple(lv))
        return out

    def __len__(self) -> int:
        return (len(self.workloads) * len(self.caches)
                * len(self.cim_levels) * len(self.techs)
                * len(self.cim_sets) * len(self.hosts) * len(self.tpus))

    def points(self) -> List[SweepPoint]:
        """Deterministic enumeration, workload-major then cache — all points
        sharing one trace analysis are contiguous.  The host and TPU axes
        iterate innermost: host is pricing-only and every TPU option of one
        workload shares one jaxpr/HLO analysis, so variants of one design
        point stay adjacent and reuse every cached artifact."""
        levels = self._level_tuples()
        out: List[SweepPoint] = []
        for w, cache, lv, tech, cs, host, tpu in itertools.product(
                self.workloads, self.caches, levels, self.techs,
                self.cim_sets, self.hosts, self.tpus):
            out.append(SweepPoint(index=len(out), workload=w, cache=cache,
                                  cim_levels=lv, tech=tech, cim_set=cs,
                                  host=host, tpu=tpu))
        return out

    def __iter__(self) -> Iterator[SweepPoint]:
        return iter(self.points())

    def n_analyses(self) -> int:
        """Number of expensive trace/IDG passes the sweep needs (vs
        ``len(self)`` full pipeline runs without memoization)."""
        return len(self.workloads) * len(self.caches)


# ---------------------------------------------------------------------------
# Axis neighborhoods — the refinement move set of adaptive DSE.
# ---------------------------------------------------------------------------
def _adjacent(ordered: Sequence, i: int) -> List:
    out = []
    if i > 0:
        out.append(ordered[i - 1])
    if 0 <= i < len(ordered) - 1:
        out.append(ordered[i + 1])
    return out


def neighborhood(point: SweepPoint, space: SweepSpace) -> List[SweepPoint]:
    """Single-axis neighbors of ``point`` within ``space``'s axis values.

    The move set deliberately mirrors how the axes order physically:

      * **cache** — the geometries adjacent to the point's in the space's
        ``caches`` ordering (declare them small→large and "adjacent" means
        the next size step, Fig. 14's axis);
      * **cim_levels** — every level set in the space that *strictly
        contains* the point's (supersets only: adding CiM arrays to more
        levels explores monotone extensions of a good placement);
      * **tech / cim_set / host** — the values adjacent in the space's
        declared ordering;
      * **tpu** — backend-aware sub-axis moves: the TPU options in the
        space that keep every other :class:`TpuOption` field and step to
        the *adjacent* chip preset or the adjacent fusion threshold (in
        the order the distinct values are declared) — one knob at a time,
        exactly like the CiM axes.

    Each move changes exactly one axis, so a refinement round prices a
    cross-shaped neighborhood around every frontier point rather than a
    new sub-cross-product.  Points are emitted with ``index=-1`` (the
    driver/engine re-indexes); values outside the space never appear, so
    refinement stays inside the declared design universe.  A point whose
    axis value is not in the space at all contributes no move on that
    axis.
    """
    moves: List[SweepPoint] = []

    def emit(**replacement) -> None:
        moves.append(dataclasses.replace(point, index=-1, **replacement))

    caches: Sequence[CacheOption] = space.caches
    ci = next((i for i, c in enumerate(caches)
               if c.levels == point.cache.levels), -1)
    for c in _adjacent(caches, ci):
        emit(cache=c)

    current = set(point.cim_levels)
    for lv in space._level_tuples():
        if current < set(lv):
            emit(cim_levels=lv)

    for t in _adjacent(space.techs, list(space.techs).index(point.tech)
                       if point.tech in space.techs else -1):
        emit(tech=t)
    for s in _adjacent(space.cim_sets,
                       list(space.cim_sets).index(point.cim_set)
                       if point.cim_set in space.cim_sets else -1):
        emit(cim_set=s)

    hosts: Sequence[Optional[HostOption]] = space.hosts
    hi = next((i for i, h in enumerate(hosts) if h == point.host), -1)
    for h in _adjacent(hosts, hi):
        emit(host=h)

    for t in tpu_neighbors(point.tpu, space.tpus):
        emit(tpu=t)
    return moves


def tpu_neighbors(current: Optional[TpuOption],
                  declared: Sequence[Optional[TpuOption]]
                  ) -> List[TpuOption]:
    """Single-knob TPU moves: options in ``declared`` reached from
    ``current`` by stepping exactly one sub-axis — the adjacent chip preset
    or the adjacent ``min_saved_bytes`` threshold (each sub-axis ordered by
    first appearance in the declared options, mirroring the other axes'
    declared-order adjacency).  Only declared options are ever returned, so
    a sparse (non-grid) TPU axis stays sparse under refinement."""
    if current is None:
        return []
    options = [t for t in declared if t is not None]
    universe = set(options)
    chips = list(dict.fromkeys(t.chip for t in options))
    thresholds = list(dict.fromkeys(t.min_saved_bytes for t in options))
    out: List[TpuOption] = []
    ci = chips.index(current.chip) if current.chip in chips else -1
    for chip in _adjacent(chips, ci):
        cand = dataclasses.replace(current, chip=chip)
        if cand in universe:
            out.append(cand)
    ti = (thresholds.index(current.min_saved_bytes)
          if current.min_saved_bytes in thresholds else -1)
    for thr in _adjacent(thresholds, ti):
        cand = dataclasses.replace(current, min_saved_bytes=thr)
        if cand in universe:
            out.append(cand)
    return out
