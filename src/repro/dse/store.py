"""Persistent, content-addressed analysis store — cross-process memoization.

The in-memory :class:`~repro.dse.engine.AnalysisCache` makes one *engine*
cheap; this module makes repeated *invocations* cheap.  An
:class:`AnalysisStore` persists the two expensive sweep layers on disk:

  Layer 1 — the traced program as a compressed ``.npz`` column archive
  (one numpy array per I-state column — see
  :class:`repro.core.columnar.ColumnarTrace` — plus cache counters and
  program outputs) and a sibling flow-table archive, keyed by ``(workload
  fingerprint, cache geometry, trace-VM version)``.  RUT/IHT are *not*
  persisted: they are derived tables, reconstructed vectorized on demand.
  Layer 2 — accepted candidates + the reshaped trace (zlib-compressed
  pickle), keyed by the layer-1 key plus the full
  :class:`~repro.core.offload.OffloadConfig`.

Keys are content-addressed: the workload fingerprint hashes the builder
module's *source*, the cache key is the full geometry (size/assoc/banks/
MSHRs, never the display name), every key mixes in
:data:`~repro.core.trace.TRACE_VM_VERSION`, and the flow/selection
artifacts additionally mix in
:data:`~repro.core.offload.ANALYSIS_VERSION` (IDG/selection/reshape
semantics) — change the workload code, the trace VM's lowering, or the
analysis algorithms and the old artifacts become unreachable instead of
silently wrong.

Durability rules:

  * writes are atomic (temp file + ``os.replace``), so a concurrent reader
    never sees a partial artifact and concurrent writers of one key settle
    on one complete file;
  * loads verify a format stamp and the embedded key; anything unreadable
    or stale is dropped (counted in ``corrupt_drops``) and treated as a
    miss — the caller rebuilds and overwrites;
  * artifacts are self-contained: layer 1 rehydrates a full
    :class:`~repro.core.trace.TraceResult` (including the structural trace
    other geometries can replay) from the columns alone, layer 2 a
    ``(OffloadResult, ReshapedTrace)`` pair (see
    :func:`~repro.core.offload.rehydrate_analysis`).

``AnalysisCache(store=...)`` layers this under the in-memory memo, and
``DSEEngine(store=...)`` / ``examples/dse_cim.py --cache-dir`` expose it,
so a second CLI sweep over the same design space performs zero trace
builds, and ``executor="process"`` workers share one global analysis per
key through the store instead of rebuilding per worker.

Every key is additionally namespaced by the analysis *backend* that owns
the artifact: the CiM layer-1/2 keys above carry ``backend: "cim"``, and
non-CiM backends (:mod:`repro.dse.backends`) persist through the generic
:meth:`AnalysisStore.load_blob` / :meth:`AnalysisStore.save_blob` API with
their own key spec — which must include the backend's name and version
stamp, so CiM and TPU artifacts coexist in one cache directory and a
version bump invalidates exactly one backend's entries.
"""
from __future__ import annotations

import hashlib
import inspect
import io
import json
import os
import pathlib
import pickle
import tempfile
import threading
import zlib
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

from repro import obs
from repro.core.cache import CacheConfig, CacheHierarchy
from repro.core.columnar import ColumnarTrace
from repro.core.idg import FlowIndex
from repro.core.offload import ANALYSIS_VERSION, OffloadConfig, OffloadResult
from repro.core.reshape import ReshapedTrace
from repro.core.trace import (TRACE_VM_VERSION, StructuralTrace, TraceResult)

# Bump when the on-disk envelope (zlib-compressed {format, key, payload}
# pickle) changes.  v2: envelopes are compressed.
STORE_FORMAT = 2
# Bump when the layer-1 .npz column encoding changes.
NPZ_FORMAT = 1


def _fsize(path: pathlib.Path) -> int:
    """On-disk size for span attribution; 0 when absent/unreadable."""
    try:
        return path.stat().st_size
    except OSError:
        return 0


class StoreFormatError(RuntimeError):
    """The cache directory was written by a *newer* ``STORE_FORMAT``.

    Older artifacts under a new reader are individually dropped by the
    per-file format stamp; a newer directory under an old reader would be
    silently treated as 100% misses and then *overwritten*, destroying
    the newer build's cache — so that case refuses to open instead."""

_FINGERPRINTS: Dict[str, str] = {}


def workload_fingerprint(workload: str) -> str:
    """Content hash of a workload: its name + the builder module's source.

    Editing any code in the module that defines the workload's builder
    invalidates every persisted analysis of it.  Unknown workloads (or
    unreadable source, e.g. frozen deployments) degrade to a name-only
    fingerprint — still correct across runs of one build, just less
    sensitive to code changes."""
    cached = _FINGERPRINTS.get(workload)
    if cached is not None:
        return cached
    src = ""
    try:
        from repro.workloads import WORKLOADS   # late: keep the store importable
        builder = WORKLOADS.get(workload)
        if builder is not None:
            src = inspect.getsource(inspect.getmodule(builder))
    except (OSError, TypeError, ImportError):
        src = ""
    digest = hashlib.sha256(f"{workload}\n{src}".encode()).hexdigest()[:16]
    _FINGERPRINTS[workload] = digest
    return digest


def _cache_geometry(levels: Sequence[CacheConfig]) -> list:
    """Full per-level geometry — two configs with equal sizes but different
    associativity/banking must never share an artifact."""
    return [[c.name, c.size, c.assoc, c.banks, c.mshrs] for c in levels]


def _offload_spec(cfg: OffloadConfig) -> dict:
    return {
        "cim_set": sorted(cfg.cim_set),
        "cim_levels": list(cfg.cim_levels),
        "require_same_bank": cfg.require_same_bank,
        "allow_cross_level": cfg.allow_cross_level,
        "min_mem_operands": cfg.min_mem_operands,
        "min_load_leaves": cfg.min_load_leaves,
        "max_tree_ops": cfg.max_tree_ops,
    }


class AnalysisStore:
    """Content-addressed on-disk artifact store (one directory tree).

    ``version`` defaults to the running trace VM's version; passing an
    explicit value exists for tests and for pinning a store to an older VM.
    Hit/miss/write/corruption counters mirror the in-memory cache's build
    counters so sweeps can *prove* a warm second run did no analysis work.
    """

    def __init__(self, root: Union[str, pathlib.Path],
                 version: int = TRACE_VM_VERSION):
        self.root = pathlib.Path(root).expanduser()
        self.version = int(version)
        self._check_format_marker()
        for layer in ("layer1", "layer2"):
            (self.root / layer).mkdir(parents=True, exist_ok=True)
        # counters are shared by thread-pool sweeps and asserted on exactly
        # by tests/CI, so increments go through a lock
        self._stats_lock = threading.Lock()
        self._usage_cache: Optional[Dict[str, int]] = None  # lint: guarded-by(_stats_lock)
        self.l1_hits = 0            # lint: guarded-by(_stats_lock)
        self.l1_misses = 0          # lint: guarded-by(_stats_lock)
        self.l2_hits = 0            # lint: guarded-by(_stats_lock)
        self.l2_misses = 0          # lint: guarded-by(_stats_lock)
        self.writes = 0             # lint: guarded-by(_stats_lock)
        self.corrupt_drops = 0      # lint: guarded-by(_stats_lock)

    def _check_format_marker(self) -> None:
        """Refuse directories written by a newer STORE_FORMAT; (re)stamp
        the marker otherwise.  An unreadable marker counts as absent —
        the per-artifact format stamps still protect every load."""
        marker = self.root / "FORMAT.json"
        written: Optional[int] = None
        try:
            written = int(json.loads(marker.read_text())["store_format"])
        except (OSError, ValueError, KeyError, TypeError):
            written = None
        if written is not None and written > STORE_FORMAT:
            raise StoreFormatError(
                f"cache directory {self.root} was written by STORE_FORMAT="
                f"{written}, but this build reads STORE_FORMAT="
                f"{STORE_FORMAT}. Upgrade this build, or point --cache-dir "
                f"at a fresh directory (reusing it here would overwrite "
                f"the newer build's artifacts).")
        if written != STORE_FORMAT:
            self.root.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump({"store_format": STORE_FORMAT}, f)
            os.replace(tmp, marker)

    def _bump(self, counter: str, by: int = 1) -> None:
        with self._stats_lock:
            setattr(self, counter, getattr(self, counter) + by)
            if counter in ("writes", "corrupt_drops"):
                self._usage_cache = None        # disk contents changed

    def invalidate_usage_cache(self) -> None:
        """Force the next ``disk_usage()`` to re-walk (callers that know
        another process just wrote — e.g. after a process-pool sweep)."""
        with self._stats_lock:
            self._usage_cache = None

    def _drop(self, path: pathlib.Path) -> None:
        """Remove an artifact that failed verification/rehydration."""
        self._bump("corrupt_drops")
        try:
            path.unlink()
        except OSError:
            pass

    # -------------------------------------------------------------- keys
    def _key(self, spec: dict) -> str:
        doc = json.dumps(spec, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(doc.encode()).hexdigest()[:32]

    def layer1_key(self, workload: str,
                   cache_levels: Sequence[CacheConfig]) -> str:
        return self._key({
            "layer": 1,
            "backend": "cim",               # namespaced: backends share a dir
            "workload": workload,
            "fingerprint": workload_fingerprint(workload),
            "cache": _cache_geometry(cache_levels),
            "trace_vm": self.version,
        })

    def layer2_key(self, workload: str, cache_levels: Sequence[CacheConfig],
                   cfg: OffloadConfig) -> str:
        return self._key({
            "layer": 2,
            "backend": "cim",
            "workload": workload,
            "fingerprint": workload_fingerprint(workload),
            "cache": _cache_geometry(cache_levels),
            "trace_vm": self.version,
            "analysis": ANALYSIS_VERSION,   # selection/reshape semantics
            "offload": _offload_spec(cfg),
        })

    def _path(self, layer: int, key: str, backend: str = "cim",
              suffix: str = "pkl") -> pathlib.Path:
        # filenames lead with the owning backend so per-backend disk usage
        # (`stats()["store_bytes_<backend>"]`) is attributable by name
        return self.root / f"layer{layer}" / f"{backend}-{key}.{suffix}"

    # ------------------------------------------------- generic backend blobs
    # Non-CiM analysis backends persist their artifacts through these: the
    # caller owns the key spec (and must mix in its backend name + version
    # stamp — see repro.dse.backends), the store owns addressing, atomic
    # writes, verification, and the hit/miss/write counters.  Specs from
    # different backends can never collide (the "backend" field namespaces
    # them), so CiM and TPU artifacts coexist in one cache directory.
    def load_blob(self, layer: int, spec: dict) -> Optional[dict]:
        key = self._key({"layer": layer, **spec})
        backend = str(spec.get("backend", "blob"))
        path = self._path(layer, key, backend)
        # span dur covers read + zlib inflate + pickle (see _read)
        with obs.span("store.load_blob", cat="store", layer=layer,
                      backend=backend) as sp:
            payload = self._read(path, key)
            if payload is None:
                self._bump("l1_misses" if layer == 1 else "l2_misses")
                sp.set(hit=False)
                return None
            self._bump("l1_hits" if layer == 1 else "l2_hits")
            sp.set(hit=True, bytes=_fsize(path))
            return payload

    def save_blob(self, layer: int, spec: dict, payload: dict) -> None:
        key = self._key({"layer": layer, **spec})
        backend = str(spec.get("backend", "blob"))
        path = self._path(layer, key, backend)
        # span dur covers pickle + zlib deflate + atomic publish
        with obs.span("store.save_blob", cat="store", layer=layer,
                      backend=backend) as sp:
            self._write(path, key, payload)
            sp.set(bytes=_fsize(path))

    # ---------------------------------------------------------------- io
    def _read(self, path: pathlib.Path, expect_key: str) -> Optional[dict]:
        """Load + verify one artifact; anything wrong is a recoverable miss."""
        try:
            with open(path, "rb") as f:
                doc = pickle.loads(zlib.decompress(f.read()))
        except FileNotFoundError:
            return None
        except Exception:
            doc = None
        if (not isinstance(doc, dict) or doc.get("format") != STORE_FORMAT
                or doc.get("key") != expect_key
                or not isinstance(doc.get("payload"), dict)):
            self._bump("corrupt_drops")
            try:
                path.unlink()
            except OSError:
                pass
            return None
        return doc["payload"]

    def _write(self, path: pathlib.Path, key: str, payload: dict) -> None:
        """Atomic publish: readers see the old artifact or the new one,
        never bytes in between; racing writers settle on a complete file."""
        data = zlib.compress(pickle.dumps(
            {"format": STORE_FORMAT, "key": key, "payload": payload},
            protocol=pickle.HIGHEST_PROTOCOL))
        fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name,
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._bump("writes")

    # ------------------------------------------------------------ layer 1
    # Layer-1 artifacts are compressed .npz column archives, not pickles:
    # one numpy array per I-state column (repro.core.columnar), the cache
    # hit/miss counters, and the program outputs.  The trace and its flow
    # tables live in two sibling files under one key: the trace archive is
    # written once when first built, and the flow file appears later when
    # an analysis first needs it — upgrading a key never re-serializes the
    # trace, and a concurrent trace-only save can never downgrade an
    # artifact that already has flow tables.
    def _flow_path(self, key: str) -> pathlib.Path:
        # the flow tables additionally depend on the IDG/flow construction
        # semantics, which the trace half of the key does not cover
        return self.root / "layer1" / f"cim-{key}.flow-v{ANALYSIS_VERSION}.npz"

    # ---- npz envelope ----------------------------------------------------
    def _write_npz(self, path: pathlib.Path, key: str,
                   arrays: Dict[str, np.ndarray]) -> None:
        buf = io.BytesIO()
        np.savez_compressed(
            buf,
            meta_store_key=np.frombuffer(key.encode(), dtype=np.uint8),
            meta_npz_format=np.asarray([NPZ_FORMAT], np.int64),
            **arrays)
        data = buf.getvalue()
        fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name,
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._bump("writes")

    def _read_npz(self, path: pathlib.Path,
                  expect_key: str) -> Optional[Dict[str, np.ndarray]]:
        """Load + verify one .npz artifact; anything wrong is a miss."""
        try:
            with np.load(path) as z:
                arrays = {k: z[k] for k in z.files}
            key = bytes(arrays["meta_store_key"]).decode()
            fmt = int(arrays["meta_npz_format"][0])
            if key != expect_key or fmt != NPZ_FORMAT:
                raise ValueError("stale or foreign artifact")
            return arrays
        except FileNotFoundError:
            return None
        except Exception:
            self._bump("corrupt_drops")
            try:
                path.unlink()
            except OSError:
                pass
            return None

    def load_layer1(self, workload: str, cache_levels: Sequence[CacheConfig]
                    ) -> Optional[Tuple[TraceResult, Optional[FlowIndex]]]:
        key = self.layer1_key(workload, cache_levels)
        trace_path = self._path(1, key, suffix="npz")
        # span dur covers read + zlib inflate + columnar rehydration
        with obs.span("store.load_l1", cat="store", layer=1,
                      workload=workload) as sp:
            return self._load_layer1(cache_levels, key, trace_path, sp)

    def _load_layer1(self, cache_levels: Sequence[CacheConfig], key: str,
                     trace_path: pathlib.Path, sp
                     ) -> Optional[Tuple[TraceResult, Optional[FlowIndex]]]:
        arrays = self._read_npz(trace_path, key)
        if arrays is None:
            self._bump("l1_misses")
            sp.set(hit=False)
            return None
        try:
            ct = ColumnarTrace.from_arrays(arrays)
            hier = CacheHierarchy(tuple(cache_levels))
            hier.restore_counters(dict(zip(
                [str(s) for s in arrays["meta_cc_names"]],
                arrays["meta_cc_vals"].tolist())))
            outputs = [arrays[f"out_{i}"]
                       for i in range(int(arrays["meta_n_outputs"][0]))]
        except Exception:
            # drop the archive, not just the load: save_layer1 skips keys
            # whose file exists, so a bad-but-readable artifact must leave
            # the filesystem or it would never be repaired
            self._drop(trace_path)
            self._bump("l1_misses")
            sp.set(hit=False, corrupt=True)
            return None
        tr = TraceResult(ct, hier, outputs,
                         structural=StructuralTrace(ct, outputs))
        flow_arrays = self._read_npz(self._flow_path(key), key)
        flow = None
        if flow_arrays is not None:
            try:
                flow = FlowIndex.from_arrays(flow_arrays)
            except Exception:
                self._drop(self._flow_path(key))
        self._bump("l1_hits")
        sp.set(hit=True, bytes=_fsize(trace_path) + _fsize(self._flow_path(key)))
        return tr, flow

    def save_layer1(self, workload: str, cache_levels: Sequence[CacheConfig],
                    trace_result: TraceResult,
                    flow: Optional[FlowIndex] = None) -> None:
        key = self.layer1_key(workload, cache_levels)
        trace_path = self._path(1, key, suffix="npz")
        # span dur covers columnar flatten + zlib deflate + atomic publish
        with obs.span("store.save_l1", cat="store", layer=1,
                      workload=workload) as sp:
            if not trace_path.exists():  # traces are deterministic per key:
                arrays = trace_result.trace.to_arrays()
                counters = trace_result.cache.counters()
                arrays["meta_cc_names"] = np.asarray(list(counters),
                                                     dtype="U")
                arrays["meta_cc_vals"] = np.asarray(list(counters.values()),
                                                    np.int64)
                arrays["meta_n_outputs"] = np.asarray(
                    [len(trace_result.outputs)], np.int64)
                for i, out in enumerate(trace_result.outputs):
                    arrays[f"out_{i}"] = np.asarray(out)
                self._write_npz(trace_path, key, arrays)
            if flow is not None and not self._flow_path(key).exists():
                self._write_npz(self._flow_path(key), key, flow.to_arrays())
            sp.set(bytes=_fsize(trace_path) + _fsize(self._flow_path(key)))

    # ------------------------------------------------------------ layer 2
    def load_layer2(self, workload: str, cache_levels: Sequence[CacheConfig],
                    cfg: OffloadConfig
                    ) -> Optional[Tuple[OffloadResult, ReshapedTrace]]:
        key = self.layer2_key(workload, cache_levels, cfg)
        path = self._path(2, key)
        # span dur covers read + zlib inflate + pickle (see _read)
        with obs.span("store.load_l2", cat="store", layer=2,
                      workload=workload) as sp:
            payload = self._read(path, key)
            if payload is None:
                self._bump("l2_misses")
                sp.set(hit=False)
                return None
            self._bump("l2_hits")
            sp.set(hit=True, bytes=_fsize(path))
            return payload["offload"], payload["reshaped"]

    def save_layer2(self, workload: str, cache_levels: Sequence[CacheConfig],
                    cfg: OffloadConfig, offload: OffloadResult,
                    reshaped: ReshapedTrace) -> None:
        key = self.layer2_key(workload, cache_levels, cfg)
        path = self._path(2, key)
        # span dur covers pickle + zlib deflate + atomic publish
        with obs.span("store.save_l2", cat="store", layer=2,
                      workload=workload) as sp:
            self._write(path, key,
                        {"offload": offload, "reshaped": reshaped})
            sp.set(bytes=_fsize(path))

    # -------------------------------------------------------------- misc
    def disk_usage(self) -> Dict[str, int]:
        """On-disk bytes, per layer and per owning backend (filenames lead
        with the backend name, so attribution is a directory walk).

        The walk result is cached and invalidated by this handle's own
        writes/drops, so the repeated ``stats()`` reads on the sweep hot
        path (run deltas, worker-chunk deltas) stay O(1); another
        process's concurrent writes surface on this handle's next write
        or a fresh ``AnalysisStore``."""
        with self._stats_lock:
            cached = self._usage_cache
        if cached is not None:
            return dict(cached)
        out = {"store_bytes_total": 0, "store_bytes_layer1": 0,
               "store_bytes_layer2": 0}
        for layer in ("layer1", "layer2"):
            d = self.root / layer
            if not d.is_dir():
                continue
            for f in d.iterdir():
                try:
                    sz = f.stat().st_size
                except OSError:
                    continue
                out["store_bytes_total"] += sz
                out[f"store_bytes_{layer}"] += sz
                # backend prefix before the first dash; files that predate
                # the prefixed naming (or don't match a plausible backend
                # name) land under "unknown"
                backend = f.name.split("-", 1)[0]
                if not ("-" in f.name and backend.isalpha()
                        and len(backend) <= 16):
                    backend = "unknown"
                bkey = f"store_bytes_{backend}"
                out[bkey] = out.get(bkey, 0) + sz
        # publish under the lock: a concurrent _bump() invalidation must
        # not lose against this (possibly stale) walk result being cached
        with self._stats_lock:
            self._usage_cache = dict(out)
        return out

    def stats(self) -> Dict[str, int]:
        return {"store_l1_hits": self.l1_hits,
                "store_l1_misses": self.l1_misses,
                "store_l2_hits": self.l2_hits,
                "store_l2_misses": self.l2_misses,
                "store_writes": self.writes,
                "store_corrupt_drops": self.corrupt_drops,
                **self.disk_usage()}

    def __repr__(self) -> str:
        return (f"AnalysisStore({str(self.root)!r}, version={self.version}, "
                f"l1={self.l1_hits}h/{self.l1_misses}m, "
                f"l2={self.l2_hits}h/{self.l2_misses}m)")
