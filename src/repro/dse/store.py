"""Persistent, content-addressed analysis store — cross-process memoization.

The in-memory :class:`~repro.dse.engine.AnalysisCache` makes one *engine*
cheap; this module makes repeated *invocations* cheap.  An
:class:`AnalysisStore` persists the two expensive sweep layers on disk:

  Layer 1 — traced program (the CIQ + RUT/IHT + cache state) and the
  IDG/flow tables, keyed by ``(workload fingerprint, cache geometry,
  trace-VM version)``;
  Layer 2 — accepted candidates + the reshaped trace, keyed by the layer-1
  key plus the full :class:`~repro.core.offload.OffloadConfig`.

Keys are content-addressed: the workload fingerprint hashes the builder
module's *source*, the cache key is the full geometry (size/assoc/banks/
MSHRs, never the display name), every key mixes in
:data:`~repro.core.trace.TRACE_VM_VERSION`, and the flow/selection
artifacts additionally mix in
:data:`~repro.core.offload.ANALYSIS_VERSION` (IDG/selection/reshape
semantics) — change the workload code, the trace VM's lowering, or the
analysis algorithms and the old artifacts become unreachable instead of
silently wrong.

Durability rules:

  * writes are atomic (temp file + ``os.replace``), so a concurrent reader
    never sees a partial artifact and concurrent writers of one key settle
    on one complete file;
  * loads verify a format stamp and the embedded key; anything unreadable
    or stale is dropped (counted in ``corrupt_drops``) and treated as a
    miss — the caller rebuilds and overwrites;
  * artifacts are self-contained pickles (see the serialization hooks on
    :class:`~repro.core.isa.Inst` and
    :func:`~repro.core.offload.rehydrate_analysis`).

``AnalysisCache(store=...)`` layers this under the in-memory memo, and
``DSEEngine(store=...)`` / ``examples/dse_cim.py --cache-dir`` expose it,
so a second CLI sweep over the same design space performs zero trace
builds, and ``executor="process"`` workers share one global analysis per
key through the store instead of rebuilding per worker.

Every key is additionally namespaced by the analysis *backend* that owns
the artifact: the CiM layer-1/2 keys above carry ``backend: "cim"``, and
non-CiM backends (:mod:`repro.dse.backends`) persist through the generic
:meth:`AnalysisStore.load_blob` / :meth:`AnalysisStore.save_blob` API with
their own key spec — which must include the backend's name and version
stamp, so CiM and TPU artifacts coexist in one cache directory and a
version bump invalidates exactly one backend's entries.
"""
from __future__ import annotations

import hashlib
import inspect
import json
import os
import pathlib
import pickle
import tempfile
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

from repro.core.cache import CacheConfig
from repro.core.idg import FlowIndex
from repro.core.offload import ANALYSIS_VERSION, OffloadConfig, OffloadResult
from repro.core.reshape import ReshapedTrace
from repro.core.trace import TRACE_VM_VERSION, TraceResult

# Bump when the on-disk envelope ({format, key, payload} pickle) changes.
STORE_FORMAT = 1

_FINGERPRINTS: Dict[str, str] = {}


def workload_fingerprint(workload: str) -> str:
    """Content hash of a workload: its name + the builder module's source.

    Editing any code in the module that defines the workload's builder
    invalidates every persisted analysis of it.  Unknown workloads (or
    unreadable source, e.g. frozen deployments) degrade to a name-only
    fingerprint — still correct across runs of one build, just less
    sensitive to code changes."""
    cached = _FINGERPRINTS.get(workload)
    if cached is not None:
        return cached
    src = ""
    try:
        from repro.workloads import WORKLOADS   # late: keep the store importable
        builder = WORKLOADS.get(workload)
        if builder is not None:
            src = inspect.getsource(inspect.getmodule(builder))
    except (OSError, TypeError, ImportError):
        src = ""
    digest = hashlib.sha256(f"{workload}\n{src}".encode()).hexdigest()[:16]
    _FINGERPRINTS[workload] = digest
    return digest


def _cache_geometry(levels: Sequence[CacheConfig]) -> list:
    """Full per-level geometry — two configs with equal sizes but different
    associativity/banking must never share an artifact."""
    return [[c.name, c.size, c.assoc, c.banks, c.mshrs] for c in levels]


def _offload_spec(cfg: OffloadConfig) -> dict:
    return {
        "cim_set": sorted(cfg.cim_set),
        "cim_levels": list(cfg.cim_levels),
        "require_same_bank": cfg.require_same_bank,
        "allow_cross_level": cfg.allow_cross_level,
        "min_mem_operands": cfg.min_mem_operands,
        "min_load_leaves": cfg.min_load_leaves,
        "max_tree_ops": cfg.max_tree_ops,
    }


class AnalysisStore:
    """Content-addressed on-disk artifact store (one directory tree).

    ``version`` defaults to the running trace VM's version; passing an
    explicit value exists for tests and for pinning a store to an older VM.
    Hit/miss/write/corruption counters mirror the in-memory cache's build
    counters so sweeps can *prove* a warm second run did no analysis work.
    """

    def __init__(self, root: Union[str, pathlib.Path],
                 version: int = TRACE_VM_VERSION):
        self.root = pathlib.Path(root).expanduser()
        self.version = int(version)
        for layer in ("layer1", "layer2"):
            (self.root / layer).mkdir(parents=True, exist_ok=True)
        # counters are shared by thread-pool sweeps and asserted on exactly
        # by tests/CI, so increments go through a lock
        self._stats_lock = threading.Lock()
        self.l1_hits = 0
        self.l1_misses = 0
        self.l2_hits = 0
        self.l2_misses = 0
        self.writes = 0
        self.corrupt_drops = 0

    def _bump(self, counter: str, by: int = 1) -> None:
        with self._stats_lock:
            setattr(self, counter, getattr(self, counter) + by)

    # -------------------------------------------------------------- keys
    def _key(self, spec: dict) -> str:
        doc = json.dumps(spec, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(doc.encode()).hexdigest()[:32]

    def layer1_key(self, workload: str,
                   cache_levels: Sequence[CacheConfig]) -> str:
        return self._key({
            "layer": 1,
            "backend": "cim",               # namespaced: backends share a dir
            "workload": workload,
            "fingerprint": workload_fingerprint(workload),
            "cache": _cache_geometry(cache_levels),
            "trace_vm": self.version,
        })

    def layer2_key(self, workload: str, cache_levels: Sequence[CacheConfig],
                   cfg: OffloadConfig) -> str:
        return self._key({
            "layer": 2,
            "backend": "cim",
            "workload": workload,
            "fingerprint": workload_fingerprint(workload),
            "cache": _cache_geometry(cache_levels),
            "trace_vm": self.version,
            "analysis": ANALYSIS_VERSION,   # selection/reshape semantics
            "offload": _offload_spec(cfg),
        })

    def _path(self, layer: int, key: str) -> pathlib.Path:
        return self.root / f"layer{layer}" / f"{key}.pkl"

    # ------------------------------------------------- generic backend blobs
    # Non-CiM analysis backends persist their artifacts through these: the
    # caller owns the key spec (and must mix in its backend name + version
    # stamp — see repro.dse.backends), the store owns addressing, atomic
    # writes, verification, and the hit/miss/write counters.  Specs from
    # different backends can never collide (the "backend" field namespaces
    # them), so CiM and TPU artifacts coexist in one cache directory.
    def load_blob(self, layer: int, spec: dict) -> Optional[dict]:
        key = self._key({"layer": layer, **spec})
        payload = self._read(self._path(layer, key), key)
        if payload is None:
            self._bump("l1_misses" if layer == 1 else "l2_misses")
            return None
        self._bump("l1_hits" if layer == 1 else "l2_hits")
        return payload

    def save_blob(self, layer: int, spec: dict, payload: dict) -> None:
        key = self._key({"layer": layer, **spec})
        self._write(self._path(layer, key), key, payload)

    # ---------------------------------------------------------------- io
    def _read(self, path: pathlib.Path, expect_key: str) -> Optional[dict]:
        """Load + verify one artifact; anything wrong is a recoverable miss."""
        try:
            with open(path, "rb") as f:
                doc = pickle.load(f)
        except FileNotFoundError:
            return None
        except Exception:
            doc = None
        if (not isinstance(doc, dict) or doc.get("format") != STORE_FORMAT
                or doc.get("key") != expect_key
                or not isinstance(doc.get("payload"), dict)):
            self._bump("corrupt_drops")
            try:
                path.unlink()
            except OSError:
                pass
            return None
        return doc["payload"]

    def _write(self, path: pathlib.Path, key: str, payload: dict) -> None:
        """Atomic publish: readers see the old artifact or the new one,
        never bytes in between; racing writers settle on a complete file."""
        fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name,
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump({"format": STORE_FORMAT, "key": key,
                             "payload": payload},
                            f, protocol=pickle.HIGHEST_PROTOCOL)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._bump("writes")

    # ------------------------------------------------------------ layer 1
    # The trace and its flow tables live in two sibling files under one key:
    # the (large) trace pickle is written once when first built, and the
    # flow file appears later when an analysis first needs it — upgrading a
    # key never re-serializes the trace, and a concurrent trace-only save
    # can never downgrade an artifact that already has flow tables.
    def _flow_path(self, key: str) -> pathlib.Path:
        # the flow tables additionally depend on the IDG/flow construction
        # semantics, which the trace half of the key does not cover
        return self.root / "layer1" / f"{key}.flow-v{ANALYSIS_VERSION}.pkl"

    def load_layer1(self, workload: str, cache_levels: Sequence[CacheConfig]
                    ) -> Optional[Tuple[TraceResult, Optional[FlowIndex]]]:
        key = self.layer1_key(workload, cache_levels)
        payload = self._read(self._path(1, key), key)
        if payload is None:
            self._bump("l1_misses")
            return None
        flow_payload = self._read(self._flow_path(key), key)
        self._bump("l1_hits")
        return (payload["trace"],
                flow_payload["flow"] if flow_payload is not None else None)

    def save_layer1(self, workload: str, cache_levels: Sequence[CacheConfig],
                    trace_result: TraceResult,
                    flow: Optional[FlowIndex] = None) -> None:
        key = self.layer1_key(workload, cache_levels)
        trace_path = self._path(1, key)
        if not trace_path.exists():     # traces are deterministic per key:
            self._write(trace_path, key, {"trace": trace_result})
        if flow is not None:
            self._write(self._flow_path(key), key, {"flow": flow})

    # ------------------------------------------------------------ layer 2
    def load_layer2(self, workload: str, cache_levels: Sequence[CacheConfig],
                    cfg: OffloadConfig
                    ) -> Optional[Tuple[OffloadResult, ReshapedTrace]]:
        key = self.layer2_key(workload, cache_levels, cfg)
        payload = self._read(self._path(2, key), key)
        if payload is None:
            self._bump("l2_misses")
            return None
        self._bump("l2_hits")
        return payload["offload"], payload["reshaped"]

    def save_layer2(self, workload: str, cache_levels: Sequence[CacheConfig],
                    cfg: OffloadConfig, offload: OffloadResult,
                    reshaped: ReshapedTrace) -> None:
        key = self.layer2_key(workload, cache_levels, cfg)
        self._write(self._path(2, key), key,
                    {"offload": offload, "reshaped": reshaped})

    # -------------------------------------------------------------- misc
    def stats(self) -> Dict[str, int]:
        return {"store_l1_hits": self.l1_hits,
                "store_l1_misses": self.l1_misses,
                "store_l2_hits": self.l2_hits,
                "store_l2_misses": self.l2_misses,
                "store_writes": self.writes,
                "store_corrupt_drops": self.corrupt_drops}

    def __repr__(self) -> str:
        return (f"AnalysisStore({str(self.root)!r}, version={self.version}, "
                f"l1={self.l1_hits}h/{self.l1_misses}m, "
                f"l2={self.l2_hits}h/{self.l2_misses}m)")
