from repro.ft.manager import FaultTolerantRunner, StragglerMonitor
