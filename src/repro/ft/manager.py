"""Fault tolerance: checkpoint/restart orchestration, straggler detection,
and elastic re-meshing — the runtime layer a 1000+ node deployment needs.

Design (CPU-testable, mesh-agnostic):

* ``StragglerMonitor`` — rolling per-step wall-time statistics; flags steps
  slower than ``threshold`` x the rolling median (ICI-jitter tolerant) and
  recommends mitigation (re-shard victim host's data / restart the worker).
  On a real pod this feeds the control plane; here it logs + counts.

* ``FaultTolerantRunner`` — wraps a train loop with (i) auto-resume from
  the newest checkpoint, (ii) periodic async saves, (iii) a failure hook:
  on any step exception it saves a salvage snapshot, re-builds the mesh
  from the devices that remain (``elastic_remesh``), re-shards state, and
  resumes — the data pipeline's pure ``batch_at(step)`` guarantees no data
  drift across the restart.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager


class StragglerMonitor:
    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.times = collections.deque(maxlen=window)
        self.straggles: List[Tuple[int, float]] = []

    def observe(self, step: int, seconds: float) -> bool:
        """Record a step time; True if this step straggled."""
        is_straggler = False
        if len(self.times) >= max(4, self.window // 4):
            med = statistics.median(self.times)
            if seconds > self.threshold * med:
                self.straggles.append((step, seconds))
                is_straggler = True
        self.times.append(seconds)
        return is_straggler

    def report(self) -> Dict[str, Any]:
        med = statistics.median(self.times) if self.times else 0.0
        return {"median_s": med, "n_straggles": len(self.straggles),
                "straggle_steps": [s for s, _ in self.straggles[-8:]]}


def elastic_remesh(min_model_parallel: int = 1):
    """Build the largest (data, model) mesh the *currently live* devices
    support — after losing a host, training resumes on fewer devices with
    the same global batch (per-device batch grows)."""
    devs = jax.devices()
    n = len(devs)
    mp = min_model_parallel
    while n % mp:
        mp -= 1
    return jax.make_mesh((n // mp, mp), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@dataclasses.dataclass
class RunReport:
    steps_run: int
    resumed_from: Optional[int]
    failures_recovered: int
    straggler: Dict[str, Any]
    final_metrics: Dict[str, float]


class FaultTolerantRunner:
    def __init__(self, ckpt_dir: str, *, save_every: int = 50, keep: int = 3,
                 max_recoveries: int = 3):
        self.ckpt = CheckpointManager(ckpt_dir, keep=keep, every=save_every)
        self.monitor = StragglerMonitor()
        self.max_recoveries = max_recoveries

    def run(self, state: Any, total_steps: int,
            step_fn: Callable[[Any, Any], Tuple[Any, Dict]],
            batch_at: Callable[[int], Any],
            *, on_failure: Optional[Callable[[int, Exception], None]] = None,
            log_every: int = 10,
            fail_at: Optional[int] = None) -> Tuple[Any, RunReport]:
        """Run ``total_steps`` with auto-resume.  ``fail_at`` injects one
        synthetic failure (tests/examples exercise the recovery path)."""
        resumed_from, state = self.ckpt.restore_latest(state)
        start = 0 if resumed_from is None else resumed_from + 1
        failures = 0
        metrics: Dict[str, float] = {}
        injected = [fail_at]
        step = start
        while step < total_steps:
            t0 = time.perf_counter()
            try:
                if injected[0] is not None and step == injected[0]:
                    injected[0] = None
                    raise RuntimeError("injected node failure")
                state, m = step_fn(state, batch_at(step))
                metrics = {k: float(v) for k, v in m.items()}
            except Exception as e:  # noqa: BLE001 — the recovery path
                failures += 1
                if on_failure is not None:
                    on_failure(step, e)
                if failures > self.max_recoveries:
                    raise
                # salvage -> resume from the newest durable snapshot
                self.ckpt.wait()
                resumed, state = self.ckpt.restore_latest(state)
                step = 0 if resumed is None else resumed + 1
                continue
            dt = time.perf_counter() - t0
            if self.monitor.observe(step, dt) and log_every:
                print(f"[ft] straggler at step {step}: {dt:.3f}s", flush=True)
            self.ckpt.maybe_save(step, state)
            if log_every and step % log_every == 0:
                print(f"[train] step {step} " +
                      " ".join(f"{k}={v:.4f}" for k, v in metrics.items()),
                      flush=True)
            step += 1
        self.ckpt.maybe_save(total_steps - 1, state, force=True)
        self.ckpt.wait()
        return state, RunReport(total_steps - start, resumed_from, failures,
                                self.monitor.report(), metrics)
