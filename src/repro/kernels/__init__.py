"""Pallas TPU kernels — the realized "CiM modules" of the TPU adaptation.

Each kernel keeps its operands VMEM-resident for the whole computation —
one HBM round-trip instead of one per op — which is the TPU-native form of
the paper's in-memory offloading (DESIGN.md S3):

  cim_bitwise      bulk AND/OR/XOR/ADD (Table III's op set; compute-caches
                   [20] / Pinatubo [22] style row-parallel ops)
  flash_attention  softmax(QK^T)V computed where the KV block lives
  mlstm_chunk      xLSTM matrix-memory recurrence, state never leaves VMEM

``ops.py`` holds the jit'd public wrappers; ``ref.py`` the pure-jnp
oracles every kernel is validated against (interpret=True on CPU).
"""
