"""Bulk bitwise/arithmetic CiM kernel (pl.pallas_call + BlockSpec).

The literal op set of paper Table III — {OR, AND, XOR, ADDW32} — realized
as a row-parallel one-pass kernel: both operand tiles are brought into
VMEM once, the op happens "in the array", and only the result returns to
HBM.  Block shape (256, 512) int32 = 512 KiB/tile keeps three tiles well
under the ~128 MiB v5e VMEM while filling the (8, 128) VPU lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# default tile: multiples of the f32/int32 (8, 128) VPU tile
BLOCK_R = 256
BLOCK_C = 512

_OPS = {
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "xor": lambda a, b: a ^ b,
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
}


def _kernel(op_fn, x_ref, y_ref, o_ref):
    o_ref[...] = op_fn(x_ref[...], y_ref[...])


@functools.partial(jax.jit, static_argnames=("op", "block_r", "block_c",
                                             "interpret"))
def cim_bitwise(x: jax.Array, y: jax.Array, *, op: str = "and",
                block_r: int = BLOCK_R, block_c: int = BLOCK_C,
                interpret: bool = False) -> jax.Array:
    """Elementwise CiM op over 2D int arrays; shapes must tile evenly
    (ops.py pads ragged inputs)."""
    assert x.shape == y.shape and x.ndim == 2, (x.shape, y.shape)
    R, C = x.shape
    br, bc = min(block_r, R), min(block_c, C)
    assert R % br == 0 and C % bc == 0, (x.shape, br, bc)
    grid = (R // br, C // bc)
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_kernel, _OPS[op]),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, y)


def _fused_kernel(op_fns, x_ref, y_ref, z_ref, o_ref):
    t = op_fns[0](x_ref[...], y_ref[...])
    o_ref[...] = op_fns[1](t, z_ref[...])


@functools.partial(jax.jit, static_argnames=("op1", "op2", "block_r",
                                             "block_c", "interpret"))
def cim_bitwise_fused(x: jax.Array, y: jax.Array, z: jax.Array, *,
                      op1: str = "add", op2: str = "xor",
                      block_r: int = BLOCK_R, block_c: int = BLOCK_C,
                      interpret: bool = False) -> jax.Array:
    """Composite candidate — (x op1 y) op2 z in ONE array pass (the IDG
    subtree of Fig. 5 as a single fused kernel)."""
    R, C = x.shape
    br, bc = min(block_r, R), min(block_c, C)
    assert R % br == 0 and C % bc == 0
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_fused_kernel, (_OPS[op1], _OPS[op2])),
        grid=(R // br, C // bc),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, y, z)
