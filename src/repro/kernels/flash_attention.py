"""Flash attention Pallas kernel (pl.pallas_call + BlockSpec VMEM tiling).

Grid = (batch*kv_heads*groups, num_q_blocks, num_kv_blocks); the last grid
dimension iterates sequentially on TPU, so the online-softmax state
(m, l, acc) lives in VMEM scratch and is revised as KV blocks stream
through — softmax(QK^T)V computed where the KV lives, never materializing
the (Sq, Skv) score matrix.  Causal + sliding-window masking via
program-id arithmetic; block shapes default to MXU-aligned (128, head_dim).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(causal: bool, window: int, sm_scale: float, block_q: int,
                  block_k: int, num_kv_blocks: int,
                  q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, d)
    k = k_ref[0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = mask & (q_pos >= k_pos)
    if window > 0:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # (bq, 1)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, d); k, v: (B, Hkv, Skv, d); GQA via H % Hkv == 0.
    Sq/Skv must tile by block_q/block_k (ops.py pads)."""
    B, H, Sq, d = q.shape
    Bk, Hkv, Skv, dk = k.shape
    assert (B, d) == (Bk, dk) and H % Hkv == 0
    G = H // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, Skv, bq, bk)
    nq, nk = Sq // bq, Skv // bk
    sm_scale = 1.0 / math.sqrt(d)

    qr = q.reshape(B * H, Sq, d)
    kr = jnp.repeat(k, G, axis=1).reshape(B * H, Skv, d)
    vr = jnp.repeat(v, G, axis=1).reshape(B * H, Skv, d)

    kernel = functools.partial(_flash_kernel, causal, window, sm_scale,
                               bq, bk, nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),            # running max m
            pltpu.VMEM((bq, 1), jnp.float32),            # running sum l
            pltpu.VMEM((bq, d), jnp.float32),            # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, d)
