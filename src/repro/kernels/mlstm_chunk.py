"""Chunkwise mLSTM Pallas kernel — the xLSTM matrix-memory recurrence with
the (dh x dh) state held in VMEM scratch across the whole sequence.

Grid = (batch*heads, num_chunks); the chunk dimension iterates sequentially
on TPU so the stabilized state (C, n, m) persists in scratch between grid
steps — the state never round-trips HBM, which is the recurrent analogue of
the CiM offload (DESIGN.md §3).  Math matches ``repro.models.ssm``'s
stabilized chunkwise form exactly (ref.py delegates to it).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_CHUNK = 128


def _mlstm_kernel(chunk: int, dh: int,
                  q_ref, k_ref, v_ref, li_ref, lf_ref, o_ref,
                  C_ref, n_ref, m_ref):
    ci = pl.program_id(1)
    K = chunk
    scale = 1.0 / math.sqrt(dh)

    @pl.when(ci == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)

    q = q_ref[0].astype(jnp.float32)                     # (K, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    li = li_ref[0].astype(jnp.float32)[:, 0]             # (K,)
    lf = lf_ref[0].astype(jnp.float32)[:, 0]

    b = jnp.cumsum(lf)                                   # inclusive decay
    g = li - b                                           # log source weight
    m_prev = m_ref[0, 0]
    m_intra = jax.lax.cummax(g) + b
    m_inter = m_prev + b
    m_t = jnp.maximum(m_intra, m_inter)                  # (K,)

    logD = b[:, None] + g[None, :] - m_t[:, None]        # (K, K)
    t_pos = jax.lax.broadcasted_iota(jnp.int32, (K, K), 0)
    j_pos = jax.lax.broadcasted_iota(jnp.int32, (K, K), 1)
    D = jnp.where(t_pos >= j_pos, jnp.exp(logD), 0.0)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    w = s * D
    num = jnp.dot(w, v, preferred_element_type=jnp.float32)
    den = jnp.sum(w, axis=-1)

    inter_w = jnp.exp(m_inter - m_t)                     # (K,)
    num = num + inter_w[:, None] * jnp.dot(q * scale, C_ref[...],
                                           preferred_element_type=jnp.float32)
    den = den + inter_w * jnp.dot(q * scale, n_ref[...][:, 0],
                                  preferred_element_type=jnp.float32)

    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[:, None]
    o_ref[0] = h.astype(o_ref.dtype)

    # ---- state update to chunk end -----------------------------------
    Ftot = b[K - 1]
    m_next = jnp.maximum(m_prev + Ftot, Ftot + jnp.max(g))
    w_prev = jnp.exp(m_prev + Ftot - m_next)
    w_src = jnp.exp(Ftot + g - m_next)                   # (K,)
    C_ref[...] = w_prev * C_ref[...] + jnp.dot(
        (k * w_src[:, None]).T, v, preferred_element_type=jnp.float32)
    n_ref[...] = w_prev * n_ref[...] + jnp.sum(
        k * w_src[:, None], axis=0)[:, None]
    m_ref[0, 0] = m_next


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunkwise(q: jax.Array, k: jax.Array, v: jax.Array,
                    i_raw: jax.Array, f_raw: jax.Array, *,
                    chunk: int = DEFAULT_CHUNK,
                    interpret: bool = False) -> jax.Array:
    """q/k/v: (B, H, S, dh); i_raw/f_raw: (B, H, S) raw gate pre-activations.
    Returns the hidden sequence (B, H, S, dh).  S must tile by ``chunk``."""
    B, H, S, dh = q.shape
    K = min(chunk, S)
    assert S % K == 0, (S, K)
    nc = S // K
    li = i_raw.astype(jnp.float32)                        # log input gate
    lf = -jax.nn.softplus(-f_raw.astype(jnp.float32))     # log sigmoid forget

    def flat(x):
        return x.reshape(B * H, S, *x.shape[3:])

    qr, kr, vr = flat(q), flat(k), flat(v)
    lir = li.reshape(B * H, S, 1)
    lfr = lf.reshape(B * H, S, 1)

    kernel = functools.partial(_mlstm_kernel, K, dh)
    seq_spec = pl.BlockSpec((1, K, dh), lambda b, c: (b, c, 0))
    gate_spec = pl.BlockSpec((1, K, 1), lambda b, c: (b, c, 0))
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[seq_spec, seq_spec, seq_spec, gate_spec, gate_spec],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),            # C state
            pltpu.VMEM((dh, 1), jnp.float32),             # n state
            pltpu.VMEM((1, 1), jnp.float32),              # m stabilizer
        ],
        interpret=interpret,
    )(qr, kr, vr, lir, lfr)
    return out.reshape(B, H, S, dh)
