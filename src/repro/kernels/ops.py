"""Public jit'd wrappers around the Pallas kernels.

These handle ragged shapes (padding to block multiples), select
interpret mode automatically off-TPU, and expose the kernels under the
names the model stack / benchmarks use.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import cim_bitwise as _cb
from repro.kernels import flash_attention as _fa
from repro.kernels import mlstm_chunk as _mc


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


# -------------------------------------------------------------- bitwise
def cim_bulk(x, y, op: str = "and", interpret: bool | None = None):
    """Bulk CiM op over same-shape int arrays of any rank (>=1)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
    y2 = y.reshape(x2.shape)
    x2, pr = _pad_to(x2, 8, 0)
    x2, pc = _pad_to(x2, 128, 1)
    y2, _ = _pad_to(y2, 8, 0)
    y2, _ = _pad_to(y2, 128, 1)
    br = min(_cb.BLOCK_R, x2.shape[0])
    bc = min(_cb.BLOCK_C, x2.shape[1])
    while x2.shape[0] % br:
        br //= 2
    while x2.shape[1] % bc:
        bc //= 2
    out = _cb.cim_bitwise(x2, y2, op=op, block_r=max(br, 1),
                          block_c=max(bc, 1), interpret=interpret)
    out = out[: out.shape[0] - pr or None, : out.shape[1] - pc or None]
    return out.reshape(shape)


def cim_fused(x, y, z, op1: str = "add", op2: str = "xor",
              interpret: bool | None = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    shape = x.shape
    def prep(a):
        a2 = a.reshape(-1, shape[-1]) if a.ndim > 1 else a.reshape(1, -1)
        a2, pr = _pad_to(a2, 8, 0)
        a2, pc = _pad_to(a2, 128, 1)
        return a2, pr, pc
    x2, pr, pc = prep(x)
    y2, _, _ = prep(y)
    z2, _, _ = prep(z)
    br = min(_cb.BLOCK_R, x2.shape[0])
    bc = min(_cb.BLOCK_C, x2.shape[1])
    while x2.shape[0] % br:
        br //= 2
    while x2.shape[1] % bc:
        bc //= 2
    out = _cb.cim_bitwise_fused(x2, y2, z2, op1=op1, op2=op2,
                                block_r=max(br, 1), block_c=max(bc, 1),
                                interpret=interpret)
    out = out[: out.shape[0] - pr or None, : out.shape[1] - pc or None]
    return out.reshape(shape)


# ------------------------------------------------------------ attention
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = _fa.DEFAULT_BLOCK_Q,
                    block_k: int = _fa.DEFAULT_BLOCK_K,
                    interpret: bool | None = None):
    """q: (B,H,Sq,d); k/v: (B,Hkv,Skv,d).  Pads Sq/Skv to block multiples;
    padded KV positions are masked out by padding K with +inf-free zeros and
    relying on causal/window masks plus explicit kv-length masking."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, H, Sq, d = q.shape
    Skv = k.shape[2]
    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Skv))
    qp, pq = _pad_to(q, bq, 2)
    kp, pk = _pad_to(k, bk, 2)
    vp, _ = _pad_to(v, bk, 2)
    if pk:
        # mask padded keys by pushing them outside every window/causal reach
        pass  # with causal masks q_pos < Sq never reaches k_pos >= Skv only
             # if Skv >= Sq; handle the general case by biasing K to zeros
    out = _fa.flash_attention(qp, kp, vp, causal=causal, window=window,
                              block_q=bq, block_k=bk, interpret=interpret)
    if pk and not causal:
        raise ValueError("non-causal ragged Skv unsupported; pad upstream")
    return out[:, :, :Sq]


# ---------------------------------------------------------------- mLSTM
def mlstm_chunkwise(q, k, v, i_raw, f_raw, *, chunk: int = _mc.DEFAULT_CHUNK,
                    interpret: bool | None = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, H, S, dh = q.shape
    K = min(chunk, S)
    while S % K:
        K //= 2
    return _mc.mlstm_chunkwise(q, k, v, i_raw, f_raw, chunk=max(K, 1),
                               interpret=interpret)
