"""Pure-jnp oracles for every Pallas kernel (the per-kernel ground truth).

Each ``*_ref`` computes the same function as its kernel with plain jnp —
no blocking, no online softmax — so allclose against these validates both
the tiling and the numerics.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

_OPS = {
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "xor": lambda a, b: a ^ b,
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
}


def cim_bitwise_ref(x, y, *, op: str = "and"):
    return _OPS[op](x, y)


def cim_bitwise_fused_ref(x, y, z, *, op1: str = "add", op2: str = "xor"):
    return _OPS[op2](_OPS[op1](x, y), z)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,H,Sq,d); k/v: (B,Hkv,Skv,d). Dense softmax reference."""
    B, H, Sq, d = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = H // Hkv
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) / math.sqrt(d)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = mask & (q_pos >= k_pos)
    if window > 0:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return out.astype(q.dtype)


def mlstm_chunkwise_ref(q, k, v, i_raw, f_raw):
    """Sequential stabilized mLSTM recurrence (token-by-token oracle).

    q/k/v: (B, H, S, dh); gates: (B, H, S).  Matches the kernel's chunkwise
    math in exact arithmetic (the chunked form is algebraically identical).
    """
    B, H, S, dh = q.shape
    scale = 1.0 / math.sqrt(dh)
    li = i_raw.astype(jnp.float32)
    lf = -jax.nn.softplus(-f_raw.astype(jnp.float32))

    def step(state, xs):
        C, n, m = state
        qt, kt, vt, lit, lft = xs                         # (B,H,dh) / (B,H)
        m_new = jnp.maximum(lft + m, lit)
        fw = jnp.exp(lft + m - m_new)
        iw = jnp.exp(lit - m_new)
        C = fw[..., None, None] * C + iw[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = fw[..., None] * n + iw[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt * scale, C)
        den = jnp.einsum("bhd,bhd->bh", qt * scale, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        return (C, n, m_new), h

    qf = jnp.moveaxis(q.astype(jnp.float32), 2, 0)
    kf = jnp.moveaxis(k.astype(jnp.float32), 2, 0)
    vf = jnp.moveaxis(v.astype(jnp.float32), 2, 0)
    lif = jnp.moveaxis(li, 2, 0)
    lff = jnp.moveaxis(lf, 2, 0)
    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    _, hs = jax.lax.scan(step, (C0, n0, m0), (qf, kf, vf, lif, lff))
    return jnp.moveaxis(hs, 0, 2).astype(q.dtype)         # (B,H,S,dh)
