"""Cell construction: one (arch x shape x mesh) dry-run/lowering unit.

A *cell* bundles the step function, abstract input shapes, and the
in/out shardings needed to ``jit(...).lower().compile()`` it — used by the
dry-run, the roofline harness, and the perf hillclimb.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES, cell_status
from repro.dist import sharding as shd
from repro.models import inputs as minputs
from repro.models.transformer import init_cache, init_params
from repro.train import steps as steps_mod


@dataclasses.dataclass
class Cell:
    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    rules: Dict[str, Any]
    fn: Callable
    in_specs: Tuple[Any, ...]          # abstract args (ShapeDtypeStruct trees)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any

    def lower(self):
        with self.mesh, shd.use_rules(self.mesh, self.rules):
            jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                             out_shardings=self.out_shardings)
            return jitted.lower(*self.in_specs)


def _replicated(mesh, tree):
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)


def state_shardings(cfg: ModelConfig, mesh: Mesh, state_shape, zero1: bool = True,
                    strategy: str = "auto"):
    pspecs = shd.param_specs(cfg, mesh, state_shape["params"], strategy=strategy)
    ospecs = (shd.opt_state_specs(cfg, mesh, state_shape["params"], pspecs,
                                  strategy=strategy)
              if zero1 else pspecs)
    out = {
        "params": shd.named(mesh, pspecs),
        "opt": {"m": shd.named(mesh, ospecs), "v": shd.named(mesh, ospecs)},
        "step": NamedSharding(mesh, P()),
    }
    if "error_fb" in state_shape:
        out["error_fb"] = shd.named(mesh, ospecs)
    return out


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               tc: Optional[TrainConfig] = None,
               strategy: str = "auto") -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    status = cell_status(cfg, shape)
    if status != "run":
        raise ValueError(f"cell {arch}x{shape_name} is {status}")
    tc = tc or TrainConfig()
    rules = shd.make_rules(cfg, mesh, shape, strategy=strategy)
    rng = jax.random.PRNGKey(0)

    if shape.kind == "train":
        state_shape = jax.eval_shape(lambda r: steps_mod.init_train_state(r, cfg), rng)
        st_sh = state_shardings(cfg, mesh, state_shape, zero1=tc.zero1,
                                strategy=strategy)
        batch_spec = minputs.train_input_specs(cfg, shape)
        batch_sh = shd.batch_input_shardings(mesh, batch_spec, rules)
        fn = steps_mod.make_train_step(cfg, tc)
        metrics_shape = {"loss": jax.ShapeDtypeStruct((), jnp.float32),
                         "aux_loss": jax.ShapeDtypeStruct((), jnp.float32),
                         "grad_norm": jax.ShapeDtypeStruct((), jnp.float32),
                         "lr": jax.ShapeDtypeStruct((), jnp.float32)}
        return Cell(cfg, shape, mesh, rules, fn,
                    in_specs=(state_shape, batch_spec),
                    in_shardings=(st_sh, batch_sh),
                    out_shardings=(st_sh, _replicated(mesh, metrics_shape)))

    params_shape = jax.eval_shape(lambda r: init_params(r, cfg), rng)
    pspecs = shd.param_specs(cfg, mesh, params_shape, strategy=strategy)
    p_sh = shd.named(mesh, pspecs)

    if shape.kind == "prefill":
        batch_spec = minputs.prefill_input_specs(cfg, shape)
        batch_sh = shd.batch_input_shardings(mesh, batch_spec, rules)
        fn = steps_mod.make_prefill_step(cfg, cache_len=shape.seq_len)
        cache_shape = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
        cache_sh = shd.named(mesh, shd.cache_specs(cfg, mesh, cache_shape, rules))
        tok_sh = NamedSharding(mesh, P(rules.get("batch")) if rules.get("batch") else P())
        return Cell(cfg, shape, mesh, rules, fn,
                    in_specs=(params_shape, batch_spec),
                    in_shardings=(p_sh, batch_sh),
                    out_shardings=(tok_sh, cache_sh))

    # decode
    dec = minputs.decode_input_specs(cfg, shape)
    cache_sh = shd.named(mesh, shd.cache_specs(cfg, mesh, dec["cache"], rules))
    b = rules.get("batch")
    tok_sh = NamedSharding(mesh, P(b) if b else P())
    fn = steps_mod.make_decode_step(cfg)
    return Cell(cfg, shape, mesh, rules, fn,
                in_specs=(params_shape, dec["token"], dec["cache"], dec["cur_pos"]),
                in_shardings=(p_sh, tok_sh, cache_sh, NamedSharding(mesh, P())),
                out_shardings=(tok_sh, cache_sh))
