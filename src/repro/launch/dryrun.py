import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede every other import (jax locks the device
count on first init). Produces one JSON artifact per cell under
``benchmarks/artifacts/dryrun/<mesh>/`` with memory_analysis,
cost_analysis, and the parsed collective-byte breakdown used by
EXPERIMENTS.md §Dry-run and §Roofline. Resumable: existing artifacts are
skipped unless ``--force``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single            # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --arch yi-34b --shape train_4k
"""

import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs.registry import ARCHS, get_config
from repro.configs.shapes import ALL_SHAPES, cell_status
from repro.core.hlo import collective_bytes, scan_trip_counts
from repro.core.hlo_cost import analyze_hlo
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh

ART = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

def run_cell(arch: str, shape_name: str, mesh_kind: str, force: bool = False,
             strategy: str = "auto", tc=None) -> dict:
    outdir = ART / mesh_kind
    outdir.mkdir(parents=True, exist_ok=True)
    suffix = "" if strategy == "auto" and tc is None else f"__{strategy}"
    path = outdir / f"{arch}__{shape_name}{suffix}.json"
    if path.exists() and not force:
        return json.loads(path.read_text())

    cfg = get_config(arch)
    shape = [s for s in ALL_SHAPES if s.name == shape_name][0]
    status = cell_status(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "status": status,
        "strategy": strategy,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    if status != "run":
        path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        cell = build_cell(arch, shape_name, mesh, tc=tc, strategy=strategy)
        lowered = cell.lower()
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ca = compiled.cost_analysis() or {}
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        scaled = analyze_hlo(hlo)      # trip-count-aware (cost_analysis
                                       # counts scan bodies once)
        rec.update(
            ok=True,
            lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
            flops_per_device=float(ca.get("flops", -1.0)),
            bytes_accessed_per_device=float(ca.get("bytes accessed", -1.0)),
            transcendentals=float(ca.get("transcendentals", -1.0)),
            memory={
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
            },
            collectives=collective_bytes(hlo),
            flops_scaled_per_device=scaled.flops,
            bytes_scaled_per_device=scaled.bytes,
            collectives_scaled={k: v for k, v in scaled.collectives.items()},
            collective_scaled_total=scaled.collective_total,
            while_trip_counts=scan_trip_counts(hlo)[:64],
            n_devices=mesh.devices.size,
        )
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: OK "
              f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s, "
              f"flops/dev {rec['flops_per_device']:.3e})", flush=True)
        print(f"  memory_analysis: {ma}", flush=True)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   tb=traceback.format_exc()[-4000:])
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: FAIL {type(e).__name__}: {e}",
              flush=True)
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="default: all 10")
    ap.add_argument("--shape", default=None, help="default: all shapes")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--strategy", default="auto", choices=["auto", "dp", "sp"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = n_skip = 0
    for mk in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, mk, force=args.force,
                               strategy=args.strategy)
                if rec["status"] != "run":
                    n_skip += 1
                elif rec.get("ok"):
                    n_ok += 1
                else:
                    n_fail += 1
    print(f"[dryrun] done: ok={n_ok} fail={n_fail} skip={n_skip}", flush=True)
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
