"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: ``axis_types`` appeared after
    0.4.x — request Auto axes where supported, plain mesh otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    mp = model_parallel if n % model_parallel == 0 else 1
    return make_mesh((n // mp, mp), ("data", "model"))
