"""Batched serving driver: prefill a batch of prompts, then decode.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
        --preset smoke --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config, reduced_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import inputs as minputs
from repro.models.transformer import init_params
from repro.train import steps as steps_mod


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=sorted(ARCHS))
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = (reduced_config(args.arch) if args.preset == "smoke"
           else get_config(args.arch))
    mesh = make_host_mesh(args.model_parallel)
    rules = shd.make_rules(cfg, mesh)
    max_len = args.prompt_len + args.gen

    rng = jax.random.PRNGKey(0)
    params = init_params(rng, cfg)
    batch = minputs.make_train_batch(rng, cfg, args.batch, args.prompt_len)
    batch.pop("labels")

    prefill = jax.jit(steps_mod.make_prefill_step(cfg, cache_len=max_len))
    decode = jax.jit(steps_mod.make_decode_step(cfg), donate_argnums=2)

    with mesh, shd.use_rules(mesh, rules):
        t0 = time.perf_counter()
        tok, cache = prefill(params, batch)
        tok.block_until_ready()
        t_prefill = time.perf_counter() - t0
        outs = [tok]
        t0 = time.perf_counter()
        for i in range(args.gen - 1):
            tok, cache = decode(params, tok, cache,
                                jnp.asarray(args.prompt_len + i, jnp.int32))
            outs.append(tok)
        tok.block_until_ready()
        t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(outs, axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] arch={args.arch} batch={args.batch} "
          f"prefill={t_prefill*1e3:.1f}ms "
          f"decode={t_decode*1e3:.1f}ms ({tps:.1f} tok/s)", flush=True)
    print(f"[serve] sample tokens: {np.asarray(gen[0][:16])}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
