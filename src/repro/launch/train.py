"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --preset smoke --steps 100 --ckpt-dir /tmp/ckpt

``--preset smoke`` trains the family-preserving reduced config (CPU-sized);
``--preset full`` uses the assigned architecture verbatim (TPU-sized).  The
loop runs under the fault-tolerance manager: auto-resume, async atomic
checkpoints, straggler monitoring; ``--fail-at N`` injects a failure at
step N to demonstrate recovery.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCHS, get_config, reduced_config
from repro.data.pipeline import DataConfig, ShardedTokenPipeline
from repro.dist import sharding as shd
from repro.ft.manager import FaultTolerantRunner, elastic_remesh
from repro.launch.mesh import make_host_mesh
from repro.models import inputs as minputs
from repro.train import steps as steps_mod


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "block", "full"])
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16", "int8_ef"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (reduced_config(args.arch) if args.preset == "smoke"
           else get_config(args.arch))
    tc = TrainConfig(total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
                     microbatches=args.microbatches, remat=args.remat,
                     grad_compression=args.grad_compression)
    mesh = make_host_mesh(args.model_parallel)
    print(f"[train] arch={args.arch} preset={args.preset} "
          f"params={cfg.param_count()/1e6:.1f}M mesh={dict(mesh.shape)}",
          flush=True)

    data = ShardedTokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch))

    rng = jax.random.PRNGKey(0)
    state = steps_mod.init_train_state(rng, cfg)
    rules = shd.make_rules(cfg, mesh)
    step_fn = steps_mod.make_train_step(cfg, tc)

    def run_step(state, batch):
        with mesh, shd.use_rules(mesh, rules):
            return jax.jit(step_fn, donate_argnums=0)(state, batch)

    def batch_at(step: int):
        b = data.batch_at(step)
        out = {"tokens": jnp.asarray(b["tokens"]),
               "labels": jnp.asarray(b["labels"])}
        if cfg.family == "encdec":
            Se = max(1, args.seq_len // cfg.enc_len_ratio)
            out["enc_embeds"] = jnp.zeros((args.batch, Se, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm" and cfg.n_prefix_embeds_ratio:
            St = args.seq_len - args.seq_len // cfg.n_prefix_embeds_ratio
            out["tokens"] = out["tokens"][:, :St]
            out["prefix_embeds"] = jnp.zeros(
                (args.batch, args.seq_len - St, cfg.d_model), jnp.bfloat16)
        return out

    runner = FaultTolerantRunner(args.ckpt_dir, save_every=args.save_every)
    t0 = time.perf_counter()
    state, report = runner.run(state, args.steps, run_step, batch_at,
                               log_every=args.log_every, fail_at=args.fail_at)
    dt = time.perf_counter() - t0
    print(f"[train] done in {dt:.1f}s: steps={report.steps_run} "
          f"resumed_from={report.resumed_from} "
          f"recoveries={report.failures_recovered} "
          f"final={report.final_metrics} straggler={report.straggler}",
          flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
