"""Static invariant checks for the Eva-CiM repro codebase.

``python -m repro.lint`` runs four ast-based checkers — see
``docs/architecture.md`` ("Static invariants") for the full contract:

* **version-integrity** — normalized AST fingerprints of the code
  behind each cache version constant, against a committed manifest;
* **jit-purity** — no Python side effects inside jitted/scanned bodies;
* **accel-parity** — every public ``core/accel`` kernel declares a
  numpy twin with a matching signature and a differential test;
* **thread-safety** — ``# lint: guarded-by(<lock>)`` attributes are
  only written under their lock, and locks nest in one global order.

Stdlib-only by design: the CI lint job runs before dependencies are
installed.
"""
from repro.lint.core import (  # noqa: F401
    CHECKERS,
    Finding,
    LintReport,
    REPO_ROOT,
    load_baseline,
    run_checkers,
)
