"""Command line for ``python -m repro.lint``.

Exit status is the contract: 0 when the tree is clean (no findings
outside the committed baseline), 1 otherwise.  Modes:

* default / ``--check-manifest`` — run every checker; the explicit flag
  additionally prints the per-layer version/fingerprint table so CI
  logs show *which* layer drifted;
* ``--update-manifest`` — re-record all layer fingerprints after an
  intentional version bump (the documented one-liner);
* ``--only <checker>`` — run a subset (repeatable);
* ``--verbose`` — also list baselined findings with their
  justifications.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time
from typing import Optional, Sequence

from repro.lint.core import REPO_ROOT, load_baseline, run_checkers
from repro.lint import fingerprint


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="repo invariant checker (version-integrity, "
                    "jit-purity, accel-parity, thread-safety)")
    ap.add_argument("--root", type=pathlib.Path, default=REPO_ROOT,
                    help="repository root (default: auto-detected)")
    ap.add_argument("--only", action="append", metavar="CHECKER",
                    help="run only this checker (repeatable)")
    ap.add_argument("--baseline", type=pathlib.Path, default=None,
                    help="alternate baseline file (default: committed "
                         "src/repro/lint/baseline.json)")
    ap.add_argument("--check-manifest", action="store_true",
                    help="run all checkers and print the per-layer "
                         "version/fingerprint table")
    ap.add_argument("--update-manifest", action="store_true",
                    help="re-record layer fingerprints in manifest.json "
                         "(run after an intentional version bump)")
    ap.add_argument("--verbose", action="store_true",
                    help="also list baselined findings")
    args = ap.parse_args(argv)

    if args.update_manifest:
        layers = fingerprint.save_manifest(args.root)
        for name, rec in layers.items():
            print(f"recorded {name}: {rec['version_const']}="
                  f"{rec['version']} fp={rec['fingerprint'][:12]}")
        print(f"wrote {fingerprint.MANIFEST_PATH}")
        return 0

    t0 = time.perf_counter()
    baseline = load_baseline(args.baseline)
    report = run_checkers(root=args.root,
                          only=tuple(args.only) if args.only else None,
                          baseline=baseline)
    dt = time.perf_counter() - t0

    if args.check_manifest:
        manifest = fingerprint.load_manifest()
        for layer in fingerprint.LAYERS:
            rec = manifest.get(layer.name, {})
            cur = fingerprint.layer_fingerprint(layer, args.root)
            ok = (cur == rec.get("fingerprint")
                  and fingerprint.read_version(layer, args.root)
                  == rec.get("version"))
            print(f"  {layer.name:<14} {layer.version_const}="
                  f"{rec.get('version')} fp={cur[:12]} "
                  f"{'ok' if ok else 'DRIFT'}")

    if args.verbose and report.suppressed:
        print(f"{len(report.suppressed)} baselined finding(s):")
        for f, why in report.suppressed:
            print(f"  {f.render()}")
            print(f"    baseline: {why}")

    for f in report.findings:
        print(f.render(), file=sys.stderr)

    n_err = sum(1 for f in report.findings if f.severity == "error")
    n_warn = len(report.findings) - n_err
    status = "clean" if report.ok else "FAILED"
    print(f"repro.lint: {status} — {len(report.checkers)} checkers, "
          f"{n_err} error(s), {n_warn} warning(s), "
          f"{len(report.suppressed)} baselined, {dt:.2f}s")
    if not report.ok:
        print("fix the findings above, or baseline a false positive in "
              "src/repro/lint/baseline.json with a justification",
              file=sys.stderr)
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
