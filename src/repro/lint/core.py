"""Framework plumbing for :mod:`repro.lint` — findings, comments, baseline.

Everything here is deliberately stdlib-only (``ast`` + ``tokenize``):
the lint job must run before the dependency install step of CI, cold,
in well under five seconds.

Three concepts:

* :class:`Finding` — one invariant violation, anchored by a *stable key*
  (checker + file + symbol) rather than a line number, so a committed
  suppression survives unrelated edits to the same file.
* **Annotations** — structured comments the checkers read through
  :func:`file_comments` (``tokenize``-based, so ``#`` inside string
  literals never confuses them): ``# lint: guarded-by(<lock>)`` declares
  a lock-protected attribute, ``# lint: numpy-twin(<target>)`` declares
  an accelerated function's reference oracle, and
  ``# lint: disable=<checker>`` suppresses one line in place.
* **Baseline** — a committed JSON file of known findings, each with a
  mandatory one-line justification.  The runner exits non-zero only on
  findings *not* in the baseline, so adopting a new checker never blocks
  the tree while real cleanups land incrementally.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import pathlib
import re
import tokenize
from typing import Callable, Dict, List, Optional, Tuple

# src/repro/lint/core.py -> parents[3] == the repository root
REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]

_DISABLE_RE = re.compile(r"lint:\s*disable=([\w,-]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One invariant violation.

    ``symbol`` anchors the baseline key: the function, attribute, or
    layer the finding is about.  Line numbers are for humans only —
    they never participate in suppression matching.
    """

    checker: str
    path: str                   # repo-relative, posix separators
    line: int
    message: str
    symbol: str = ""
    severity: str = "error"     # "error" gates CI; "warning" is advisory

    @property
    def key(self) -> str:
        return f"{self.checker}:{self.path}:{self.symbol or self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.checker}] {self.message}"


# ----------------------------------------------------------------- files
def rel(path: pathlib.Path, root: pathlib.Path) -> str:
    return path.resolve().relative_to(root.resolve()).as_posix()


def parse_file(path: pathlib.Path) -> ast.Module:
    return ast.parse(path.read_text(), filename=str(path))


def file_comments(path: pathlib.Path) -> Dict[int, str]:
    """``{lineno: comment text}`` for every ``#`` comment in the file.

    Tokenize-based: a ``#`` inside a string literal is not a comment."""
    out: Dict[int, str] = {}
    try:
        toks = tokenize.generate_tokens(io.StringIO(path.read_text()).readline)
        for tok in toks:
            if tok.type == tokenize.COMMENT:
                out[tok.start[0]] = tok.string.lstrip("#").strip()
    except tokenize.TokenError:
        pass
    return out


def annotation(comments: Dict[int, str], lines: range,
               name: str) -> Optional[str]:
    """The argument of the first ``lint: <name>(<arg>)`` annotation found
    on any line of ``lines`` (e.g. the span of a ``def`` statement)."""
    pat = re.compile(r"lint:\s*" + re.escape(name) + r"\(([^)]*)\)")
    for ln in lines:
        c = comments.get(ln)
        if c is None:
            continue
        m = pat.search(c)
        if m is not None:
            return m.group(1).strip()
    return None


def is_disabled(comments: Dict[int, str], line: int, checker: str) -> bool:
    """True when ``line`` (or the line above it) carries
    ``# lint: disable=<checker>``."""
    for ln in (line, line - 1):
        c = comments.get(ln)
        if c is None:
            continue
        m = _DISABLE_RE.search(c)
        if m and checker in {x.strip() for x in m.group(1).split(",")}:
            return True
    return False


# -------------------------------------------------------------- baseline
BASELINE_PATH = pathlib.Path(__file__).resolve().parent / "baseline.json"


def load_baseline(path: Optional[pathlib.Path] = None) -> Dict[str, str]:
    """``{finding key: justification}`` from the committed baseline."""
    path = path or BASELINE_PATH
    if not path.exists():
        return {}
    doc = json.loads(path.read_text())
    out: Dict[str, str] = {}
    for entry in doc.get("suppressions", []):
        key, why = entry.get("key", ""), entry.get("justification", "")
        if not key or not why.strip():
            raise ValueError(
                f"baseline entry {entry!r} needs both a key and a "
                f"non-empty one-line justification")
        out[key] = why
    return out


def save_baseline(entries: Dict[str, str],
                  path: Optional[pathlib.Path] = None) -> None:
    path = path or BASELINE_PATH
    doc = {"format": 1,
           "suppressions": [{"key": k, "justification": v}
                            for k, v in sorted(entries.items())]}
    path.write_text(json.dumps(doc, indent=2) + "\n")


# -------------------------------------------------------------- registry
CHECKERS: Dict[str, Callable[[pathlib.Path], List[Finding]]] = {}


def register(name: str):
    """Register ``fn(repo_root) -> [Finding]`` under ``name``."""
    def deco(fn):
        CHECKERS[name] = fn
        return fn
    return deco


@dataclasses.dataclass
class LintReport:
    """Outcome of one lint run: new findings vs. baselined ones."""

    findings: List[Finding]                   # not in the baseline
    suppressed: List[Tuple[Finding, str]]     # (finding, justification)
    checkers: Tuple[str, ...]

    @property
    def ok(self) -> bool:
        return not any(f.severity == "error" for f in self.findings)


def run_checkers(root: Optional[pathlib.Path] = None,
                 only: Optional[Tuple[str, ...]] = None,
                 baseline: Optional[Dict[str, str]] = None) -> LintReport:
    """Run the registered checkers and split results against the baseline."""
    # import for side effect: checker modules self-register
    from repro.lint import fingerprint, jit_purity, parity, threads  # noqa: F401
    root = root or REPO_ROOT
    names = tuple(only) if only else tuple(sorted(CHECKERS))
    unknown = set(names) - set(CHECKERS)
    if unknown:
        raise ValueError(f"unknown checker(s) {sorted(unknown)}; "
                         f"known: {sorted(CHECKERS)}")
    if baseline is None:
        baseline = load_baseline()
    new: List[Finding] = []
    suppressed: List[Tuple[Finding, str]] = []
    for name in names:
        for f in CHECKERS[name](root):
            if f.key in baseline:
                suppressed.append((f, baseline[f.key]))
            else:
                new.append(f)
    new.sort(key=lambda f: (f.path, f.line, f.checker))
    return LintReport(findings=new, suppressed=suppressed, checkers=names)
