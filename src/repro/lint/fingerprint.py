"""version-integrity checker: AST fingerprints behind the version constants.

The store trusts four hand-bumped constants to invalidate cached
artifacts (`TRACE_VM_VERSION`, `ANALYSIS_VERSION`, `TPU_ANALYSIS_VERSION`,
`STORE_FORMAT`).  Nothing at runtime can tell that the code producing an
artifact changed while its version constant did not — the cache key still
matches and a stale artifact is served silently.  This checker closes
that hole statically:

* each versioned layer maps to a set of modules (or, for layers that
  share a file with unrelated code, a set of top-level symbols);
* the layer's source is normalized — docstrings dropped, local names
  canonicalized by first appearance, the version constant itself
  excluded — and hashed;
* a committed manifest (``manifest.json``) records the
  ``(version, fingerprint)`` pair per layer;
* a mismatch is an error telling you which constant to bump and to run
  ``python -m repro.lint --update-manifest``.

Normalization is deliberately *behavior-shaped*, not byte-shaped:
renaming a local variable, editing a comment, or rewording a docstring
does not change the fingerprint; changing control flow, arithmetic, an
attribute name, or a public signature does.  The checker cannot prove a
change is semantic — it forces a human decision where today there is
silence.
"""
from __future__ import annotations

import ast
import copy
import hashlib
import json
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple

from repro.lint.core import Finding, parse_file, register

MANIFEST_PATH = pathlib.Path(__file__).resolve().parent / "manifest.json"


class LayerSpec:
    """One versioned artifact layer: modules + the constant that gates it."""

    def __init__(self, name: str, version_const: str, version_module: str,
                 modules: Sequence[str],
                 symbols: Optional[Dict[str, Sequence[str]]] = None):
        self.name = name
        self.version_const = version_const
        self.version_module = version_module   # module holding the constant
        self.modules = tuple(modules)          # repo-relative paths
        # optional per-module symbol filter: only these top-level defs /
        # ClassName.method paths participate in the fingerprint (for
        # modules where the layer shares a file with unrelated code)
        self.symbols = {k: tuple(v) for k, v in (symbols or {}).items()}


LAYERS: Tuple[LayerSpec, ...] = (
    LayerSpec(
        name="trace-vm",
        version_const="TRACE_VM_VERSION",
        version_module="src/repro/core/trace.py",
        modules=("src/repro/core/trace.py",
                 "src/repro/core/columnar.py",
                 "src/repro/core/isa.py"),
    ),
    LayerSpec(
        name="analysis",
        version_const="ANALYSIS_VERSION",
        version_module="src/repro/core/offload.py",
        # the constant's own docstring declares it covers idg + offload +
        # reshape, so reshape.py is in the fingerprint too
        modules=("src/repro/core/offload.py",
                 "src/repro/core/idg.py",
                 "src/repro/core/reshape.py"),
    ),
    LayerSpec(
        name="tpu-analysis",
        version_const="TPU_ANALYSIS_VERSION",
        version_module="src/repro/dse/backends.py",
        modules=("src/repro/dse/backends.py",),
        # backends.py also holds CimBackend, which is covered by the
        # trace-vm/analysis layers it delegates to — only the TPU path
        # feeds TPU_ANALYSIS_VERSION-keyed artifacts
        symbols={"src/repro/dse/backends.py": (
            "TpuCandidate", "TpuWorkloadAnalysis", "TpuSelection",
            "TpuBackend", "arch_fingerprint")},
    ),
    LayerSpec(
        name="sampling",
        version_const="SAMPLING_VERSION",
        version_module="src/repro/core/sampling/spec.py",
        # everything that shapes a persisted sampled artifact or the
        # estimate computed from it: the spec/key schema, the skim and
        # windowed machines, plan construction, the sampled pipeline, and
        # the estimator
        modules=("src/repro/core/sampling/spec.py",
                 "src/repro/core/sampling/machines.py",
                 "src/repro/core/sampling/cluster.py",
                 "src/repro/core/sampling/pipeline.py",
                 "src/repro/core/sampling/estimate.py"),
    ),
    LayerSpec(
        name="store-format",
        version_const="STORE_FORMAT",
        version_module="src/repro/dse/store.py",
        modules=("src/repro/dse/store.py",),
        # only the on-disk envelope + key derivation; stats/usage paths
        # can change freely without invalidating stored artifacts
        symbols={"src/repro/dse/store.py": (
            "NPZ_FORMAT", "workload_fingerprint", "_cache_geometry",
            "_offload_spec",
            "AnalysisStore._key", "AnalysisStore._path",
            "AnalysisStore.layer1_key", "AnalysisStore.layer2_key",
            "AnalysisStore._read", "AnalysisStore._write",
            "AnalysisStore._flow_path",
            "AnalysisStore._write_npz", "AnalysisStore._read_npz")},
    ),
)


# ---------------------------------------------------------- normalization
class _Normalizer(ast.NodeTransformer):
    """Canonicalize an AST so only behavior-shaped edits change the dump.

    * docstrings (first Constant-str statement of module/class/def) drop;
    * every local name (``Name.id``, ``arg.arg``, except-handler and
      global/nonlocal names) is renamed to ``_nN`` by first appearance —
      so renames don't bump versions but data-flow changes do;
    * def/class names, attribute names, and keyword argument names are
      KEPT: they are API surface and cache-key material.
    """

    def __init__(self) -> None:
        self._names: Dict[str, str] = {}

    def _canon(self, name: str) -> str:
        if name not in self._names:
            self._names[name] = f"_n{len(self._names)}"
        return self._names[name]

    def _strip_docstring(self, node):
        body = node.body
        if (body and isinstance(body[0], ast.Expr)
                and isinstance(body[0].value, ast.Constant)
                and isinstance(body[0].value.value, str)):
            node.body = body[1:] or [ast.Pass()]
        return node

    def visit_Module(self, node):
        self.generic_visit(node)
        return self._strip_docstring(node)

    def visit_FunctionDef(self, node):
        self.generic_visit(node)
        return self._strip_docstring(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node):
        self.generic_visit(node)
        return self._strip_docstring(node)

    def visit_Name(self, node):
        node.id = self._canon(node.id)
        return node

    def visit_arg(self, node):
        self.generic_visit(node)
        node.arg = self._canon(node.arg)
        return node

    def visit_ExceptHandler(self, node):
        self.generic_visit(node)
        if node.name:
            node.name = self._canon(node.name)
        return node

    def visit_Global(self, node):
        node.names = [self._canon(n) for n in node.names]
        return node

    visit_Nonlocal = visit_Global


def _select_symbols(tree: ast.Module, wanted: Sequence[str]) -> ast.Module:
    """Reduce a module to the listed top-level symbols.

    ``"name"`` keeps a top-level def/class/assign target; ``"Cls.meth"``
    keeps just that method (wrapped in a stub class so nesting survives).
    """
    flat = {w for w in wanted if "." not in w}
    methods: Dict[str, set] = {}
    for w in wanted:
        if "." in w:
            cls, meth = w.split(".", 1)
            methods.setdefault(cls, set()).add(meth)
    body: List[ast.stmt] = []
    for stmt in tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if stmt.name in flat:
                body.append(stmt)
        elif isinstance(stmt, ast.ClassDef):
            if stmt.name in flat:
                body.append(stmt)
            elif stmt.name in methods:
                keep = [s for s in stmt.body
                        if isinstance(s, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))
                        and s.name in methods[stmt.name]]
                stub = ast.ClassDef(name=stmt.name, bases=[], keywords=[],
                                    body=keep or [ast.Pass()],
                                    decorator_list=[])
                body.append(stub)
        elif isinstance(stmt, (ast.Assign, ast.AnnAssign)):
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            names = {t.id for t in targets if isinstance(t, ast.Name)}
            if names & flat:
                body.append(stmt)
    out = ast.Module(body=body, type_ignores=[])
    return out


def _drop_assign(tree: ast.Module, name: str) -> ast.Module:
    """Remove the version constant's own assignment: bumping it must not
    move the code fingerprint."""
    tree.body = [
        s for s in tree.body
        if not (isinstance(s, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == name
                        for t in s.targets))
        and not (isinstance(s, ast.AnnAssign)
                 and isinstance(s.target, ast.Name)
                 and s.target.id == name)]
    return tree


def layer_fingerprint(layer: LayerSpec, root: pathlib.Path) -> str:
    """sha256 over the normalized dumps of the layer's modules."""
    h = hashlib.sha256()
    for mod in layer.modules:
        tree = parse_file(root / mod)
        wanted = layer.symbols.get(mod)
        if wanted:
            tree = _select_symbols(tree, wanted)
        tree = _drop_assign(tree, layer.version_const)
        tree = _Normalizer().visit(copy.deepcopy(tree))
        h.update(mod.encode())
        h.update(ast.dump(tree, include_attributes=False).encode())
    return h.hexdigest()


def read_version(layer: LayerSpec, root: pathlib.Path) -> Optional[int]:
    """The current value of the layer's version constant, statically."""
    tree = parse_file(root / layer.version_module)
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if (isinstance(t, ast.Name) and t.id == layer.version_const
                        and isinstance(stmt.value, ast.Constant)):
                    return stmt.value.value
    return None


# --------------------------------------------------------------- manifest
def compute_manifest(root: pathlib.Path) -> Dict[str, Dict[str, object]]:
    out: Dict[str, Dict[str, object]] = {}
    for layer in LAYERS:
        out[layer.name] = {
            "version_const": layer.version_const,
            "version": read_version(layer, root),
            "modules": list(layer.modules),
            "fingerprint": layer_fingerprint(layer, root),
        }
    return out


def load_manifest(path: Optional[pathlib.Path] = None) -> Dict[str, Dict]:
    path = path or MANIFEST_PATH
    if not path.exists():
        return {}
    return json.loads(path.read_text()).get("layers", {})


def save_manifest(root: pathlib.Path,
                  path: Optional[pathlib.Path] = None) -> Dict[str, Dict]:
    path = path or MANIFEST_PATH
    layers = compute_manifest(root)
    path.write_text(json.dumps({"format": 1, "layers": layers}, indent=2)
                    + "\n")
    return layers


@register("version-integrity")
def check_versions(root: pathlib.Path,
                   manifest_path: Optional[pathlib.Path] = None
                   ) -> List[Finding]:
    manifest = load_manifest(manifest_path)
    findings: List[Finding] = []
    if not manifest:
        return [Finding(
            checker="version-integrity", path="src/repro/lint/manifest.json",
            line=1, symbol="<manifest>",
            message="no committed manifest; run "
                    "`python -m repro.lint --update-manifest`")]
    for layer in LAYERS:
        rec = manifest.get(layer.name)
        const_at = f"{layer.version_module}"
        if rec is None:
            findings.append(Finding(
                checker="version-integrity", path=const_at, line=1,
                symbol=layer.name,
                message=f"layer '{layer.name}' missing from manifest; run "
                        f"`python -m repro.lint --update-manifest`"))
            continue
        cur_fp = layer_fingerprint(layer, root)
        cur_ver = read_version(layer, root)
        if cur_ver is None:
            findings.append(Finding(
                checker="version-integrity", path=const_at, line=1,
                symbol=layer.name,
                message=f"cannot find constant {layer.version_const} "
                        f"in {layer.version_module}"))
            continue
        if cur_fp == rec.get("fingerprint") and cur_ver == rec.get("version"):
            continue
        if cur_fp != rec.get("fingerprint") and cur_ver == rec.get("version"):
            findings.append(Finding(
                checker="version-integrity", path=const_at, line=1,
                symbol=layer.name,
                message=(
                    f"code behind {layer.version_const} changed but the "
                    f"constant is still {cur_ver} — cached artifacts would "
                    f"go stale silently. Bump {layer.version_const} in "
                    f"{layer.version_module} and run `python -m repro.lint "
                    f"--update-manifest` (or run --update-manifest alone "
                    f"for a provably non-semantic refactor)")))
        else:
            findings.append(Finding(
                checker="version-integrity", path=const_at, line=1,
                symbol=layer.name,
                message=(
                    f"{layer.version_const} is {cur_ver} but the manifest "
                    f"records {rec.get('version')}; run `python -m "
                    f"repro.lint --update-manifest` to re-record the layer")))
    return findings
