"""jit-purity checker: no Python side effects inside traced functions.

Anything passed through ``jax.jit`` / ``accel.register_jitted`` /
``lax.scan`` runs *once* at trace time; Python-level effects in the body
are baked into the compiled artifact or silently skipped on cache hits.
The classic bugs this catches:

* ``time.*`` / ``datetime.now`` / ``random.*`` / ``np.random.*`` — the
  value is frozen at trace time, every later call reuses it;
* ``os.environ`` / ``os.getenv`` — config reads that don't retrigger
  compilation when the env changes (read env *outside* the kernel and
  pass the result in, as ``place._use_pallas`` does);
* ``print`` / ``open`` — effects that happen once, not per call;
* ``.item()`` / ``np.asarray(...)`` on traced values — host syncs that
  either fail under jit or force a device round-trip;
* mutable default arguments — unhashable, so they break jit's
  signature-based compile cache.

Detection is name-based and conservative: we only inspect functions we
can *see* flowing into a jit entry point (decorator or call), resolving
through the wrapper idioms this codebase uses
(``register_jitted(jax.jit(jax.vmap(f, ...)))``, ``functools.partial``).
"""
from __future__ import annotations

import ast
import pathlib
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.lint.core import Finding, file_comments, is_disabled, parse_file, rel, register

# call/decorator heads that mark their first argument (or the decorated
# function) as traced
_JIT_WRAPPERS = {"jax.jit", "jit", "register_jitted",
                 "accel.register_jitted"}
_SCAN_HEADS = {"lax.scan", "jax.lax.scan"}
_PALLAS_HEADS = {"pl.pallas_call", "pallas_call", "pltpu.pallas_call"}
# transparent wrappers: unwrap to their first positional argument
_TRANSPARENT = {"jax.vmap", "vmap", "jax.pmap", "pmap",
                "functools.partial", "partial", "jax.checkpoint",
                "jax.remat"} | _JIT_WRAPPERS

_RANDOM_PREFIXES = ("random.", "np.random.", "numpy.random.", "onp.random.")
_TIME_CALLS = {"time.time", "time.perf_counter", "time.monotonic",
               "time.process_time", "time.time_ns", "time.sleep"}
_HOST_SYNC_CALLS = {"np.asarray", "np.array", "numpy.asarray",
                    "numpy.array", "onp.asarray", "onp.array", "float",
                    "int"}  # float()/int() on traced values also sync
_ENV_CALLS = {"os.getenv", "os.environ.get"}


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _first_pos_arg(call: ast.Call) -> Optional[ast.expr]:
    return call.args[0] if call.args else None


def _unwrap(expr: ast.expr) -> Optional[ast.expr]:
    """Chase ``register_jitted(jax.jit(jax.vmap(f, ...)))`` down to f."""
    seen = 0
    while isinstance(expr, ast.Call) and seen < 8:
        head = dotted(expr.func)
        if head in _TRANSPARENT:
            nxt = _first_pos_arg(expr)
            if nxt is None:
                return None
            expr, seen = nxt, seen + 1
        else:
            return expr
    return expr


class _DefIndex(ast.NodeVisitor):
    """name -> [def nodes] over the whole file (scope-insensitive; good
    enough for lint — a shadowed name just gets both candidates checked)."""

    def __init__(self) -> None:
        self.defs: Dict[str, List[ast.AST]] = {}

    def visit_FunctionDef(self, node):
        self.defs.setdefault(node.name, []).append(node)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef


def _jit_targets(tree: ast.Module,
                 index: Dict[str, List[ast.AST]]) -> Iterable[Tuple[ast.AST, str]]:
    """Yield (function node, how-it-got-jitted) pairs."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                head = dotted(dec)
                if head is None and isinstance(dec, ast.Call):
                    head = dotted(dec.func)
                    # functools.partial(jax.jit, ...) as a decorator
                    if head in {"functools.partial", "partial"}:
                        inner = _first_pos_arg(dec)
                        head = dotted(inner) if inner is not None else None
                if head in _JIT_WRAPPERS:
                    yield node, f"@{head}"
        elif isinstance(node, ast.Call):
            head = dotted(node.func)
            if head in _JIT_WRAPPERS | _SCAN_HEADS | _PALLAS_HEADS:
                arg = _first_pos_arg(node)
                if arg is None:
                    continue
                resolved = _unwrap(arg)
                if resolved is None:
                    continue
                if isinstance(resolved, ast.Lambda):
                    yield resolved, f"{head}(<lambda>)"
                elif isinstance(resolved, ast.Name):
                    for d in index.get(resolved.id, ()):
                        yield d, f"{head}({resolved.id})"


def _impurities(fn: ast.AST) -> Iterable[Tuple[int, str]]:
    """(line, message) for each side effect in a traced body."""
    # unhashable defaults break jit's compile cache
    if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
        args = fn.args
        for default in list(args.defaults) + [d for d in args.kw_defaults
                                              if d is not None]:
            if isinstance(default, (ast.List, ast.Dict, ast.Set)):
                yield (default.lineno,
                       "mutable default argument in a jitted function "
                       "(unhashable; breaks the compile cache)")
            elif (isinstance(default, ast.Call)
                  and dotted(default.func) in {"list", "dict", "set"}):
                yield (default.lineno,
                       "mutable default argument in a jitted function "
                       "(unhashable; breaks the compile cache)")
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Global):
                yield (node.lineno,
                       "`global` statement inside a jitted function")
            elif isinstance(node, ast.Subscript):
                if dotted(node.value) == "os.environ":
                    yield (node.lineno,
                           "os.environ read inside a jitted function "
                           "(frozen at trace time; read it outside and "
                           "pass the value in)")
            elif isinstance(node, ast.Call):
                head = dotted(node.func)
                if head is None:
                    if (isinstance(node.func, ast.Attribute)
                            and node.func.attr == "item"):
                        yield (node.lineno,
                               ".item() host sync inside a jitted function")
                    continue
                if head in _TIME_CALLS or head.startswith("datetime."):
                    yield (node.lineno,
                           f"{head}() inside a jitted function is frozen "
                           f"at trace time")
                elif head.startswith(_RANDOM_PREFIXES):
                    yield (node.lineno,
                           f"{head}() inside a jitted function is frozen "
                           f"at trace time (use jax.random with an "
                           f"explicit key)")
                elif head in _ENV_CALLS:
                    yield (node.lineno,
                           f"{head}() inside a jitted function (frozen at "
                           f"trace time; read env outside and pass the "
                           f"value in)")
                elif head in {"print", "open"}:
                    yield (node.lineno,
                           f"{head}() inside a jitted function runs at "
                           f"trace time only (use jax.debug.print for "
                           f"per-call output)")
                elif head in _HOST_SYNC_CALLS and head not in {"float",
                                                               "int"}:
                    yield (node.lineno,
                           f"{head}() on a traced value is a host sync "
                           f"inside a jitted function")
                elif head.endswith(".item"):
                    yield (node.lineno,
                           ".item() host sync inside a jitted function")


def check_file(path: pathlib.Path, root: pathlib.Path) -> List[Finding]:
    tree = parse_file(path)
    indexer = _DefIndex()
    indexer.visit(tree)
    comments = file_comments(path)
    rpath = rel(path, root)
    seen: Set[Tuple[str, int, str]] = set()
    out: List[Finding] = []
    done_fns: Set[int] = set()
    for fn, how in _jit_targets(tree, indexer.defs):
        if id(fn) in done_fns:
            continue
        done_fns.add(id(fn))
        name = getattr(fn, "name", "<lambda>")
        for line, msg in _impurities(fn):
            key = (rpath, line, msg)
            if key in seen or is_disabled(comments, line, "jit-purity"):
                continue
            seen.add(key)
            out.append(Finding(
                checker="jit-purity", path=rpath, line=line,
                symbol=f"{name}:{msg.split(' ', 1)[0]}",
                message=f"{msg} [{name} jitted via {how}]"))
    return out


@register("jit-purity")
def check_jit_purity(root: pathlib.Path) -> List[Finding]:
    findings: List[Finding] = []
    src = root / "src" / "repro"
    for path in sorted(src.rglob("*.py")):
        if "lint" in path.relative_to(src).parts:
            continue
        findings.extend(check_file(path, root))
    return findings
