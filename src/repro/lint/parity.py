"""accel-parity checker: every jax kernel keeps its numpy oracle.

PR 7's contract is that numpy stays the reference implementation for
every accelerated path: same answer, `EVA_CIM_ACCEL` only changes the
speed.  That contract has three mechanical parts this checker enforces
for every *public* top-level function in ``core/accel/`` (except
``__init__.py``, which is the backend-selection layer, not a kernel):

1. a ``# lint: numpy-twin(<target>[, batched])`` annotation on the def
   naming the oracle — ``repro.core.offload:_place`` style for in-repo
   twins, a plain dotted path (``jax.ops.segment_sum``) for external
   ones;
2. for in-repo twins: the target exists and the signatures match
   (parameter names, in order, ``self`` excluded).  The ``batched``
   flag opts out of the signature comparison for kernels that
   intentionally take a batch axis their scalar oracle lacks;
3. a differential test in ``tests/test_accel.py`` referencing the
   accel function by name.
"""
from __future__ import annotations

import ast
import pathlib
import re
from typing import List, Optional, Tuple

from repro.lint.core import Finding, annotation, file_comments, is_disabled, parse_file, rel, register

ACCEL_DIR = "src/repro/core/accel"
TEST_FILE = "tests/test_accel.py"


def _params(fn: ast.FunctionDef, drop_self: bool) -> List[str]:
    a = fn.args
    names = ([p.arg for p in a.posonlyargs] + [p.arg for p in a.args])
    if drop_self and names and names[0] in {"self", "cls"}:
        names = names[1:]
    if a.vararg:
        names.append("*" + a.vararg.arg)
    names += [p.arg for p in a.kwonlyargs]
    if a.kwarg:
        names.append("**" + a.kwarg.arg)
    return names


def _resolve_twin(target: str, root: pathlib.Path
                  ) -> Tuple[Optional[ast.FunctionDef], bool, str]:
    """(def node, is_method, problem) for an in-repo ``mod:qualname``."""
    mod, _, qual = target.partition(":")
    path = root / "src" / pathlib.Path(*mod.split("."))
    path = path.with_suffix(".py")
    if not path.exists():
        return None, False, f"twin module {mod} not found at {path.name}"
    tree = parse_file(path)
    parts = qual.split(".")
    body = tree.body
    is_method = False
    node: Optional[ast.AST] = None
    for i, part in enumerate(parts):
        node = next((s for s in body
                     if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                       ast.ClassDef))
                     and s.name == part), None)
        if node is None:
            return None, False, f"twin symbol {qual} not found in {mod}"
        if isinstance(node, ast.ClassDef):
            body = node.body
            is_method = i + 1 < len(parts)
    if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return None, False, f"twin {target} is not a function"
    return node, is_method, ""


@register("accel-parity")
def check_parity(root: pathlib.Path) -> List[Finding]:
    findings: List[Finding] = []
    accel = root / ACCEL_DIR
    test_path = root / TEST_FILE
    test_src = test_path.read_text() if test_path.exists() else ""
    for path in sorted(accel.glob("*.py")):
        if path.name == "__init__.py":
            continue
        tree = parse_file(path)
        comments = file_comments(path)
        rpath = rel(path, root)
        for node in tree.body:
            if not isinstance(node, ast.FunctionDef):
                continue
            if node.name.startswith("_"):
                continue
            if is_disabled(comments, node.lineno, "accel-parity"):
                continue
            # annotation may sit on the line above the def, on the def
            # line, or on any signature line before the body starts
            span = range(node.lineno - 1, node.body[0].lineno)
            ann = annotation(comments, span, "numpy-twin")
            if ann is None:
                findings.append(Finding(
                    checker="accel-parity", path=rpath, line=node.lineno,
                    symbol=node.name,
                    message=(f"public accel function {node.name} has no "
                             f"`# lint: numpy-twin(<target>)` annotation "
                             f"naming its numpy oracle")))
                continue
            parts = [p.strip() for p in ann.split(",")]
            target, batched = parts[0], "batched" in parts[1:]
            if target.startswith("repro."):
                twin, is_method, problem = _resolve_twin(target, root)
                if twin is None:
                    findings.append(Finding(
                        checker="accel-parity", path=rpath,
                        line=node.lineno, symbol=node.name,
                        message=f"{node.name}: {problem}"))
                elif not batched:
                    ours = _params(node, drop_self=False)
                    theirs = _params(twin, drop_self=is_method)
                    if ours != theirs:
                        findings.append(Finding(
                            checker="accel-parity", path=rpath,
                            line=node.lineno, symbol=node.name,
                            message=(f"{node.name}{tuple(ours)} does not "
                                     f"match numpy twin {target}"
                                     f"{tuple(theirs)} (add `, batched` to "
                                     f"the annotation if the shape "
                                     f"difference is intentional)")))
            # external twins (jax.ops.*, numpy.*) are taken on trust —
            # the differential test below is what actually verifies them
            if not re.search(rf"\b{re.escape(node.name)}\b", test_src):
                findings.append(Finding(
                    checker="accel-parity", path=rpath, line=node.lineno,
                    symbol=f"{node.name}:test",
                    message=(f"{node.name} has no differential test "
                             f"referencing it in {TEST_FILE}")))
    return findings
