"""thread-safety checker: guarded writes and lock ordering in the daemon.

The DSE daemon (PR 6) shares engine/store/metrics state across handler
threads.  The locking discipline is conventional — every shared
attribute has one designated lock — but nothing enforced it until now.

Declaration is explicit, on the owning assignment (usually in
``__init__``)::

    self._memo = {}          # lint: guarded-by(_memo_lock)

With that in place the checker flags, per class:

* any write to a guarded attribute — rebinding, ``+=``, subscript
  stores, ``del``, or a mutating method call (``append``, ``update``,
  ``pop``, ...) — outside a ``with self.<lock>:`` block;
* ``setattr(self, ...)`` outside every declared lock (dynamic writes
  can hit any guarded attribute);
* inconsistent lock-acquisition order: if one code path takes lock A
  then B and another takes B then A, both sites are reported (the
  classic ABBA deadlock).

``__init__`` is exempt (no concurrent access before construction
returns).  Writes inside *nested* functions are checked with an empty
held-lock set: a closure handed to an executor runs after the ``with``
block exited, so the enclosing lock proves nothing.
"""
from __future__ import annotations

import ast
import pathlib
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.lint.core import Finding, file_comments, is_disabled, parse_file, rel, register

THREADED = ("src/repro/dse/service", "src/repro/dse/engine.py",
            "src/repro/dse/store.py", "src/repro/ckpt/checkpoint.py",
            "src/repro/obs")

_MUTATORS = {"append", "extend", "insert", "remove", "pop", "popitem",
             "clear", "update", "setdefault", "add", "discard",
             "appendleft", "popleft"}

_GUARD_RE = re.compile(r"lint:\s*guarded-by\((\w+)\)")


def _self_attr(node: ast.AST) -> Optional[str]:
    """``X`` when node is ``self.X``."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _written_attrs(target: ast.AST) -> List[Tuple[str, int]]:
    """Guardable (attr, line) pairs written by an assignment target."""
    out: List[Tuple[str, int]] = []
    if isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            out.extend(_written_attrs(elt))
        return out
    if isinstance(target, ast.Starred):
        return _written_attrs(target.value)
    attr = _self_attr(target)
    if attr is not None:
        out.append((attr, target.lineno))
    elif isinstance(target, ast.Subscript):
        attr = _self_attr(target.value)
        if attr is not None:
            out.append((attr, target.lineno))
    return out


def _collect_guards(cls: ast.ClassDef,
                    comments: Dict[int, str]) -> Dict[str, str]:
    """attr -> lock from ``# lint: guarded-by(<lock>)`` on assignments."""
    guards: Dict[str, str] = {}
    for node in ast.walk(cls):
        if not isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            continue
        targets = (node.targets if isinstance(node, ast.Assign)
                   else [node.target])
        end = getattr(node, "end_lineno", node.lineno) or node.lineno
        lock = None
        for ln in range(node.lineno, end + 1):
            c = comments.get(ln)
            if c:
                m = _GUARD_RE.search(c)
                if m:
                    lock = m.group(1)
                    break
        if lock is None:
            continue
        for t in targets:
            attr = _self_attr(t)
            if attr is not None:
                guards[attr] = lock
    return guards


class _ClassChecker:
    def __init__(self, cls: ast.ClassDef, guards: Dict[str, str],
                 comments: Dict[int, str], rpath: str):
        self.cls = cls
        self.guards = guards
        self.comments = comments
        self.rpath = rpath
        self.findings: List[Finding] = []
        # lock-order edges: (held, acquired) -> first line observed
        self.edges: Dict[Tuple[str, str], int] = {}

    def run(self) -> None:
        for node in self.cls.body:
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if node.name == "__init__":
                continue
            self._visit_block(node.body, held=frozenset(), method=node.name)

    # ------------------------------------------------------------ core
    def _flag(self, attr: str, line: int, method: str, kind: str) -> None:
        if is_disabled(self.comments, line, "thread-safety"):
            return
        lock = self.guards[attr]
        self.findings.append(Finding(
            checker="thread-safety", path=self.rpath, line=line,
            symbol=f"{self.cls.name}.{method}:{attr}",
            message=(f"{kind} of {self.cls.name}.{attr} (guarded-by "
                     f"{lock}) outside `with self.{lock}:` in "
                     f"{method}()")))

    def _with_locks(self, stmt: ast.With) -> Set[str]:
        locks: Set[str] = set()
        for item in stmt.items:
            attr = _self_attr(item.context_expr)
            if attr is not None:
                locks.add(attr)
        return locks

    def _visit_block(self, stmts: Sequence[ast.stmt],
                     held: frozenset, method: str) -> None:
        for stmt in stmts:
            self._visit_stmt(stmt, held, method)

    def _visit_stmt(self, stmt: ast.stmt, held: frozenset,
                    method: str) -> None:
        if isinstance(stmt, ast.With):
            acquired = self._with_locks(stmt)
            for a in held:
                for b in acquired:
                    if a != b:
                        self.edges.setdefault((a, b), stmt.lineno)
            self._check_exprs(stmt, held, method, skip_body=True)
            self._visit_block(stmt.body, held | acquired, method)
            return
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a closure may run after the lock was released (executor
            # submit, callback): check its body with nothing held
            self._visit_block(stmt.body, frozenset(), method=stmt.name)
            return
        if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            for t in targets:
                for attr, line in _written_attrs(t):
                    if attr in self.guards and self.guards[attr] not in held:
                        kind = ("augmented write"
                                if isinstance(stmt, ast.AugAssign)
                                else "write")
                        self._flag(attr, line, method, kind)
        if isinstance(stmt, ast.Delete):
            for t in stmt.targets:
                for attr, line in _written_attrs(t):
                    if attr in self.guards and self.guards[attr] not in held:
                        self._flag(attr, line, method, "delete")
        self._check_exprs(stmt, held, method)
        if isinstance(stmt, (ast.If, ast.For, ast.AsyncFor, ast.While,
                             ast.Try)):
            for field in ("body", "orelse", "finalbody"):
                inner = getattr(stmt, field, None)
                if isinstance(inner, list):
                    self._visit_block(inner, held, method)
            for handler in getattr(stmt, "handlers", []):
                self._visit_block(handler.body, held, method)

    def _check_exprs(self, stmt: ast.stmt, held: frozenset, method: str,
                     skip_body: bool = False) -> None:
        """Mutating calls on guarded attrs anywhere in the statement's
        own expressions (not its nested statement body)."""
        for node in ast.walk(stmt):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if isinstance(fn, ast.Attribute) and fn.attr in _MUTATORS:
                attr = _self_attr(fn.value)
                if (attr is not None and attr in self.guards
                        and self.guards[attr] not in held
                        and self._owns(stmt, node, skip_body)):
                    self._flag(attr, node.lineno, method,
                               f".{fn.attr}() mutation")
            elif (isinstance(fn, ast.Name) and fn.id == "setattr"
                  and node.args
                  and isinstance(node.args[0], ast.Name)
                  and node.args[0].id == "self" and self.guards
                  and not (held & set(self.guards.values()))
                  and self._owns(stmt, node, skip_body)):
                if not is_disabled(self.comments, node.lineno,
                                   "thread-safety"):
                    self.findings.append(Finding(
                        checker="thread-safety", path=self.rpath,
                        line=node.lineno,
                        symbol=f"{self.cls.name}.{method}:setattr",
                        message=(f"setattr(self, ...) in {method}() "
                                 f"outside every declared lock of "
                                 f"{self.cls.name} (a dynamic write can "
                                 f"hit any guarded attribute)")))

    def _owns(self, stmt: ast.stmt, node: ast.AST, skip_body: bool) -> bool:
        """True when ``node`` belongs to this statement's own expressions
        — i.e. not inside a nested statement list we visit separately."""
        if not skip_body and not isinstance(stmt, (ast.If, ast.For,
                                                   ast.AsyncFor, ast.While,
                                                   ast.Try, ast.With)):
            return True
        nested: List[ast.stmt] = []
        for field in ("body", "orelse", "finalbody"):
            v = getattr(stmt, field, None)
            if isinstance(v, list):
                nested.extend(v)
        for h in getattr(stmt, "handlers", []):
            nested.extend(h.body)
        for sub in nested:
            for n in ast.walk(sub):
                if n is node:
                    return False
        return True


def _order_findings(all_edges: Dict[str, Dict[Tuple[str, str], int]],
                    rpaths: Dict[str, str]) -> List[Finding]:
    out: List[Finding] = []
    for cls_name, edges in all_edges.items():
        for (a, b), line in sorted(edges.items()):
            if (b, a) in edges and a < b:
                other = edges[(b, a)]
                out.append(Finding(
                    checker="thread-safety", path=rpaths[cls_name],
                    line=line, symbol=f"{cls_name}:lock-order:{a}/{b}",
                    message=(f"inconsistent lock order in {cls_name}: "
                             f"{a} -> {b} at line {line} but "
                             f"{b} -> {a} at line {other} (ABBA "
                             f"deadlock)")))
    return out


def _threaded_files(root: pathlib.Path) -> List[pathlib.Path]:
    out: List[pathlib.Path] = []
    for entry in THREADED:
        p = root / entry
        if p.is_dir():
            out.extend(sorted(p.glob("*.py")))
        elif p.exists():
            out.append(p)
    return out


@register("thread-safety")
def check_threads(root: pathlib.Path) -> List[Finding]:
    findings: List[Finding] = []
    all_edges: Dict[str, Dict[Tuple[str, str], int]] = {}
    rpaths: Dict[str, str] = {}
    for path in _threaded_files(root):
        tree = parse_file(path)
        comments = file_comments(path)
        rpath = rel(path, root)
        for node in tree.body:
            if not isinstance(node, ast.ClassDef):
                continue
            guards = _collect_guards(node, comments)
            if not guards:
                continue
            checker = _ClassChecker(node, guards, comments, rpath)
            checker.run()
            findings.extend(checker.findings)
            all_edges[node.name] = checker.edges
            rpaths[node.name] = rpath
    findings.extend(_order_findings(all_edges, rpaths))
    return findings
