from repro.models.transformer import (forward_decode, forward_full, init_cache,
                                      init_params, lm_loss)
