"""GQA attention: chunked-flash train/prefill, cached decode, cross-attn.

The train/prefill path is a pure-jnp *chunked online-softmax* (flash)
implementation: it never materializes the (Sq, Skv) score matrix, so the
lowered HLO has the same HBM-traffic shape as the Pallas kernel in
``repro.kernels.flash_attention`` (which is the TPU deployment path).
This is the "compute where the KV lives" CiM analogue — see DESIGN.md §3.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import shard
from repro.models.layers import apply_rope, dense_init, pdtype_of

NEG_INF = -1e30


# ---------------------------------------------------------------- params
def make_attn_params(rng, cfg: ModelConfig, cross: bool = False):
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = pdtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(k1, (d, h, dh), dt, fan_in=d),
        "wk": dense_init(k2, (d, hk, dh), dt, fan_in=d),
        "wv": dense_init(k3, (d, hk, dh), dt, fan_in=d),
        "wo": dense_init(k4, (h, dh, d), dt, fan_in=h * dh),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h, dh), dt)
        p["bk"] = jnp.zeros((hk, dh), dt)
        p["bv"] = jnp.zeros((hk, dh), dt)
    return p


def qkv_proj(params, cfg: ModelConfig, x, positions=None, rope: bool = True):
    """x: (B, S, d) -> q (B,S,H,dh), k/v (B,S,Hkv,dh)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def out_proj(params, attn_out):
    out = jnp.einsum("bshk,hkd->bsd", attn_out, params["wo"])
    out = shard(out, "batch", "seq", "embed_out")   # identity unless decode
    return shard(out, "batch", "seq", "embed")


# ------------------------------------------------------- chunked flash
def _block_mask(q_pos, k_pos, causal, window, kv_len, skv_bound):
    """(Sq, blk) bool mask; window/kv_len may be traced float scalars."""
    mask = k_pos[None, :] < kv_len
    if causal:
        mask = mask & (q_pos[:, None] >= k_pos[None, :])
    w = jnp.where(window > 0, window, skv_bound)
    return mask & (q_pos[:, None] - k_pos[None, :] < w)


def _split_blocks(x, nblk, block):
    B = x.shape[0]
    return x.reshape(B, nblk, block, *x.shape[2:]).transpose(1, 0, 2, 3, 4)


def _flash_fwd_impl(causal, block, softcap, q, k, v, window, q_offset, kv_len):
    B, Sq, H, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(dh)
    nblk = -(-Skv // block)
    pad = nblk * block - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qh = q.reshape(B, Sq, Hkv, G, dh)
    q_pos = q_offset + jnp.arange(Sq, dtype=jnp.float32)
    skv_bound = float(Skv + Sq + 1)

    kb, vb = _split_blocks(k, nblk, block), _split_blocks(v, nblk, block)
    starts = (jnp.arange(nblk) * block).astype(jnp.float32)

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, dh), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, start = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, kblk,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = start + jnp.arange(block, dtype=jnp.float32)
        mask = _block_mask(q_pos, k_pos, causal, window, kv_len, skv_bound)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))               # (B,Hkv,G,Sq)
    out = out.reshape(B, Hkv * G, Sq, dh).transpose(0, 2, 1, 3).reshape(
        B, Sq, H, dh).astype(q.dtype)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _flash(causal, block, softcap, q, k, v, window, q_offset, kv_len):
    out, _ = _flash_fwd_impl(causal, block, softcap, q, k, v, window, q_offset, kv_len)
    return out


def _flash_fwd(causal, block, softcap, q, k, v, window, q_offset, kv_len):
    out, lse = _flash_fwd_impl(causal, block, softcap, q, k, v, window, q_offset, kv_len)
    return out, (q, k, v, out, lse, window, q_offset, kv_len)


def _flash_bwd(causal, block, softcap, res, dout):
    """FA2-style backward: re-compute p per block from the saved LSE."""
    q, k, v, out, lse, window, q_offset, kv_len = res
    B, Sq, H, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(dh)
    nblk = -(-Skv // block)
    pad = nblk * block - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qh = q.reshape(B, Sq, Hkv, G, dh)
    doh = dout.reshape(B, Sq, Hkv, G, dh)
    outh = out.reshape(B, Sq, Hkv, G, dh)
    # D_t = sum_d dout_t * out_t  (rowsum of p*dp)
    D = jnp.einsum("bqhgd,bqhgd->bhgq", doh.astype(jnp.float32),
                   outh.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(Sq, dtype=jnp.float32)
    skv_bound = float(Skv + Sq + 1)
    kb, vb = _split_blocks(k, nblk, block), _split_blocks(v, nblk, block)
    starts = (jnp.arange(nblk) * block).astype(jnp.float32)

    dq0 = jnp.zeros((B, Sq, Hkv, G, dh), jnp.float32)

    def body(dq, xs):
        kblk, vblk, start = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, kblk,
                       preferred_element_type=jnp.float32) * scale
        k_pos = start + jnp.arange(block, dtype=jnp.float32)
        mask = _block_mask(q_pos, k_pos, causal, window, kv_len, skv_bound)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                    # (B,Hkv,G,Sq,blk)
        dv_blk = jnp.einsum("bhgqk,bqhgd->bkhd", p, doh.astype(jnp.float32))
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", doh, vblk,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - D[..., None]) * scale
        dq = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kblk,
                             preferred_element_type=jnp.float32)
        dk_blk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qh.astype(jnp.float32))
        return dq, (dk_blk, dv_blk)

    dq, (dkb, dvb) = jax.lax.scan(body, dq0, (kb, vb, starts))
    dk = dkb.transpose(1, 0, 2, 3, 4).reshape(B, nblk * block, Hkv, dh)[:, :Skv]
    dv = dvb.transpose(1, 0, 2, 3, 4).reshape(B, nblk * block, Hkv, dh)[:, :Skv]
    dq = dq.reshape(B, Sq, H, dh)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            jnp.zeros_like(window), jnp.zeros_like(q_offset),
            jnp.zeros_like(kv_len))


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_jnp(q, k, v, *, causal: bool, window=0, q_offset=0,
                        kv_len=None, block: int = 1024, softcap: float = 0.0):
    """Online-softmax attention, scanned over KV blocks, flash-style VJP.

    q: (B, Sq, H, dh); k, v: (B, Skv, Hkv, dh). ``window``: if > 0 (may be a
    traced scalar), only keys with q_pos - k_pos < window attend (plus the
    causal constraint). ``kv_len``: number of valid kv positions (for padded
    caches). Returns (B, Sq, H, dh).
    """
    Skv = k.shape[1]
    block = min(block, Skv)
    window_f = jnp.asarray(window, jnp.float32)
    q_offset_f = jnp.asarray(q_offset, jnp.float32)
    kv_len_f = jnp.asarray(Skv if kv_len is None else kv_len, jnp.float32)
    return _flash(causal, block, float(softcap), q, k, v,
                  window_f, q_offset_f, kv_len_f)


def flash_attention_banded(q, k, v, *, window: int, block: int = 1024,
                           softcap: float = 0.0):
    """Sliding-window attention with KV *block-skipping* (§Perf iteration).

    The plain path computes the full (Sq, Skv) score matrix and masks it —
    O(S^2) compute even when only a width-``window`` band is live.  Here the
    q sequence is scanned in blocks and each block attends only to its own
    KV band (ceil(window/block)+1 blocks), so compute and HBM traffic scale
    as O(S * window).  Requires a *static* integer window (causal).
    """
    B, S, H, dh = q.shape
    blk = min(block, S, max(window, 128))
    while S % blk:
        blk //= 2
    nq = S // blk
    wblk = -(-window // blk)                        # band blocks before diag
    nband = min(wblk + 1, nq)
    if nband >= nq:                                 # band covers everything
        return flash_attention_jnp(q, k, v, causal=True, window=window,
                                   block=blk, softcap=softcap)

    def body(_, i):
        q_i = jax.lax.dynamic_slice_in_dim(q, i * blk, blk, axis=1)
        start = jnp.maximum(i - (nband - 1), 0) * blk
        k_b = jax.lax.dynamic_slice_in_dim(k, start, nband * blk, axis=1)
        v_b = jax.lax.dynamic_slice_in_dim(v, start, nband * blk, axis=1)
        # positions inside the band are relative; shifting q by the band
        # start preserves (q_pos - k_pos) for the causal + window masks
        o_i = flash_attention_jnp(q_i, k_b, v_b, causal=True, window=window,
                                  q_offset=i * blk - start, block=blk,
                                  softcap=softcap)
        return None, o_i

    _, o_blocks = jax.lax.scan(body, None, jnp.arange(nq))
    return o_blocks.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh)


def flash_dispatch(q, k, v, *, causal: bool, window=0, block: int = 1024,
                   softcap: float = 0.0, kv_len=None):
    """Route to the banded (block-skipping) path when the window is a
    static int — the §Perf sliding-window optimization — else the masked
    full path (traced per-layer windows, cross-attn, ragged kv)."""
    import numpy as _np
    if (isinstance(window, (int, _np.integer)) and int(window) > 0 and causal
            and kv_len is None and q.shape[1] == k.shape[1]):
        return flash_attention_banded(q, k, v, window=int(window),
                                      block=block, softcap=softcap)
    return flash_attention_jnp(q, k, v, causal=causal, window=window,
                               kv_len=kv_len, block=block, softcap=softcap)


# --------------------------------------------------------------- decode
def attend_cache(q, cache_k, cache_v, cur_pos, *, window=0, softcap: float = 0.0):
    """Single-token decode attention over a (padded) KV cache.

    q: (B, 1, H, dh); cache_k/v: (B, Smax, Hkv, dh); cur_pos: scalar index of
    the token being generated (cache holds positions [0, cur_pos]).
    """
    B, _, H, dh = q.shape
    Smax, Hkv = cache_k.shape[1], cache_k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(dh)
    qh = q.reshape(B, Hkv, G, dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qh, cache_k).astype(jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    k_pos = jnp.arange(Smax)
    mask = k_pos <= cur_pos
    w = jnp.asarray(window)
    w = jnp.where(w > 0, w, Smax + 1)
    mask = mask & (cur_pos - k_pos < w)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(cache_v.dtype), cache_v)
    return out.reshape(B, 1, H, dh).astype(q.dtype)


# ----------------------------------------------------------- full blocks
def self_attention(params, cfg: ModelConfig, x, positions, *, causal=True,
                   window=0, block=1024):
    q, k, v = qkv_proj(params, cfg, x, positions)
    o = flash_dispatch(q, k, v, causal=causal, window=window, block=block,
                       softcap=cfg.attn_logit_softcap)
    return out_proj(params, o)


def self_attention_prefill(params, cfg: ModelConfig, x, positions, *, window=0,
                           block=1024):
    """Returns (out, (k, v)) so the caller can seed the KV cache."""
    q, k, v = qkv_proj(params, cfg, x, positions)
    o = flash_dispatch(q, k, v, causal=True, window=window, block=block,
                       softcap=cfg.attn_logit_softcap)
    return out_proj(params, o), (k, v)


def self_attention_decode(params, cfg: ModelConfig, x, cache_k, cache_v,
                          cur_pos, *, window=0):
    """One-token step: writes (k, v) at cur_pos, attends over the cache."""
    positions = jnp.asarray(cur_pos)[None]
    q, k, v = qkv_proj(params, cfg, x, positions[None])
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), cur_pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), cur_pos, axis=1)
    o = attend_cache(q, cache_k, cache_v, cur_pos, window=window,
                     softcap=cfg.attn_logit_softcap)
    return out_proj(params, o), (cache_k, cache_v)


def cross_attention(params, cfg: ModelConfig, x, mem_k, mem_v, *, mem_len=None,
                    block=1024):
    """Decoder->encoder attention; memory K/V precomputed (B, Sm, Hkv, dh)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    o = flash_attention_jnp(q, mem_k, mem_v, causal=False, kv_len=mem_len, block=block)
    return out_proj(params, o)


def encode_memory(params, cfg: ModelConfig, mem):
    """Precompute cross-attention K/V from encoder output (no rope)."""
    k = jnp.einsum("bsd,dhk->bshk", mem, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", mem, params["wv"])
    return k, v
