"""Model input construction: concrete batches (smoke/examples) and
ShapeDtypeStruct stand-ins (dry-run lowering, no allocation).

Modality frontends are STUBS per the assignment: ``[audio]``/``[vlm]`` archs
receive precomputed frame/patch embeddings here.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import init_cache


def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text positions for VLM (rest of the sequence is the patch prefix)."""
    if cfg.family == "vlm" and cfg.n_prefix_embeds_ratio:
        return seq_len - seq_len // cfg.n_prefix_embeds_ratio
    return seq_len


def prefix_len(cfg: ModelConfig, seq_len: int) -> int:
    return seq_len - _text_len(cfg, seq_len)


def make_train_batch(rng, cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, Any]:
    k1, k2 = jax.random.split(rng)
    St = _text_len(cfg, seq_len)
    out = {
        "tokens": jax.random.randint(k1, (batch, St), 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(k2, (batch, seq_len), 0, cfg.vocab_size, jnp.int32),
    }
    if cfg.family == "encdec":
        Se = max(1, seq_len // cfg.enc_len_ratio)
        out["enc_embeds"] = jax.random.normal(k1, (batch, Se, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm" and St < seq_len:
        out["prefix_embeds"] = jax.random.normal(k1, (batch, seq_len - St, cfg.d_model), jnp.bfloat16)
    return out


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    St = _text_len(cfg, S)
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, St), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.family == "encdec":
        Se = max(1, S // cfg.enc_len_ratio)
        specs["enc_embeds"] = jax.ShapeDtypeStruct((B, Se, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm" and St < S:
        specs["prefix_embeds"] = jax.ShapeDtypeStruct((B, S - St, cfg.d_model), jnp.bfloat16)
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    specs = train_input_specs(cfg, shape)
    specs.pop("labels")
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """One-token step: token + cache-of-length-seq_len (ShapeDtypeStructs)."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    return {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "cache": cache,
        "cur_pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def make_decode_inputs(rng, cfg: ModelConfig, batch: int, max_len: int, cur_pos: int):
    token = jax.random.randint(rng, (batch, 1), 0, cfg.vocab_size, jnp.int32)
    cache = init_cache(cfg, batch, max_len)
    return {"token": token, "cache": cache, "cur_pos": jnp.asarray(cur_pos, jnp.int32)}
