"""Shared building blocks: norms, rotary embeddings, SwiGLU, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ----------------------------------------------------------------- init
def dense_init(rng, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


def embed_init(rng, shape, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------- norms
def rms_norm(x, weight, eps):
    # keep the (B,S,d) tensor in compute dtype; only the reduction runs fp32
    # (a full fp32 copy of x gets hoisted into the saved-residual stack by
    # XLA and doubles training activation memory — see DESIGN.md).
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + weight).astype(x.dtype)


def layer_norm(x, weight, bias, eps):
    mu = jnp.mean(x.astype(jnp.float32), axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x.astype(jnp.float32) - mu), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = (x - mu.astype(x.dtype)) * inv.astype(x.dtype)
    return y * weight.astype(x.dtype) + bias.astype(x.dtype)


def make_norm_params(cfg: ModelConfig, rng=None):
    d = cfg.d_model
    if cfg.norm == "ln":
        return {"w": jnp.ones((d,), pdtype_of(cfg)), "b": jnp.zeros((d,), pdtype_of(cfg))}
    return {"w": jnp.zeros((d,), pdtype_of(cfg))}


def apply_norm(cfg: ModelConfig, params, x):
    if cfg.norm == "ln":
        return layer_norm(x, params["w"], params["b"], cfg.norm_eps)
    return rms_norm(x, params["w"], cfg.norm_eps)


# ----------------------------------------------------------------- rotary
def rope_freqs(head_dim: int, theta: float):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)                       # (head_dim/2,)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                          # (dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., :, None, :]                 # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- FFN
def make_swiglu_params(rng, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def swiglu(params, x):
    from repro.dist.sharding import shard
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard(h, "batch", "seq", "dff")
    out = jnp.einsum("bsf,fd->bsd", h, params["w_down"])
    return shard(out, "batch", "seq", "embed")


# ----------------------------------------------------------------- misc
def stack_layer_params(init_fn, rng, n_layers: int):
    """vmap a per-layer init over split rngs -> params stacked on axis 0."""
    rngs = jax.random.split(rng, n_layers)
    return jax.vmap(init_fn)(rngs)


def softcap(logits, cap: float):
    if cap and cap > 0:
        return jnp.tanh(logits / cap) * cap
    return logits
