"""Mixture-of-Experts FFN with two dispatch implementations.

``einsum``  — GShard/Switch-style one-hot dispatch+combine tensors. This is
              the literature-baseline (and the paper-era) formulation; its
              dispatch einsums burn real MXU FLOPs, which the roofline's
              useful-FLOPs ratio exposes (see EXPERIMENTS.md §Perf).
``gather``  — argsort-based dispatch: tokens are sorted by expert id and
              scattered into (E, C, d) buffers; zero matmul overhead. Used
              as the beyond-paper optimization (and default for k=6/64e).

Experts are sharded over the ``model`` mesh axis (EP): expert weights are
(E, d, f) with E-major sharding; dispatched activations (G, E, C, d) carry
E on ``model`` so each expert's FFN runs where its weights live.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import shard
from repro.models.layers import dense_init, pdtype_of


def make_moe_params(rng, cfg: ModelConfig):
    d, e = cfg.d_model, cfg.moe
    dt = pdtype_of(cfg)
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    p = {
        "router": dense_init(k1, (d, e.n_experts), jnp.float32),
        "w_gate": dense_init(k2, (e.n_experts, d, e.expert_d_ff), dt, fan_in=d),
        "w_up": dense_init(k3, (e.n_experts, d, e.expert_d_ff), dt, fan_in=d),
        "w_down": dense_init(k4, (e.n_experts, e.expert_d_ff, d), dt, fan_in=e.expert_d_ff),
    }
    if e.n_shared_experts:
        f = e.n_shared_experts * e.expert_d_ff
        ks = jax.random.split(k5, 3)
        p["shared"] = {
            "w_gate": dense_init(ks[0], (d, f), dt),
            "w_up": dense_init(ks[1], (d, f), dt),
            "w_down": dense_init(ks[2], (f, d), dt),
        }
    return p


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    e = cfg.moe
    c = int(tokens_per_group * e.top_k * e.capacity_factor / e.n_experts) + 1
    return max(8, ((c + 7) // 8) * 8)  # align


def _router(params, cfg: ModelConfig, x):
    """x (G, S, d) -> gates (G, S, k), idx (G, S, k), aux_loss (scalar)."""
    e = cfg.moe
    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, e.top_k)          # (G,S,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing loss
    me = probs.mean(axis=(0, 1))                             # (E,)
    ce = jnp.zeros((e.n_experts,)).at[idx.reshape(-1)].add(1.0) / idx.size
    aux = e.n_experts * jnp.sum(me * ce)
    return gate_vals, idx, aux


def _expert_ffn(params, h):
    """h: (..., E, C, d) with E leading-contracted against (E, d, f)."""
    h = shard(h, "batch", "expert", "cap", "embed")
    g = jnp.einsum("gecd,edf->gecf", h, params["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", h, params["w_up"])
    a = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
    out = jnp.einsum("gecf,efd->gecd", a, params["w_down"])
    return shard(out, "batch", "expert", "cap", "embed")


# ------------------------------------------------------------- einsum impl
def _moe_einsum(params, cfg: ModelConfig, x):
    G, S, d = x.shape
    e = cfg.moe
    C = _capacity(cfg, S)
    gates, idx, aux = _router(params, cfg, x)
    combine = jnp.zeros((G, S, e.n_experts, C), jnp.float32)
    for ki in range(e.top_k):
        oh = jax.nn.one_hot(idx[..., ki], e.n_experts, dtype=jnp.float32)   # (G,S,E)
        pos = (jnp.cumsum(oh, axis=1) - 1.0) * oh                            # (G,S,E)
        keep = (pos < C) & (oh > 0)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32) * keep[..., None]
        combine = combine + gates[..., ki, None, None] * oh[..., None] * pos_oh
    dispatch = (combine > 0).astype(x.dtype)                                 # (G,S,E,C)
    h = jnp.einsum("gsec,gsd->gecd", dispatch, x)
    out = _expert_ffn(params, h)
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), out)
    return y, aux


# ------------------------------------------------------------- gather impl
def _moe_gather(params, cfg: ModelConfig, x):
    G, S, d = x.shape
    e = cfg.moe
    k = e.top_k
    C = _capacity(cfg, S)
    gates, idx, aux = _router(params, cfg, x)

    def per_group(xg, idxg, gateg):
        # xg (S,d); idxg/gateg (S,k)
        eid = idxg.reshape(-1)                       # (S*k,)
        tok = jnp.repeat(jnp.arange(S), k)           # token index per slot
        gat = gateg.reshape(-1)
        order = jnp.argsort(eid)                     # stable
        eid_s, tok_s, gat_s = eid[order], tok[order], gat[order]
        # position within expert = rank - first-rank-of-expert
        first = jnp.searchsorted(eid_s, jnp.arange(e.n_experts), side="left")
        slot = jnp.arange(S * k) - first[eid_s]
        keep = slot < C
        slot_c = jnp.clip(slot, 0, C - 1)
        buf = jnp.zeros((e.n_experts, C, d), xg.dtype)
        buf = buf.at[eid_s, slot_c].add(jnp.where(keep[:, None], xg[tok_s], 0))
        return buf, (eid_s, slot_c, tok_s, gat_s, keep)

    buf, meta = jax.vmap(per_group)(x, idx, gates)   # buf (G,E,C,d)
    out = _expert_ffn(params, buf)                   # (G,E,C,d)

    def per_group_combine(outg, m):
        eid_s, slot_c, tok_s, gat_s, keep = m
        vals = outg[eid_s, slot_c] * (gat_s * keep).astype(outg.dtype)[:, None]
        y = jnp.zeros((S, d), outg.dtype).at[tok_s].add(vals)
        return y

    y = jax.vmap(per_group_combine)(out, meta)
    return y, aux


def moe_ffn(params, cfg: ModelConfig, x):
    """x: (B, S, d) -> (y, aux_loss). Groups = batch rows."""
    impl = _moe_einsum if cfg.moe.impl == "einsum" else _moe_gather
    y, aux = impl(params, cfg, x)
    if cfg.moe.n_shared_experts:
        sp = params["shared"]
        g = jnp.einsum("bsd,df->bsf", x, sp["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, sp["w_up"])
        y = y + jnp.einsum("bsf,fd->bsd",
                           jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u,
                           sp["w_down"])
    return y, aux
