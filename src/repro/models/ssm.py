"""Recurrent sequence mixers: xLSTM (mLSTM + sLSTM) and Mamba-2 style SSD.

All train/prefill paths are *chunkwise-parallel* (lax.scan over chunks,
parallel inside a chunk) so the state never round-trips HBM per token —
the same VMEM-residency argument as the attention kernel (DESIGN.md §3).
Decode paths are single-step recurrences over carried state.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, pdtype_of

NEG_INF = -1e30


# ======================================================================
# mLSTM — matrix-memory LSTM (xLSTM [arXiv:2405.04517]), chunkwise form.
# ======================================================================
def make_mlstm_params(rng, cfg: ModelConfig):
    d = cfg.d_model
    H, dh = cfg.ssm.n_heads, cfg.ssm.head_dim
    di = H * dh
    dt = pdtype_of(cfg)
    ks = jax.random.split(rng, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * di), dt),
        "wq": dense_init(ks[1], (di, H, dh), dt, fan_in=di),
        "wk": dense_init(ks[2], (di, H, dh), dt, fan_in=di),
        "wv": dense_init(ks[3], (di, H, dh), dt, fan_in=di),
        "w_if": dense_init(ks[4], (d, 2 * H), jnp.float32, fan_in=d),
        "b_if": jnp.concatenate([jnp.zeros((H,), jnp.float32),
                                 jnp.linspace(3.0, 6.0, H)]),
        "w_down": dense_init(ks[5], (di, d), dt),
        "ogate_w": dense_init(ks[6], (d, di), dt),
    }


def mlstm_init_state(batch: int, H: int, dh: int):
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), NEG_INF, jnp.float32),
    }


def _mlstm_chunk_step(qc, kc, vc, li, lf, state):
    """One chunk: qc/kc/vc (B,K,H,dh); li/lf (B,K,H) log gates; state dict."""
    B, K, H, dh = qc.shape
    scale = 1.0 / math.sqrt(dh)
    b = jnp.cumsum(lf, axis=1)                       # (B,K,H) inclusive decay
    g = li - b                                       # log source weight
    m_intra = jax.lax.cummax(g, axis=1) + b          # (B,K,H)
    m_inter = state["m"][:, None] + b                # (B,K,H)
    m_t = jnp.maximum(m_intra, m_inter)

    # intra-chunk: D[t,j] = exp(b_t + g_j - m_t) for j <= t (head-major)
    bh = jnp.transpose(b, (0, 2, 1))                 # (B,H,K)
    gh = jnp.transpose(g, (0, 2, 1))
    mh = jnp.transpose(m_t, (0, 2, 1))
    logD = bh[:, :, :, None] + gh[:, :, None, :] - mh[:, :, :, None]  # (B,H,K,K)
    causal = jnp.tril(jnp.ones((K, K), bool))
    D = jnp.where(causal, jnp.exp(logD), 0.0)

    qh = jnp.transpose(qc, (0, 2, 1, 3)).astype(jnp.float32)  # (B,H,K,dh)
    kh = jnp.transpose(kc, (0, 2, 1, 3)).astype(jnp.float32)
    vh = jnp.transpose(vc, (0, 2, 1, 3)).astype(jnp.float32)
    s = jnp.einsum("bhtd,bhjd->bhtj", qh, kh) * scale
    w = s * D
    num = jnp.einsum("bhtj,bhjd->bhtd", w, vh)
    den = w.sum(-1)                                   # (B,H,K)

    # inter-chunk contribution
    inter_w = jnp.exp(m_inter - m_t)                  # (B,K,H)
    inter_wh = jnp.transpose(inter_w, (0, 2, 1))      # (B,H,K)
    num = num + inter_wh[..., None] * jnp.einsum("bhtd,bhde->bhte", qh * scale, state["C"])
    den = den + inter_wh * jnp.einsum("bhtd,bhd->bht", qh * scale, state["n"])

    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-mh))[..., None]
    h = jnp.transpose(h, (0, 2, 1, 3))                # (B,K,H,dh)

    # state update to chunk end
    Ftot = b[:, -1]                                   # (B,H)
    m_next = jnp.maximum(state["m"] + Ftot, Ftot + jnp.max(g, axis=1))
    w_prev = jnp.exp(state["m"] + Ftot - m_next)      # (B,H)
    w_src = jnp.exp(Ftot[:, None] + g - m_next[:, None])   # (B,K,H)
    C_new = w_prev[..., None, None] * state["C"] + jnp.einsum(
        "bkh,bhkd,bhke->bhde", w_src, jnp.transpose(kc, (0, 2, 1, 3)).astype(jnp.float32),
        jnp.transpose(vc, (0, 2, 1, 3)).astype(jnp.float32))
    n_new = w_prev[..., None] * state["n"] + jnp.einsum(
        "bkh,bhkd->bhd", w_src, jnp.transpose(kc, (0, 2, 1, 3)).astype(jnp.float32))
    return h, {"C": C_new, "n": n_new, "m": m_next}


def mlstm_sequence(q, k, v, i_raw, f_raw, state=None, chunk: int = 128):
    """q/k/v: (B,S,H,dh); i_raw/f_raw: (B,S,H). Returns (h, final_state)."""
    B, S, H, dh = q.shape
    if state is None:
        state = mlstm_init_state(B, H, dh)
    li = i_raw.astype(jnp.float32)                    # log input gate (exp gate)
    lf = -jax.nn.softplus(-f_raw.astype(jnp.float32))  # log sigmoid forget gate
    K = min(chunk, S)
    nchunk = -(-S // K)
    pad = nchunk * K - S
    if pad:
        padw = ((0, 0), (0, pad), (0, 0))
        q = jnp.pad(q, padw + ((0, 0),))
        k = jnp.pad(k, padw + ((0, 0),))
        v = jnp.pad(v, padw + ((0, 0),))
        li = jnp.pad(li, padw, constant_values=NEG_INF)  # no source weight
        lf = jnp.pad(lf, padw)                            # decay 1 on padding

    def split(x):
        return x.reshape(B, nchunk, K, *x.shape[2:]).transpose(1, 0, 2, *range(3, x.ndim + 1))

    def body(st, xs):
        qc, kc, vc, lic, lfc = xs
        h, st = _mlstm_chunk_step(qc, kc, vc, lic, lfc, st)
        return st, h

    state, hs = jax.lax.scan(body, state, (split(q), split(k), split(v), split(li), split(lf)))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, nchunk * K, H, dh)[:, :S]
    return h.astype(q.dtype), state


def mlstm_step(q1, k1, v1, i_raw, f_raw, state):
    """Single decode step. q1/k1/v1: (B,H,dh); i_raw/f_raw: (B,H)."""
    scale = 1.0 / math.sqrt(q1.shape[-1])
    li = i_raw.astype(jnp.float32)
    lf = -jax.nn.softplus(-f_raw.astype(jnp.float32))
    m_new = jnp.maximum(lf + state["m"], li)
    fw = jnp.exp(lf + state["m"] - m_new)
    iw = jnp.exp(li - m_new)
    kf, vf, qf = (k1.astype(jnp.float32), v1.astype(jnp.float32), q1.astype(jnp.float32))
    C = fw[..., None, None] * state["C"] + iw[..., None, None] * (kf[..., :, None] * vf[..., None, :])
    n = fw[..., None] * state["n"] + iw[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf * scale, C)
    den = jnp.einsum("bhd,bhd->bh", qf * scale, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h.astype(q1.dtype), {"C": C, "n": n, "m": m_new}


def mlstm_block(params, cfg: ModelConfig, x, state=None, decode: bool = False):
    """Full mLSTM block: up-proj, per-head qkv+gates, recurrence, gated down."""
    H, dh = cfg.ssm.n_heads, cfg.ssm.head_dim
    di = H * dh
    u = jnp.einsum("bsd,de->bse", x, params["w_up"])
    a, z = jnp.split(u, 2, axis=-1)                   # (B,S,di) each
    q = jnp.einsum("bse,ehd->bshd", a, params["wq"])
    k = jnp.einsum("bse,ehd->bshd", a, params["wk"])
    v = jnp.einsum("bse,ehd->bshd", a, params["wv"])
    gates = jnp.einsum("bsd,dg->bsg", x.astype(jnp.float32), params["w_if"]) + params["b_if"]
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)       # (B,S,H)
    og = jax.nn.silu(jnp.einsum("bsd,de->bse", x, params["ogate_w"]).astype(jnp.float32)).astype(x.dtype)
    if decode:
        h, state = mlstm_step(q[:, 0], k[:, 0], v[:, 0], i_raw[:, 0], f_raw[:, 0], state)
        h = h[:, None]
    else:
        h, state = mlstm_sequence(q, k, v, i_raw, f_raw, state, chunk=cfg.ssm.chunk)
    h = h.reshape(*h.shape[:2], di) * og
    return jnp.einsum("bse,ed->bsd", h, params["w_down"]), state


# ======================================================================
# sLSTM — scalar-memory LSTM with recurrent gating (strictly sequential).
# ======================================================================
def make_slstm_params(rng, cfg: ModelConfig):
    d = cfg.d_model
    H, dh = cfg.ssm.n_heads, cfg.ssm.head_dim
    dt = pdtype_of(cfg)
    ks = jax.random.split(rng, 4)
    return {
        "w_gates": dense_init(ks[0], (d, 4 * d), jnp.float32, fan_in=d),   # z,i,f,o
        "r_gates": dense_init(ks[1], (4, H, dh, dh), jnp.float32, fan_in=dh),
        "b_gates": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                                    jnp.tile(jnp.linspace(3.0, 6.0, H), dh).reshape(dh, H).T.reshape(-1),
                                    jnp.zeros((d,), jnp.float32)]),
        "w_out": dense_init(ks[2], (d, d), dt),
    }


def slstm_init_state(batch: int, d: int, H: int, dh: int):
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"h": z, "c": z, "n": z + 1e-6, "m": jnp.full((batch, H, dh), NEG_INF, jnp.float32)}


def _slstm_cell(params, cfg: ModelConfig, xw, st):
    """xw: (B, 4d) precomputed input contribution; st: state dict."""
    H, dh = cfg.ssm.n_heads, cfg.ssm.head_dim
    B = xw.shape[0]
    rec = jnp.einsum("ghde,bhe->bghd", params["r_gates"], st["h"])   # (B,4,H,dh)
    gates = xw.reshape(B, 4, H, dh) + rec + params["b_gates"].reshape(4, H, dh)
    z_t = jnp.tanh(gates[:, 0])
    i_raw, f_raw = gates[:, 1], gates[:, 2]
    o_t = jax.nn.sigmoid(gates[:, 3])
    lf = -jax.nn.softplus(-f_raw)                     # log sigmoid forget
    m_new = jnp.maximum(lf + st["m"], i_raw)
    iw = jnp.exp(i_raw - m_new)
    fw = jnp.exp(lf + st["m"] - m_new)
    c = fw * st["c"] + iw * z_t
    n = fw * st["n"] + iw
    h = o_t * c / jnp.maximum(n, 1e-6)
    return {"h": h, "c": c, "n": n, "m": m_new}


def slstm_block(params, cfg: ModelConfig, x, state=None, decode: bool = False):
    B, S, d = x.shape
    H, dh = cfg.ssm.n_heads, cfg.ssm.head_dim
    if state is None:
        state = slstm_init_state(B, d, H, dh)
    xw = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["w_gates"])  # (B,S,4d)
    if decode:
        state = _slstm_cell(params, cfg, xw[:, 0], state)
        hs = state["h"][:, None]
    else:
        def body(st, xt):
            st = _slstm_cell(params, cfg, xt, st)
            return st, st["h"]
        state, hs = jax.lax.scan(body, state, xw.transpose(1, 0, 2))
        hs = hs.transpose(1, 0, 2, 3)                 # (B,S,H,dh)
    out = hs.reshape(*hs.shape[:2], d).astype(x.dtype)
    return jnp.einsum("bsd,de->bse", out, params["w_out"]), state


# ======================================================================
# Mamba-2 style SSD (hymba's SSM heads) — scalar-per-head decay, chunked.
# ======================================================================
def make_mamba_params(rng, cfg: ModelConfig):
    d = cfg.d_model
    H, dh, N = cfg.ssm.n_heads, cfg.ssm.head_dim, cfg.ssm.d_state
    di = H * dh
    dt = pdtype_of(cfg)
    ks = jax.random.split(rng, 6)
    return {
        "w_in": dense_init(ks[0], (d, 2 * di), dt),                 # x, z
        "conv_w": dense_init(ks[1], (cfg.ssm.conv_dim, di), dt, fan_in=cfg.ssm.conv_dim),
        "w_bc": dense_init(ks[2], (d, 2 * N), dt),                  # B, C (ngroups=1)
        "w_dt": dense_init(ks[3], (d, H), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.linspace(1e-3, 0.1, H))).astype(jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "w_out": dense_init(ks[4], (di, d), dt),
    }


def mamba_init_state(batch: int, cfg: ModelConfig):
    H, dh, N = cfg.ssm.n_heads, cfg.ssm.head_dim, cfg.ssm.d_state
    di = H * dh
    return {
        "ssm": jnp.zeros((batch, H, dh, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm.conv_dim - 1, di), jnp.float32),
    }


def _causal_conv(params, cfg: ModelConfig, xc, conv_state=None):
    """Depthwise causal conv over (B,S,di); returns (y, new_tail_state)."""
    K = cfg.ssm.conv_dim
    if conv_state is None:
        pad = jnp.zeros((xc.shape[0], K - 1, xc.shape[2]), xc.dtype)
    else:
        pad = conv_state.astype(xc.dtype)
    xp = jnp.concatenate([pad, xc], axis=1)           # (B, S+K-1, di)
    y = sum(xp[:, i:i + xc.shape[1]] * params["conv_w"][i] for i in range(K))
    new_state = xp[:, -(K - 1):].astype(jnp.float32)
    return jax.nn.silu(y.astype(jnp.float32)).astype(xc.dtype), new_state


def ssd_sequence(xh, B_t, C_t, la, state, chunk: int):
    """Chunked SSD: xh (B,S,H,dh) dt-scaled inputs; B_t/C_t (B,S,N);
    la (B,S,H) log decay (<= 0); state (B,H,dh,N)."""
    Bb, S, H, dh = xh.shape
    N = B_t.shape[-1]
    K = min(chunk, S)
    nchunk = -(-S // K)
    pad = nchunk * K - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B_t = jnp.pad(B_t, ((0, 0), (0, pad), (0, 0)))
        C_t = jnp.pad(C_t, ((0, 0), (0, pad), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)), constant_values=0.0)

    def split(x):
        return x.reshape(Bb, nchunk, K, *x.shape[2:]).transpose(1, 0, 2, *range(3, x.ndim + 1))

    def body(st, xs):
        xc, bc, cc, lac = xs                          # (B,K,H,dh),(B,K,N),(B,K,N),(B,K,H)
        b = jnp.cumsum(lac, axis=1)                   # (B,K,H)
        # intra-chunk: y_t += sum_{j<=t} exp(b_t-b_j) (C_t.B_j) x_j
        sc = jnp.einsum("btn,bjn->btj", cc.astype(jnp.float32), bc.astype(jnp.float32))
        logw = b[:, :, None, :] - b[:, None, :, :]     # (B,t,j,H)
        causal = jnp.tril(jnp.ones((K, K), bool))[None, :, :, None]
        # mask BEFORE exp: non-causal logw is positive and overflows to inf,
        # and where(c, inf, 0) back-propagates 0 * inf = NaN cotangents
        logw = jnp.where(causal, logw, -jnp.inf)
        w = jnp.exp(logw) * sc[..., None]
        y = jnp.einsum("btjh,bjhd->bthd", w, xc.astype(jnp.float32))
        # inter-chunk: y_t += exp(b_t) C_t . h_prev
        winter = jnp.exp(b)                            # (B,K,H)
        y = y + winter[..., None] * jnp.einsum("btn,bhdn->bthd", cc.astype(jnp.float32), st)
        # state update
        Ftot = b[:, -1]                                # (B,H)
        wsrc = jnp.exp(Ftot[:, None] - b)              # (B,K,H)
        st = jnp.exp(Ftot)[:, :, None, None] * st + jnp.einsum(
            "bkh,bkhd,bkn->bhdn", wsrc, xc.astype(jnp.float32), bc.astype(jnp.float32))
        return st, y

    state, ys = jax.lax.scan(body, state, (split(xh), split(B_t), split(C_t), split(la)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bb, nchunk * K, H, dh)[:, :S]
    return y, state


def mamba_block(params, cfg: ModelConfig, x, state=None, decode: bool = False):
    """Returns ((B,S,H*dh) heads output BEFORE out-proj, new_state)."""
    B, S, d = x.shape
    H, dh, N = cfg.ssm.n_heads, cfg.ssm.head_dim, cfg.ssm.d_state
    di = H * dh
    if state is None:
        state = mamba_init_state(B, cfg)
    u = jnp.einsum("bsd,de->bse", x, params["w_in"])
    xc, z = jnp.split(u, 2, axis=-1)
    xc, conv_state = _causal_conv(params, cfg, xc, state["conv"])
    bc = jnp.einsum("bsd,dn->bsn", x, params["w_bc"])
    B_t, C_t = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), params["w_dt"])
                         + params["dt_bias"])         # (B,S,H)
    la = -jnp.exp(params["A_log"]) * dt               # log decay <= 0
    xh = xc.reshape(B, S, H, dh) * dt[..., None].astype(xc.dtype)
    if decode:
        st = state["ssm"]
        a = jnp.exp(la[:, 0])                          # (B,H)
        st = a[..., None, None] * st + jnp.einsum("bhd,bn->bhdn",
                                                  xh[:, 0].astype(jnp.float32),
                                                  B_t[:, 0].astype(jnp.float32))
        y = jnp.einsum("bn,bhdn->bhd", C_t[:, 0].astype(jnp.float32), st)[:, None]
        new_ssm = st
    else:
        y, new_ssm = ssd_sequence(xh, B_t, C_t, la, state["ssm"], cfg.ssm.chunk)
    y = y + params["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return y, {"ssm": new_ssm, "conv": conv_state}
