"""Model assembly: init, train forward, prefill, decode — all families.

Layer stacks are ``lax.scan`` over layer-stacked params (axis 0), which keeps
the HLO size O(1) in depth (fast multi-pod compiles) and is remat-friendly.
Per-layer heterogeneity (gemma3's 5:1 local:global windows) is expressed as
per-layer *data* (a windows array scanned as xs), never as per-layer code.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_norm, dense_init, embed_init,
                                 make_norm_params, make_swiglu_params,
                                 pdtype_of, stack_layer_params)


# ======================================================================
# Parameter init
# ======================================================================
def _decoder_layer_init(rng, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(rng, 6)
    p = {
        "attn_norm": make_norm_params(cfg),
        "attn": attn.make_attn_params(ks[0], cfg),
        "ffn_norm": make_norm_params(cfg),
    }
    if cfg.moe.n_experts:
        p["ffn"] = moe_mod.make_moe_params(ks[1], cfg)
    elif cfg.d_ff:
        p["ffn"] = make_swiglu_params(ks[1], cfg.d_model, cfg.d_ff, pdtype_of(cfg))
    if cross:
        p["cross_norm"] = make_norm_params(cfg)
        p["cross"] = attn.make_attn_params(ks[2], cfg, cross=True)
    if cfg.family == "hybrid":
        p["mamba"] = ssm_mod.make_mamba_params(ks[3], cfg)
        dh = cfg.head_dim
        p["hy_norm_attn"] = jnp.zeros((dh,), jnp.float32)
        p["hy_norm_ssm"] = jnp.zeros((dh,), jnp.float32)
        p["hy_beta_attn"] = jnp.ones((cfg.n_heads, dh), pdtype_of(cfg))
        p["hy_beta_ssm"] = jnp.ones((cfg.n_heads, dh), pdtype_of(cfg))
    return p


def _xlstm_pair_init(rng, cfg: ModelConfig):
    k1, k2 = jax.random.split(rng)
    return {
        "m_norm": make_norm_params(cfg),
        "mlstm": ssm_mod.make_mlstm_params(k1, cfg),
        "s_norm": make_norm_params(cfg),
        "slstm": ssm_mod.make_slstm_params(k2, cfg),
    }


def init_params(rng, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(rng, 8)
    dt = pdtype_of(cfg)
    p: Dict[str, Any] = {
        "embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dt),
        "final_norm": make_norm_params(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.padded_vocab), dt)
    if cfg.family == "ssm":
        n_pairs = cfg.n_layers // 2
        p["layers"] = stack_layer_params(lambda r: _xlstm_pair_init(r, cfg), ks[2], n_pairs)
    elif cfg.family == "encdec":
        enc_cfg = cfg
        p["enc_layers"] = stack_layer_params(
            lambda r: _decoder_layer_init(r, enc_cfg), ks[3], cfg.n_enc_layers)
        p["enc_final_norm"] = make_norm_params(cfg)
        p["layers"] = stack_layer_params(
            lambda r: _decoder_layer_init(r, cfg, cross=True), ks[2], cfg.n_layers)
    else:
        p["layers"] = stack_layer_params(
            lambda r: _decoder_layer_init(r, cfg), ks[2], cfg.n_layers)
    return p


def layer_windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer sliding window (0 = full attention)."""
    w = np.full((cfg.n_layers,), cfg.sliding_window, np.int32)
    if cfg.global_every:
        w[cfg.global_every - 1::cfg.global_every] = 0
    return w


# ======================================================================
# Shared layer bodies
# ======================================================================
def _ffn_apply(lp, cfg: ModelConfig, x):
    """Returns (y, aux)."""
    if cfg.moe.n_experts:
        return moe_mod.moe_ffn(lp["ffn"], cfg, x)
    if cfg.d_ff:
        from repro.models.layers import swiglu
        return swiglu(lp["ffn"], x), 0.0
    return jnp.zeros_like(x), 0.0


def _headnorm(x, w, eps=1e-6):
    """Per-head RMS norm over the last (dh) dim; w: (dh,)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w)).astype(dt)


def _hymba_mixer(lp, cfg: ModelConfig, h, positions, window, cache=None,
                 cur_pos=None, decode=False):
    """Parallel attention + mamba heads, fused output projection."""
    ap = lp["attn"]
    new_cache = {}
    if decode:
        q, k, v = attn.qkv_proj(ap, cfg, h, jnp.asarray(cur_pos)[None][None])
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cur_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cur_pos, axis=1)
        a_out = attn.attend_cache(q, ck, cv, cur_pos, window=window)
        new_cache.update(k=ck, v=cv)
        s_out, sstate = ssm_mod.mamba_block(lp["mamba"], cfg, h,
                                            {"ssm": cache["ssm"], "conv": cache["conv"]},
                                            decode=True)
        new_cache.update(ssm=sstate["ssm"], conv=sstate["conv"])
    else:
        q, k, v = attn.qkv_proj(ap, cfg, h, positions)
        a_out = attn.flash_dispatch(q, k, v, causal=True, window=window)
        s_out, sstate = ssm_mod.mamba_block(lp["mamba"], cfg, h, None, decode=False)
        new_cache.update(k=k, v=v, ssm=sstate["ssm"], conv=sstate["conv"])
    B, S = h.shape[0], h.shape[1]
    s_heads = s_out.reshape(B, S, cfg.n_heads, cfg.head_dim)
    mixed = 0.5 * (_headnorm(a_out, lp["hy_norm_attn"]) * lp["hy_beta_attn"]
                   + _headnorm(s_heads, lp["hy_norm_ssm"]) * lp["hy_beta_ssm"])
    return attn.out_proj(ap, mixed), new_cache


# ======================================================================
# Train / full-sequence forward
# ======================================================================
def _embed(params, cfg: ModelConfig, tokens):
    from repro.dist.sharding import shard
    return shard(params["embed"][tokens], "batch", "seq", "embed")


def _unembed(params, cfg: ModelConfig, x):
    from repro.dist.sharding import shard
    x = apply_norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return shard(logits, "batch", "seq", "vocab")


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    policy = None
    if remat == "block":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=policy)


def forward_full(params, cfg: ModelConfig, batch: Dict[str, Any], *,
                 remat: str = "none", collect_cache: bool = False,
                 cache_len: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
    """Full-sequence forward for train & prefill.

    Returns (logits, aux_loss, cache_or_None). ``batch`` carries ``tokens``
    (B, S_text) plus optional ``enc_embeds`` / ``prefix_embeds``.
    """
    tokens = batch["tokens"]
    x = _embed(params, cfg, tokens)
    if cfg.family == "vlm" and "prefix_embeds" in batch:
        x = jnp.concatenate([batch["prefix_embeds"].astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]

    mem_kv = None
    if cfg.family == "encdec":
        mem = _encode(params, cfg, batch["enc_embeds"], remat=remat)
        # memory K/V are per-decoder-layer; computed inside the scan from mem
    windows = jnp.asarray(layer_windows(cfg)) if cfg.family != "ssm" else None

    aux_total = jnp.zeros((), jnp.float32)
    cache = None

    if cfg.family == "ssm":
        def pair_body(carry, lp):
            h, aux = carry
            y, ms = ssm_mod.mlstm_block(lp["mlstm"], cfg, apply_norm(cfg, lp["m_norm"], h))
            h = h + y
            y, ss = ssm_mod.slstm_block(lp["slstm"], cfg, apply_norm(cfg, lp["s_norm"], h))
            h = h + y
            return (h, aux), ((ms, ss) if collect_cache else None)
        (x, aux_total), ys = jax.lax.scan(_maybe_remat(pair_body, remat), (x, aux_total), params["layers"])
        logits = _unembed(params, cfg, x)
        if collect_cache:
            ms, ss = ys                                # stacked on pair dim
            cache = {"mlstm": ms, "slstm": ss}
            return logits, aux_total, cache
        return logits, aux_total, None

    if cfg.family == "encdec":
        def dec_body(carry, lp):
            h, aux = carry
            hn = apply_norm(cfg, lp["attn_norm"], h)
            if collect_cache:
                a, kv = attn.self_attention_prefill(lp["attn"], cfg, hn, positions)
            else:
                a = attn.self_attention(lp["attn"], cfg, hn, positions, causal=True)
                kv = None
            h = h + a
            mk, mv = attn.encode_memory(lp["cross"], cfg, mem)
            c = attn.cross_attention(lp["cross"], cfg, apply_norm(cfg, lp["cross_norm"], h), mk, mv)
            h = h + c
            f, a2 = _ffn_apply(lp, cfg, apply_norm(cfg, lp["ffn_norm"], h))
            return (h + f, aux + a2), ((kv, (mk, mv)) if collect_cache else None)
        (x, aux_total), ys = jax.lax.scan(_maybe_remat(dec_body, remat), (x, aux_total), params["layers"])
        logits = _unembed(params, cfg, x)
        if collect_cache:
            (k, v), (mk, mv) = ys
            pad = cache_len - k.shape[2]
            if pad > 0:
                padw = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
                k, v = jnp.pad(k, padw), jnp.pad(v, padw)
            cache = {"k": k, "v": v, "mem_k": mk, "mem_v": mv}
        return logits, aux_total, cache

    # decoder-only families (dense / moe / hybrid / vlm)
    def layer_step(carry, lp, window):
        h, aux = carry
        hn = apply_norm(cfg, lp["attn_norm"], h)
        if cfg.family == "hybrid":
            a_out, hy_cache = _hymba_mixer(lp, cfg, hn, positions, window)
            kv = ((hy_cache["k"], hy_cache["v"], hy_cache["ssm"], hy_cache["conv"])
                  if collect_cache else None)
        elif collect_cache:
            a_out, kv = attn.self_attention_prefill(lp["attn"], cfg, hn, positions, window=window)
        else:
            a_out = attn.self_attention(lp["attn"], cfg, hn, positions, window=window)
            kv = None
        h = h + a_out
        f, a2 = _ffn_apply(lp, cfg, apply_norm(cfg, lp["ffn_norm"], h))
        return (h + f, aux + a2), kv

    tmap = jax.tree_util.tree_map
    w_np = layer_windows(cfg)
    if len(set(w_np.tolist())) == 1:
        # uniform window across layers: pass it STATICALLY so the banded
        # block-skipping attention path applies (see attention.py)
        w_static = int(w_np[0])

        def layer_body(carry, lp):
            return layer_step(carry, lp, w_static)
        (x, aux_total), kv = jax.lax.scan(_maybe_remat(layer_body, remat),
                                          (x, aux_total), params["layers"])
    elif cfg.global_every and cfg.n_layers >= cfg.global_every:
        # periodic local:global pattern (gemma3's 5:1): scan over PERIODS
        # with the window pattern unrolled statically inside the body, so
        # the banded path applies to every local layer (§Perf).  Leftover
        # layers (L % period) run unrolled after the scan.
        p = cfg.global_every
        n_per = cfg.n_layers // p
        pattern = [int(w) for w in w_np[:p]]
        periods = tmap(lambda a: a[:n_per * p].reshape(n_per, p, *a.shape[1:]),
                       params["layers"])

        def period_body(carry, lp_period):
            kvs = []
            for j in range(p):
                lp_j = tmap(lambda a, j=j: a[j], lp_period)
                carry, kv_j = layer_step(carry, lp_j, pattern[j])
                kvs.append(kv_j)
            if collect_cache:
                return carry, tmap(lambda *xs: jnp.stack(xs), *kvs)
            return carry, None

        (x, aux_total), kv_p = jax.lax.scan(_maybe_remat(period_body, remat),
                                            (x, aux_total), periods)
        rem_kvs = []
        for i in range(n_per * p, cfg.n_layers):
            lp_i = tmap(lambda a, i=i: a[i], params["layers"])
            (x, aux_total), kv_i = layer_step((x, aux_total), lp_i, int(w_np[i]))
            rem_kvs.append(kv_i)
        if collect_cache:
            kv = tmap(lambda a: a.reshape(n_per * p, *a.shape[2:]), kv_p)
            if rem_kvs:
                kv_r = tmap(lambda *xs: jnp.stack(xs), *rem_kvs)
                kv = tmap(lambda a, b: jnp.concatenate([a, b], axis=0), kv, kv_r)
        else:
            kv = None
    else:
        def layer_body(carry, xs):
            lp, window = xs
            return layer_step(carry, lp, window)
        (x, aux_total), kv = jax.lax.scan(_maybe_remat(layer_body, remat),
                                          (x, aux_total), (params["layers"], windows))
    logits = _unembed(params, cfg, x)
    if collect_cache:
        if cfg.family == "hybrid":
            k, v, ssm_st, conv_st = kv
        else:
            k, v = kv      # (L, B, S, Hkv, dh)
        pad = cache_len - k.shape[2]
        if pad > 0:
            padw = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
            k, v = jnp.pad(k, padw), jnp.pad(v, padw)
        cache = {"k": k, "v": v}
        if cfg.family == "hybrid":
            cache["ssm"], cache["conv"] = ssm_st, conv_st
    return logits, aux_total, cache


def _encode(params, cfg: ModelConfig, enc_embeds, *, remat="none"):
    """Bidirectional encoder over stub frame embeddings (B, Se, d)."""
    x = enc_embeds.astype(jnp.dtype(cfg.compute_dtype))
    Se = x.shape[1]
    positions = jnp.arange(Se)[None, :]

    def enc_body(h, lp):
        a = attn.self_attention(lp["attn"], cfg, apply_norm(cfg, lp["attn_norm"], h),
                                positions, causal=False)
        h = h + a
        f, _ = _ffn_apply(lp, cfg, apply_norm(cfg, lp["ffn_norm"], h))
        return h + f, None

    x, _ = jax.lax.scan(_maybe_remat(enc_body, remat), x, params["enc_layers"])
    return apply_norm(cfg, params["enc_final_norm"], x)


# ======================================================================
# Decode (single token against cache / state)
# ======================================================================
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Uniform stacked cache pytree for one-token decode."""
    L = cfg.n_layers
    if cfg.family == "ssm":
        n_pairs = L // 2
        H, dh = cfg.ssm.n_heads, cfg.ssm.head_dim
        return {
            "mlstm": {
                "C": jnp.zeros((n_pairs, batch, H, dh, dh), jnp.float32),
                "n": jnp.zeros((n_pairs, batch, H, dh), jnp.float32),
                "m": jnp.full((n_pairs, batch, H), -1e30, jnp.float32),
            },
            "slstm": {
                k: (jnp.full((n_pairs, batch, H, dh), -1e30, jnp.float32) if k == "m"
                    else jnp.zeros((n_pairs, batch, H, dh), jnp.float32))
                for k in ("h", "c", "n", "m")
            },
        }
    cache = {
        "k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
    }
    if cfg.family == "hybrid":
        H, dh, N = cfg.ssm.n_heads, cfg.ssm.head_dim, cfg.ssm.d_state
        di = H * dh
        cache["ssm"] = jnp.zeros((L, batch, H, dh, N), jnp.float32)
        cache["conv"] = jnp.zeros((L, batch, cfg.ssm.conv_dim - 1, di), jnp.float32)
    if cfg.family == "encdec":
        Se = max_len // cfg.enc_len_ratio
        cache["mem_k"] = jnp.zeros((L, batch, Se, cfg.n_kv_heads, cfg.head_dim), dtype)
        cache["mem_v"] = jnp.zeros((L, batch, Se, cfg.n_kv_heads, cfg.head_dim), dtype)
    return cache


def forward_decode(params, cfg: ModelConfig, token, cache, cur_pos):
    """token: (B, 1) int32; cur_pos: scalar int32. Returns (logits, cache)."""
    x = _embed(params, cfg, token)
    windows = jnp.asarray(layer_windows(cfg)) if cfg.family != "ssm" else None

    if cfg.family == "ssm":
        def pair_body(h, st):
            y, ms = ssm_mod.mlstm_block(st["p"]["mlstm"], cfg,
                                        apply_norm(cfg, st["p"]["m_norm"], h),
                                        st["m_state"], decode=True)
            h = h + y
            y, ss = ssm_mod.slstm_block(st["p"]["slstm"], cfg,
                                        apply_norm(cfg, st["p"]["s_norm"], h),
                                        st["s_state"], decode=True)
            return h + y, {"m": ms, "s": ss}
        def body(h, xs):
            p, mC, mn, mm, sh_, sc_, sn_, sm_ = xs
            h, new = pair_body(h, {"p": p,
                                   "m_state": {"C": mC, "n": mn, "m": mm},
                                   "s_state": {"h": sh_, "c": sc_, "n": sn_, "m": sm_}})
            return h, (new["m"]["C"], new["m"]["n"], new["m"]["m"],
                       new["s"]["h"], new["s"]["c"], new["s"]["n"], new["s"]["m"])
        ml, sl = cache["mlstm"], cache["slstm"]
        x, outs = jax.lax.scan(body, x, (params["layers"], ml["C"], ml["n"], ml["m"],
                                         sl["h"], sl["c"], sl["n"], sl["m"]))
        new_cache = {"mlstm": {"C": outs[0], "n": outs[1], "m": outs[2]},
                     "slstm": {"h": outs[3], "c": outs[4], "n": outs[5], "m": outs[6]}}
        return _unembed(params, cfg, x), new_cache

    if cfg.family == "encdec":
        def body(h, xs):
            lp, ck, cv, mk, mv = xs
            a, (ck, cv) = attn.self_attention_decode(
                lp["attn"], cfg, apply_norm(cfg, lp["attn_norm"], h), ck, cv, cur_pos)
            h = h + a
            c = attn.cross_attention(lp["cross"], cfg,
                                     apply_norm(cfg, lp["cross_norm"], h), mk, mv)
            h = h + c
            f, _ = _ffn_apply(lp, cfg, apply_norm(cfg, lp["ffn_norm"], h))
            return h + f, (ck, cv)
        x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"],
                                             cache["mem_k"], cache["mem_v"]))
        cache = dict(cache, k=nk, v=nv)
        return _unembed(params, cfg, x), cache

    if cfg.family == "hybrid":
        def body(h, xs):
            lp, ck, cv, cs, cc, window = xs
            hn = apply_norm(cfg, lp["attn_norm"], h)
            a_out, nc = _hymba_mixer(lp, cfg, hn, None, window,
                                     cache={"k": ck, "v": cv, "ssm": cs, "conv": cc},
                                     cur_pos=cur_pos, decode=True)
            h = h + a_out
            f, _ = _ffn_apply(lp, cfg, apply_norm(cfg, lp["ffn_norm"], h))
            return h + f, (nc["k"], nc["v"], nc["ssm"], nc["conv"])
        x, outs = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"],
                                         cache["ssm"], cache["conv"], windows))
        cache = {"k": outs[0], "v": outs[1], "ssm": outs[2], "conv": outs[3]}
        return _unembed(params, cfg, x), cache

    def body(h, xs):
        lp, ck, cv, window = xs
        a, (ck, cv) = attn.self_attention_decode(
            lp["attn"], cfg, apply_norm(cfg, lp["attn_norm"], h), ck, cv, cur_pos,
            window=window)
        h = h + a
        f, _ = _ffn_apply(lp, cfg, apply_norm(cfg, lp["ffn_norm"], h))
        return h + f, (ck, cv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"], windows))
    cache = dict(cache, k=nk, v=nv)
    return _unembed(params, cfg, x), cache


# ======================================================================
# Loss
# ======================================================================
def lm_loss(logits, labels, vocab_size: int):
    """Mean token cross-entropy; labels < 0 are masked.

    Written without gathers along the vocab dim so vocab-TP logits never
    get all-gathered: the gold logit is an elementwise select-and-reduce
    over the sharded axis (partial sums + a scalar-ish all-reduce).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    onehot = (vocab_iota == jnp.maximum(labels, 0)[..., None])
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - gold
    mask = (labels >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
