"""repro.obs — span tracing for the Eva-CiM pipeline itself.

The paper's thesis is attribution (where do a workload's energy and
time go?); this package applies the same discipline to the framework:
every pipeline stage — trace VM, replay, IDG analysis, selection,
pricing, store I/O, jit launches, adaptive rounds, daemon requests —
opens a :class:`Span`, and the finished spans export to Perfetto
(Chrome trace-event JSON), NDJSON, or a per-stage attribution table.

Tracing is off by default and free when off::

    from repro import obs
    tracer = obs.enable()
    ...run a sweep...
    tracer.export_chrome("trace.json")       # open in ui.perfetto.dev
    print(obs.attribution_markdown(obs.stage_attribution(tracer.spans())))
    obs.disable()

See ``docs/architecture.md`` ("Tracing") for the span taxonomy.
"""
from repro.obs.tracer import (NULL_SPAN, Span, TraceContext, Tracer, active,
                              attach, counter, current, disable, enable,
                              ingest, span, tracer)
from repro.obs.export import (attribution_markdown, build_tree,
                              export_chrome, export_ndjson,
                              stage_attribution)

__all__ = [
    "NULL_SPAN", "Span", "TraceContext", "Tracer",
    "active", "attach", "counter", "current", "disable", "enable",
    "ingest", "span", "tracer",
    "attribution_markdown", "build_tree", "export_chrome",
    "export_ndjson", "stage_attribution",
]
