"""Exporters + rollups over finished span dicts.

Everything here is a pure function over the span/counter dicts a
:class:`repro.obs.Tracer` collects (see ``tracer.py`` for the record
shape), so it works equally on a live tracer's buffer, a daemon ring
buffer entry, or spans re-read from an NDJSON log.

Three consumers, three formats:

* :func:`export_chrome` — Chrome trace-event JSON, the dialect
  Perfetto (https://ui.perfetto.dev) and ``chrome://tracing`` load:
  complete spans as ``ph:"X"`` events (``ts``/``dur`` in microseconds),
  counter samples as ``ph:"C"`` events, and ``ph:"M"`` metadata naming
  each pid/tid so the track labels read "eva-cim (pid 1234)" /
  "dse-worker-3" instead of bare numbers.
* :func:`export_ndjson` — one span dict per line, for grep/jq.
* :func:`stage_attribution` — the per-stage rollup behind
  ``examples/dse_cim.py --trace-report``: total and *self* time per
  category (self = duration minus children, clamped at zero — so with a
  serial executor the self times of a trace telescope back to its root
  span's duration), cache hit ratios from ``source=`` attributes, and a
  per-workload breakdown.  :func:`attribution_markdown` renders it.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence

# span attrs tagging how a cache layer answered; memo/store count as
# hits (work reused), build as a miss, coalesced as a dedup'd wait
_HIT_SOURCES = ("memo", "store", "coalesced")
_MISS_SOURCES = ("build", "evaluated")


def _us(ns: int) -> float:
    return ns / 1000.0


def export_chrome(spans: Sequence[Dict], counters: Sequence[Dict] = (),
                  path: Any = None, name: str = "eva-cim") -> int:
    """Write Chrome trace-event JSON; returns the number of X events.

    ``path`` may be a filesystem path or an open text file.  Timestamps
    are rebased so the earliest event sits at ts=0 (Perfetto renders
    unix-epoch microseconds fine, but a zero origin keeps the numbers
    readable in the JSON itself)."""
    events: List[Dict] = []
    base_ns = min([s["ts_ns"] for s in spans]
                  + [c["ts_ns"] for c in counters]) if (spans or counters) \
        else 0
    seen_pids: Dict[int, None] = {}
    seen_tids: Dict[tuple, str] = {}
    for s in spans:
        pid, tid = s["pid"], s["tid"]
        seen_pids.setdefault(pid, None)
        seen_tids.setdefault((pid, tid), s.get("thread") or f"tid {tid}")
        args = dict(s["attrs"])
        args["trace_id"] = s["trace_id"]
        args["span_id"] = s["span_id"]
        if s["parent_id"]:
            args["parent_id"] = s["parent_id"]
        events.append({"name": s["name"], "cat": s["cat"] or "misc",
                       "ph": "X", "ts": _us(s["ts_ns"] - base_ns),
                       "dur": _us(s["dur_ns"]), "pid": pid, "tid": tid,
                       "args": args})
    n_span_events = len(events)
    for c in counters:
        pid = c["pid"]
        seen_pids.setdefault(pid, None)
        events.append({"name": c["name"], "cat": "counter", "ph": "C",
                       "ts": _us(c["ts_ns"] - base_ns), "pid": pid,
                       "tid": 0, "args": {"value": c["value"]}})
    meta: List[Dict] = []
    for pid in seen_pids:
        meta.append({"name": "process_name", "ph": "M", "pid": pid,
                     "tid": 0, "args": {"name": f"{name} (pid {pid})"}})
    for (pid, tid), tname in seen_tids.items():
        meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                     "tid": tid, "args": {"name": tname}})
    doc = {"traceEvents": meta + events, "displayTimeUnit": "ms",
           "otherData": {"producer": "repro.obs", "spans": n_span_events}}
    if hasattr(path, "write"):
        json.dump(doc, path)
    else:
        with open(path, "w") as fh:
            json.dump(doc, fh)
    return n_span_events


def export_ndjson(spans: Sequence[Dict], path: Any) -> int:
    """One finished-span dict per line; returns the line count."""
    if hasattr(path, "write"):
        for s in spans:
            path.write(json.dumps(s) + "\n")
        return len(spans)
    with open(path, "w") as fh:
        for s in spans:
            fh.write(json.dumps(s) + "\n")
    return len(spans)


def build_tree(spans: Sequence[Dict]) -> List[Dict]:
    """Nest spans into parent→children trees (roots returned, children
    under a ``"children"`` key, siblings in start-time order).  Spans
    whose parent is missing from the input are treated as roots."""
    by_id: Dict[str, Dict] = {}
    for s in spans:
        node = dict(s)
        node["children"] = []
        by_id[s["span_id"]] = node
    roots: List[Dict] = []
    for node in by_id.values():
        parent = node.get("parent_id")
        if parent and parent in by_id:
            by_id[parent]["children"].append(node)
        else:
            roots.append(node)
    for node in by_id.values():
        node["children"].sort(key=lambda n: n["ts_ns"])
    roots.sort(key=lambda n: n["ts_ns"])
    return roots


def stage_attribution(spans: Sequence[Dict]) -> Dict:
    """Per-stage (span category) rollup of where the time went.

    Returns::

        {"wall_s":        sum of root-span durations,
         "attributed_s":  sum of self times across all spans,
         "coverage":      attributed_s / wall_s   (≈1.0 for serial runs;
                          >1 signals overlapped/parallel children),
         "n_spans":       input size,
         "stages": {cat: {"count", "total_s", "self_s", "hits",
                          "misses", "hit_rate"}},
         "workloads": {workload: {cat: self_s}}}
    """
    by_id = {s["span_id"]: s for s in spans}
    children_ns: Dict[str, int] = {}
    for s in spans:
        parent = s.get("parent_id")
        if parent and parent in by_id:
            children_ns[parent] = children_ns.get(parent, 0) + s["dur_ns"]

    stages: Dict[str, Dict] = {}
    workloads: Dict[str, Dict[str, float]] = {}
    wall_ns = 0
    attributed_ns = 0
    for s in spans:
        if not (s.get("parent_id") and s["parent_id"] in by_id):
            wall_ns += s["dur_ns"]
        self_ns = max(0, s["dur_ns"] - children_ns.get(s["span_id"], 0))
        attributed_ns += self_ns
        cat = s["cat"] or "misc"
        st = stages.setdefault(cat, {"count": 0, "total_ns": 0,
                                     "self_ns": 0, "hits": 0, "misses": 0})
        st["count"] += 1
        st["total_ns"] += s["dur_ns"]
        st["self_ns"] += self_ns
        source = s["attrs"].get("source")
        if source in _HIT_SOURCES:
            st["hits"] += 1
        elif source in _MISS_SOURCES:
            st["misses"] += 1
        workload = s["attrs"].get("workload")
        if workload:
            per = workloads.setdefault(str(workload), {})
            per[cat] = per.get(cat, 0.0) + self_ns / 1e9

    out_stages: Dict[str, Dict] = {}
    for cat, st in sorted(stages.items(),
                          key=lambda kv: -kv[1]["self_ns"]):
        answered = st["hits"] + st["misses"]
        out_stages[cat] = {
            "count": st["count"],
            "total_s": st["total_ns"] / 1e9,
            "self_s": st["self_ns"] / 1e9,
            "hits": st["hits"],
            "misses": st["misses"],
            "hit_rate": (st["hits"] / answered) if answered else None,
        }
    wall_s = wall_ns / 1e9
    attributed_s = attributed_ns / 1e9
    return {"wall_s": wall_s, "attributed_s": attributed_s,
            "coverage": (attributed_s / wall_s) if wall_ns else 1.0,
            "n_spans": len(spans), "stages": out_stages,
            "workloads": {w: dict(sorted(per.items(),
                                         key=lambda kv: -kv[1]))
                          for w, per in sorted(workloads.items())}}


def attribution_markdown(att: Dict) -> str:
    """Render :func:`stage_attribution` output as markdown tables."""
    lines = ["| stage | spans | total s | self s | % wall | hit rate |",
             "|---|---:|---:|---:|---:|---:|"]
    wall_s = att["wall_s"] or 1e-12
    for cat, st in att["stages"].items():
        hit = f"{st['hit_rate']:.0%}" if st["hit_rate"] is not None else "-"
        lines.append(f"| {cat} | {st['count']} | {st['total_s']:.4f} "
                     f"| {st['self_s']:.4f} "
                     f"| {100.0 * st['self_s'] / wall_s:.1f}% | {hit} |")
    if att["workloads"]:
        lines.append("")
        lines.append("| workload | top stages (self s) |")
        lines.append("|---|---|")
        for workload, per in att["workloads"].items():
            top = ", ".join(f"{cat} {s:.4f}" for cat, s in
                            list(per.items())[:4])
            lines.append(f"| {workload} | {top} |")
    lines.append("")
    lines.append(f"spans {att['n_spans']} · wall {att['wall_s']:.4f}s · "
                 f"attributed {att['attributed_s']:.4f}s "
                 f"({att['coverage']:.1%})")
    return "\n".join(lines)
