"""Span tracer: nested wall-clock spans with cross-executor propagation.

One process-global :class:`Tracer` (installed with :func:`enable`, removed
with :func:`disable`) collects finished spans as plain dicts.  Everything
is stdlib — ``contextvars`` carries the active span across call frames,
``threading`` guards the finished-span list, ``time`` supplies the clock.

Design rules, in order of importance:

* **Off is free.**  The module-global ``_tracer`` is the single switch:
  :func:`span` reads it once and hands back the shared :data:`NULL_SPAN`
  when tracing is off, so a hot loop pays one global read + one function
  call per would-be span and allocates nothing.  Call sites that sit on
  gated benchmark paths check ``obs.tracer() is None`` themselves and
  skip even the keyword-argument packing.
* **Propagation is explicit.**  ``contextvars`` does not follow
  ``ThreadPoolExecutor.submit``, so fan-out code captures
  :func:`current` (a :class:`TraceContext`) before submitting and wraps
  the worker body in :func:`attach`.  The same :class:`TraceContext` is
  a frozen two-string dataclass, so it pickles into
  ``executor="process"`` worker chunks unchanged; workers run their own
  :class:`Tracer`, :meth:`Tracer.drain` the finished spans, and ship
  them back for :func:`ingest` — span ids are prefixed with the owning
  pid, so worker spans parent into the coordinator's tree without
  collisions.
* **Clocks compose.**  Spans are timed with ``perf_counter_ns`` (never
  goes backwards) and exported on the unix epoch via a per-tracer
  offset captured at construction, so spans from different processes on
  one machine land on one consistent timeline.
* **Memory is bounded.**  ``max_spans`` caps the finished list; further
  spans are counted in ``dropped`` instead of growing the buffer (the
  DSE daemon additionally drains each request's spans into its own ring
  buffer the moment the request finishes).
"""
from __future__ import annotations

import contextvars
import dataclasses
import itertools
import os
import threading
import time
import uuid
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

_current: contextvars.ContextVar[Optional["TraceContext"]] = \
    contextvars.ContextVar("eva_cim_trace_ctx", default=None)

_tracer: Optional["Tracer"] = None     # module-global on/off switch


@dataclasses.dataclass(frozen=True)
class TraceContext:
    """The propagation handle: which trace + which span is "current".

    Frozen, two strings — safe to capture before a thread-pool fan-out
    and to pickle into a spawned ``executor="process"`` worker."""
    trace_id: str
    span_id: str


class _NullSpan:
    """The shared do-nothing span handed out while tracing is off."""

    __slots__ = ()
    trace_id = None
    span_id = None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Span:
    """One in-flight span; finished spans live on as plain dicts.

    Use as a context manager — ``__enter__`` stamps the start time and
    makes this span the :func:`current` context, ``__exit__`` restores
    the parent and hands the finished record to the tracer.  ``set``
    attaches attributes at any point before exit (it only touches this
    span's own dict, so it is safe under any caller-held lock)."""

    __slots__ = ("_tracer", "name", "cat", "trace_id", "span_id",
                 "parent_id", "attrs", "_t0", "_token")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 trace_id: str, span_id: str, parent_id: Optional[str],
                 attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.attrs = attrs
        self._t0 = 0
        self._token: Optional[contextvars.Token] = None

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self._token = _current.set(TraceContext(self.trace_id, self.span_id))
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        dur_ns = time.perf_counter_ns() - self._t0
        if self._token is not None:
            _current.reset(self._token)
            self._token = None
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        self._tracer._finish(self, dur_ns)
        return False


class Tracer:
    """Collector of finished spans + counter samples for one process."""

    def __init__(self, name: str = "eva-cim", max_spans: int = 200_000):
        self.name = name
        self.pid = os.getpid()
        self.max_spans = max_spans
        # maps perf_counter_ns() readings onto the unix epoch, so spans
        # from different processes share one timeline
        self._epoch_ns = time.time_ns() - time.perf_counter_ns()
        self._seq = itertools.count()         # next() is atomic in CPython
        self._lock = threading.Lock()
        self._spans: List[Dict] = []          # lint: guarded-by(_lock)
        self._samples: List[Dict] = []        # lint: guarded-by(_lock)
        self.dropped = 0                      # lint: guarded-by(_lock)

    # ------------------------------------------------------------- spans
    def _new_id(self) -> str:
        return f"{self.pid:x}.{next(self._seq):x}"

    def span(self, name: str, cat: str = "misc", **attrs) -> Span:
        """A new span under the current context (a fresh root trace when
        there is none)."""
        ctx = _current.get()
        if ctx is None:
            trace_id: str = uuid.uuid4().hex[:16]
            parent: Optional[str] = None
        else:
            trace_id, parent = ctx.trace_id, ctx.span_id
        return Span(self, name, cat, trace_id, self._new_id(), parent, attrs)

    def _finish(self, span: Span, dur_ns: int) -> None:
        thread = threading.current_thread()
        rec = {"name": span.name, "cat": span.cat,
               "trace_id": span.trace_id, "span_id": span.span_id,
               "parent_id": span.parent_id,
               "ts_ns": span._t0 + self._epoch_ns, "dur_ns": dur_ns,
               "pid": self.pid, "tid": thread.ident, "thread": thread.name,
               "attrs": dict(span.attrs)}
        with self._lock:
            if len(self._spans) >= self.max_spans:
                self.dropped += 1
            else:
                self._spans.append(rec)

    # ----------------------------------------------------------- counters
    def counter(self, name: str, value: float) -> None:
        """Record one counter sample (a Chrome ``C`` event on export)."""
        sample = {"name": name, "value": float(value),
                  "ts_ns": time.perf_counter_ns() + self._epoch_ns,
                  "pid": self.pid}
        with self._lock:
            if len(self._samples) < self.max_spans:
                self._samples.append(sample)

    # ------------------------------------------------------------- access
    def spans(self) -> List[Dict]:
        with self._lock:
            return list(self._spans)

    def counters(self) -> List[Dict]:
        with self._lock:
            return list(self._samples)

    def ingest(self, spans: Iterable[Dict],
               samples: Iterable[Dict] = ()) -> None:
        """Adopt finished spans shipped from another tracer (typically a
        process-pool worker's :meth:`drain`) — already absolute-timed and
        pid-stamped, so they merge without translation."""
        spans, samples = list(spans), list(samples)
        with self._lock:
            self._spans.extend(spans)
            self._samples.extend(samples)

    def drain(self) -> Tuple[List[Dict], List[Dict]]:
        """Remove and return everything collected so far."""
        with self._lock:
            spans, samples = self._spans, self._samples
            self._spans = []
            self._samples = []
            return spans, samples

    def take(self, trace_id: str) -> List[Dict]:
        """Remove and return the finished spans of one trace (the DSE
        daemon calls this per request to keep the tracer's buffer from
        accumulating across its lifetime)."""
        with self._lock:
            taken = [s for s in self._spans if s["trace_id"] == trace_id]
            self._spans = [s for s in self._spans
                           if s["trace_id"] != trace_id]
        return taken

    # ------------------------------------------------------------ exports
    def export_chrome(self, path) -> int:
        """Write a Chrome trace-event JSON file (Perfetto-loadable);
        returns the number of span events written."""
        from repro.obs import export
        return export.export_chrome(self.spans(), self.counters(), path)

    def export_ndjson(self, path) -> int:
        from repro.obs import export
        return export.export_ndjson(self.spans(), path)

    def stage_attribution(self) -> Dict:
        from repro.obs import export
        return export.stage_attribution(self.spans())


# ======================================================================
# Module-level switch + helpers (the API call sites actually use)
# ======================================================================
def tracer() -> Optional[Tracer]:
    """The installed tracer, or ``None`` when tracing is off — the one
    attribute read hot loops are allowed to pay."""
    return _tracer


def active() -> bool:
    return _tracer is not None


def enable(t: Optional[Tracer] = None) -> Tracer:
    """Install (or keep) the process-global tracer and return it."""
    global _tracer
    if t is not None:
        _tracer = t
    elif _tracer is None:
        _tracer = Tracer()
    return _tracer


def disable() -> None:
    global _tracer
    _tracer = None


def span(name: str, cat: str = "misc", **attrs):
    """A span under the current context — :data:`NULL_SPAN` when off."""
    t = _tracer
    if t is None:
        return NULL_SPAN
    return t.span(name, cat, **attrs)


def counter(name: str, value: float) -> None:
    t = _tracer
    if t is not None:
        t.counter(name, value)


def current() -> Optional[TraceContext]:
    """The pickle-able propagation handle for the active span (``None``
    when tracing is off or no span is open)."""
    if _tracer is None:
        return None
    return _current.get()


class _Attach:
    __slots__ = ("_ctx", "_token")

    def __init__(self, ctx: Optional[TraceContext]):
        self._ctx = ctx
        self._token: Optional[contextvars.Token] = None

    def __enter__(self) -> None:
        if self._ctx is not None:
            self._token = _current.set(self._ctx)

    def __exit__(self, *exc) -> bool:
        if self._token is not None:
            _current.reset(self._token)
            self._token = None
        return False


def attach(ctx: Optional[TraceContext]) -> _Attach:
    """Re-establish a captured :class:`TraceContext` in another thread or
    process: spans opened inside parent under ``ctx``'s span.  ``None``
    (tracing was off at capture time) makes this a no-op."""
    return _Attach(ctx)


def ingest(spans: Sequence[Dict], samples: Sequence[Dict] = ()) -> None:
    """Adopt worker-shipped spans into the installed tracer, if any."""
    t = _tracer
    if t is not None and (spans or samples):
        t.ingest(spans, samples)
