"""AdamW with bf16 params + fp32 moments, global-norm clipping, and a
cosine-with-warmup schedule. Written against raw pytrees (no optax dep)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def init_moments(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params)}


def lr_schedule(tc: TrainConfig, step):
    step = step.astype(jnp.float32)
    warm = tc.learning_rate * (step + 1) / max(tc.warmup_steps, 1)
    prog = jnp.clip((step - tc.warmup_steps) /
                    max(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * tc.learning_rate * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < tc.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(params, grads, moments, step, tc: TrainConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    grads, gn = clip_by_global_norm(grads, tc.grad_clip)
    lr = lr_schedule(tc, step)
    b1, b2 = tc.beta1, tc.beta2
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + tc.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, params, grads, moments["m"], moments["v"])
    new_p = jax.tree_util.tree_map(lambda t3: t3[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t3: t3[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t3: t3[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gn, "lr": lr}
