"""Gradient compression for the DP all-reduce (distributed-optimization trick).

With ``jax.jit``+GSPMD the gradient all-reduce is implicit, so compression is
expressed as a *cast point*: gradients are rounded to the compressed dtype
before the optimizer (bf16) or quantized to int8 with error feedback (the
residual is carried in the train state). On real multi-pod meshes this halves
(bf16) or quarters (int8) the bytes crossing the DCI/ICI for the gradient
reduction — the collective term of the roofline.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> Any:
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def compress_grads(grads, method: str, error_fb: Optional[Any] = None
                   ) -> Tuple[Any, Optional[Any]]:
    if method == "none":
        return grads, error_fb
    if method == "bf16":
        return jax.tree_util.tree_map(lambda g: g.astype(jnp.bfloat16), grads), error_fb

    if method == "int8_ef":
        def one(g, e):
            g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
            scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
            deq = q.astype(jnp.float32) * scale
            return deq.astype(g.dtype), (g32 - deq).astype(jnp.bfloat16)

        out = jax.tree_util.tree_map(one, grads, error_fb)
        newg = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        newe = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return newg, newe
    raise ValueError(f"unknown compression {method!r}")
