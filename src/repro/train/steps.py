"""train_step / serve_step factories (the functions the dry-run lowers).

``make_train_step(cfg, tc)`` returns ``step(state, batch) -> (state, metrics)``
with AdamW, remat, optional microbatch gradient accumulation and gradient
compression. ``make_prefill_step`` / ``make_decode_step`` are the serving
counterparts.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.inputs import prefix_len
from repro.models.transformer import forward_decode, forward_full, lm_loss
from repro.optim import adamw, compression


def init_train_state(rng, cfg: ModelConfig) -> Dict[str, Any]:
    from repro.models.transformer import init_params
    params = init_params(rng, cfg)
    state = {
        "params": params,
        "opt": adamw.init_moments(params),
        "step": jnp.zeros((), jnp.int32),
    }
    return state


def loss_fn(params, cfg: ModelConfig, batch, *, remat: str = "none"):
    logits, aux, _ = forward_full(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    # next-token shift: predict labels[t] from logits[t-1]; here labels are
    # pre-shifted by the pipeline, so align lengths only (VLM prefix).
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, -labels.shape[1]:]
    loss = lm_loss(logits, labels, cfg.padded_vocab)
    return loss + 0.01 * aux, (loss, aux)


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    def lf(p, b):
        return loss_fn(p, cfg, b, remat=tc.remat)

    def train_step(state, batch):
        params = state["params"]

        if tc.microbatches > 1:
            def micro(batch_slice):
                return jax.grad(lf, has_aux=True)(params, batch_slice)

            def split(x):
                b = x.shape[0]
                mb = tc.microbatches
                return x.reshape(mb, b // mb, *x.shape[1:])

            mb_batch = jax.tree_util.tree_map(split, batch)

            def body(carry, bslice):
                g_acc, l_acc, a_acc = carry
                g, (l, a) = micro(bslice)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l, a_acc + a), None

            zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, aux), _ = jax.lax.scan(
                body, (zeros, jnp.zeros(()), jnp.zeros(())), mb_batch)
            grads = jax.tree_util.tree_map(lambda g: g / tc.microbatches, grads)
            loss, aux = loss / tc.microbatches, aux / tc.microbatches
        else:
            grads, (loss, aux) = jax.grad(lf, has_aux=True)(params, batch)

        efb = state.get("error_fb")
        grads, efb = compression.compress_grads(grads, tc.grad_compression, efb)
        new_params, new_opt, om = adamw.adamw_update(
            params, grads, state["opt"], state["step"], tc)
        new_state = dict(state, params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        if efb is not None:
            new_state["error_fb"] = efb
        metrics = {"loss": loss, "aux_loss": aux, **om}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    def prefill_step(params, batch):
        logits, _, cache = forward_full(params, cfg, batch, collect_cache=True,
                                        cache_len=cache_len)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, token, cache, cur_pos):
        logits, cache = forward_decode(params, cfg, token, cache, cur_pos)
        next_tok = jnp.argmax(logits[:, -1], axis=-1, keepdims=True).astype(jnp.int32)
        return next_tok, cache

    return decode_step
