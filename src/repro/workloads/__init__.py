"""The paper's 17 benchmark applications (Table IV) as traceable JAX programs.

Every workload module exposes ``build(scale=1) -> (fn, args)`` with
deterministic inputs; ``fn(*args)`` must trace through the Eva-CiM VM
(``repro.core.trace_program``).  Sizes are chosen so a full trace lands in
the 10^4–10^5 instruction range — the same order as the paper's LCS
validation trace ("around 3000 instructions") scaled to exercise the cache
hierarchy.  Documented kernel reductions (DESIGN.md §2): M2D -> IDCT +
motion compensation; h264ref -> SAD motion search; mcf -> Bellman-Ford
edge relaxation on the min-cost network; hmmer -> Viterbi recursion.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.workloads import graph, ml, spec, strings, media

WORKLOADS: Dict[str, Callable] = {
    # machine learning
    "NB": ml.build_nb,
    "DT": ml.build_dt,
    "SVM": ml.build_svm,
    "LiR": ml.build_lir,
    "KM": ml.build_km,
    # string processing
    "LCS": strings.build_lcs,
    # multimedia
    "M2D": media.build_m2d,
    # graph processing
    "BFS": graph.build_bfs,
    "DFS": graph.build_dfs,
    "BC": graph.build_bc,
    "SSSP": graph.build_sssp,
    "CCOMP": graph.build_ccomp,
    "PRANK": graph.build_prank,
    # SPEC 2006 kernels
    "astar": spec.build_astar,
    "h264ref": spec.build_h264ref,
    "hmmer": spec.build_hmmer,
    "mcf": spec.build_mcf,
}

CATEGORY = {
    "NB": "ml", "DT": "ml", "SVM": "ml", "LiR": "ml", "KM": "ml",
    "LCS": "string", "M2D": "media",
    "BFS": "graph", "DFS": "graph", "BC": "graph", "SSSP": "graph",
    "CCOMP": "graph", "PRANK": "graph",
    "astar": "spec", "h264ref": "spec", "hmmer": "spec", "mcf": "spec",
}


def build(name: str, scale: int = 1):
    return WORKLOADS[name](scale)
