"""Graph-processing benchmarks (Table IV): BFS, DFS, BC, SSSP, CCOMP, PRANK.

Graphs are small deterministic Erdős–Rényi instances; dense adjacency for
the level-synchronous algorithms (bitwise and/or — the CiM-native form) and
edge lists for the pointer-chasing ones (DFS, mcf-style relaxation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

INF = 10 ** 6


def _graph(n: int, p: float, seed: int, weighted: bool = False):
    r = np.random.default_rng(seed)
    adj = (r.random((n, n)) < p).astype(np.int32)
    np.fill_diagonal(adj, 0)
    adj = np.maximum(adj, adj.T)                       # undirected
    if weighted:
        w = r.integers(1, 16, (n, n)).astype(np.int32)
        w = np.where(adj > 0, w, INF)
        np.fill_diagonal(w, 0)
        return adj, w
    return adj


# ----------------------------------------------------------------- BFS
def build_bfs(scale: int = 1):
    """Level-synchronous BFS over a boolean frontier: next = (adj AND
    frontier) OR-reduced, masked by ~visited — pure bitwise CiM ops."""
    n = 20 * scale
    adj = jnp.asarray(_graph(n, 0.15, 7))

    def bfs(adj):
        frontier0 = jnp.zeros((n,), jnp.int32).at[0].set(1)
        visited0 = frontier0
        depth0 = jnp.full((n,), -1, jnp.int32).at[0].set(0)

        def step(state, d):
            frontier, visited, depth = state
            reach = jnp.sum(adj & frontier[:, None], axis=0)   # and + or-like add
            nxt = ((reach > 0).astype(jnp.int32)) & (1 - visited)
            visited = visited | nxt
            depth = jnp.where((nxt > 0) & (depth < 0), d + 1, depth)
            return (nxt, visited, depth), None

        (f, v, depth), _ = jax.lax.scan(step, (frontier0, visited0, depth0),
                                        jnp.arange(8, dtype=jnp.int32))
        return depth, jnp.sum(v)

    return bfs, (adj,)


# ----------------------------------------------------------------- DFS
def build_dfs(scale: int = 1):
    """Iterative DFS with an explicit stack (pointer chasing: gathers and
    dynamic stack updates — the paper's least CiM-favorable pattern)."""
    n = 12 * scale
    adj = np.asarray(_graph(n, 0.2, 8))
    # padded adjacency lists
    deg = adj.sum(1)
    max_deg = int(deg.max())
    nbrs = np.full((n, max_deg), -1, np.int32)
    for u in range(n):
        vs = np.nonzero(adj[u])[0]
        nbrs[u, :len(vs)] = vs
    nbrs = jnp.asarray(nbrs)

    def dfs(nbrs):
        stack0 = jnp.full((4 * n,), -1, jnp.int32).at[0].set(0)
        state0 = (stack0, jnp.int32(1), jnp.zeros((n,), jnp.int32),
                  jnp.int32(0))

        def cond(s):
            return s[1] > 0

        def body(s):
            stack, top, visited, order = s
            u = stack[top - 1]
            top = top - 1
            seen = visited[u] > 0
            visited = visited.at[u].set(1)
            order = order + jnp.where(seen, 0, 1)

            def push(carry, v):
                stack, top = carry
                ok = (v >= 0) & (visited[v] == 0) & ~seen
                stack = jax.lax.dynamic_update_slice(
                    stack, jnp.where(ok, v, stack[top])[None], (top,))
                return (stack, top + ok.astype(jnp.int32)), None
            (stack, top), _ = jax.lax.scan(push, (stack, top), nbrs[u])
            return (stack, top, visited, order)

        stack, top, visited, order = jax.lax.while_loop(cond, body, state0)
        return order, visited

    return dfs, (nbrs,)


# ----------------------------------------------------------------- BC
def build_bc(scale: int = 1):
    """Betweenness centrality (Brandes, single source): BFS counting
    shortest paths, then reverse dependency accumulation (float div/mul)."""
    n = 10 * scale
    adj_np = _graph(n, 0.25, 9)
    adj = jnp.asarray(adj_np)
    MAXD = 6

    def bc(adj):
        adjf = adj.astype(jnp.float32)
        dist0 = jnp.full((n,), -1, jnp.int32).at[0].set(0)
        sigma0 = jnp.zeros((n,), jnp.float32).at[0].set(1.0)

        def fwd(state, d):
            dist, sigma = state
            frontier = (dist == d).astype(jnp.float32)
            contrib = adjf.T @ (sigma * frontier)          # path counts
            new = (dist < 0) & (contrib > 0)
            dist = jnp.where(new, d + 1, dist)
            sigma = sigma + jnp.where(new, contrib, 0.0)
            return (dist, sigma), None
        (dist, sigma), _ = jax.lax.scan(fwd, (dist0, sigma0),
                                        jnp.arange(MAXD, dtype=jnp.int32))

        delta0 = jnp.zeros((n,), jnp.float32)

        def bwd(delta, d_rev):
            d = MAXD - 1 - d_rev
            on_level = (dist == (d + 1)).astype(jnp.float32)
            coeff = jnp.where(sigma > 0, (1.0 + delta) / jnp.maximum(sigma, 1e-9), 0.0)
            pred_mask = (dist == d).astype(jnp.float32)
            acc = adjf @ (coeff * on_level)
            delta = delta + pred_mask * sigma * acc
            return delta, None
        delta, _ = jax.lax.scan(bwd, delta0, jnp.arange(MAXD, dtype=jnp.int32))
        return delta

    return bc, (adj,)


# ----------------------------------------------------------------- SSSP
def build_sssp(scale: int = 1):
    """Bellman-Ford via min-plus relaxation (integer add + min: the
    CiM-supported op pair — paper reports SSSP among the higher MACRs)."""
    n = 14 * scale
    _, w = _graph(n, 0.25, 10, weighted=True)
    w = jnp.asarray(w)

    def sssp(w):
        dist0 = jnp.full((n,), INF, jnp.int32).at[0].set(0)

        def relax(dist, _):
            cand = jnp.min(dist[:, None] + w, axis=0)      # add + min chains
            return jnp.minimum(dist, cand), None
        dist, _ = jax.lax.scan(relax, dist0, None, length=6)
        return dist

    return sssp, (w,)


# ----------------------------------------------------------------- CCOMP
def build_ccomp(scale: int = 1):
    """Connected components by label propagation (integer min over
    neighbors)."""
    n = 20 * scale
    adj = jnp.asarray(_graph(n, 0.08, 11))

    def ccomp(adj):
        labels0 = jnp.arange(n, dtype=jnp.int32)
        big = jnp.int32(INF)

        def prop(labels, _):
            nbr = jnp.where(adj > 0, labels[None, :], big)
            best = jnp.min(nbr, axis=1)
            return jnp.minimum(labels, best), None
        labels, _ = jax.lax.scan(prop, labels0, None, length=6)
        return labels

    return ccomp, (adj,)


# ----------------------------------------------------------------- PRANK
def build_prank(scale: int = 1):
    """PageRank power iteration (float mul/add matvec + damping)."""
    n = 14 * scale
    adj_np = _graph(n, 0.2, 12)
    deg = np.maximum(adj_np.sum(1), 1)
    P = (adj_np / deg[:, None]).astype(np.float32)
    P = jnp.asarray(P)

    def prank(P):
        r0 = jnp.full((n,), 1.0 / n, jnp.float32)

        def it(rv, _):
            rv2 = 0.85 * (P.T @ rv) + 0.15 / n
            return rv2, jnp.sum(jnp.abs(rv2 - rv))
        rv, deltas = jax.lax.scan(it, r0, None, length=5)
        return rv, deltas

    return prank, (P,)
