"""Multimedia (Table IV): MPEG-2 decode core — 8x8 inverse DCT + motion
compensation (documented kernel reduction, DESIGN.md §2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _idct_matrix() -> np.ndarray:
    n = 8
    C = np.zeros((n, n), np.float32)
    for k in range(n):
        for i in range(n):
            a = np.sqrt(1.0 / n) if k == 0 else np.sqrt(2.0 / n)
            C[k, i] = a * np.cos((2 * i + 1) * k * np.pi / (2 * n))
    return C


def build_m2d(scale: int = 1):
    """Per 8x8 block: dequant (int mul), 2D IDCT (two 8x8 matmuls),
    motion compensation (reference block add), saturate to [0, 255]."""
    r = np.random.default_rng(6)
    B = 2 * scale                                   # blocks
    coeffs = jnp.asarray(r.integers(-32, 32, (B, 8, 8)), jnp.int32)
    quant = jnp.asarray(r.integers(1, 8, (8, 8)), jnp.int32)
    ref = jnp.asarray(r.integers(0, 255, (B, 8, 8)), jnp.int32)
    C = jnp.asarray(_idct_matrix())

    def m2d(coeffs, quant, ref):
        def one_block(cf, rf):
            deq = (cf * quant).astype(jnp.float32)
            pix = C.T @ deq @ C                       # 2D IDCT
            out = pix.astype(jnp.int32) + rf          # motion compensation
            return jnp.clip(out, 0, 255)
        blocks = jax.vmap(one_block)(coeffs, ref)
        return blocks, jnp.sum(blocks)

    return m2d, (coeffs, quant, ref)
