"""Machine-learning benchmarks (paper Table IV): NB, DT, SVM, LiR, KM."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


# ----------------------------------------------------------------- NB
def build_nb(scale: int = 1):
    """Categorical naive Bayes inference: integer log-likelihood table
    lookups accumulated per class (gather + add chains)."""
    r = _rng(0)
    N, F, C, V = 8 * scale, 8, 4, 4
    x = jnp.asarray(r.integers(0, V, (N, F)), jnp.int32)
    # fixed-point log-likelihoods (scaled ints — integer adds are CiM ops)
    table = jnp.asarray(r.integers(-64, 0, (C, F, V)), jnp.int32)
    prior = jnp.asarray(r.integers(-16, 0, (C,)), jnp.int32)

    def nb(x, table, prior):
        def score_one(xi):
            def per_class(c_tab):
                # sum_f table[f, x_f]
                vals = jax.vmap(lambda t, xf: t[xf])(c_tab, xi)
                return jnp.sum(vals)
            scores = jax.vmap(per_class)(table) + prior
            return jnp.argmax(scores)
        return jax.vmap(score_one)(x)

    return nb, (x, table, prior)


# ----------------------------------------------------------------- DT
def build_dt(scale: int = 1):
    """Decision-tree inference: depth-8 complete tree walked per sample
    (gather feature -> compare threshold -> branch index arithmetic)."""
    r = _rng(1)
    N, F, DEPTH = 16 * scale, 8, 8
    n_nodes = 2 ** DEPTH
    x = jnp.asarray(r.integers(0, 256, (N, F)), jnp.int32)
    feat = jnp.asarray(r.integers(0, F, (n_nodes,)), jnp.int32)
    thresh = jnp.asarray(r.integers(0, 256, (n_nodes,)), jnp.int32)

    def dt(x, feat, thresh):
        def walk(xi):
            def step(node, _):
                f = feat[node]
                t = thresh[node]
                go_right = xi[f] > t
                node = 2 * node + 1 + go_right.astype(jnp.int32)
                node = jnp.minimum(node, n_nodes - 1)
                return node, None
            leaf, _ = jax.lax.scan(step, jnp.int32(0), None, length=DEPTH)
            return leaf & 1                          # class = leaf parity
        return jax.vmap(walk)(x)

    return dt, (x, feat, thresh)


# ----------------------------------------------------------------- SVM
def build_svm(scale: int = 1):
    """Linear SVM: inference scores + one hinge-loss subgradient step."""
    r = _rng(2)
    N, F = 12 * scale, 12
    X = jnp.asarray(r.normal(size=(N, F)), jnp.float32)
    y = jnp.asarray(r.choice([-1.0, 1.0], N), jnp.float32)
    w = jnp.asarray(r.normal(size=(F,)) * 0.1, jnp.float32)

    def svm(X, y, w):
        scores = X @ w                                  # (N,)
        margin = y * scores
        active = (margin < 1.0).astype(jnp.float32)     # hinge subgradient
        grad = -(X.T @ (active * y)) / N + 0.01 * w
        w2 = w - 0.1 * grad
        preds = jnp.sign(X @ w2)
        acc_n = jnp.sum((preds == y).astype(jnp.int32))
        return w2, acc_n

    return svm, (X, y, w)


# ----------------------------------------------------------------- LiR
def build_lir(scale: int = 1):
    """Linear regression: 4 full-batch gradient-descent steps."""
    r = _rng(3)
    N, F, STEPS = 12 * scale, 8, 4
    X = jnp.asarray(r.normal(size=(N, F)), jnp.float32)
    yv = jnp.asarray(r.normal(size=(N,)), jnp.float32)
    w0 = jnp.zeros((F,), jnp.float32)

    def lir(X, yv, w0):
        def step(w, _):
            err = X @ w - yv
            grad = X.T @ err / N
            return w - 0.05 * grad, jnp.sum(err * err)
        w, losses = jax.lax.scan(step, w0, None, length=STEPS)
        return w, losses

    return lir, (X, yv, w0)


# ----------------------------------------------------------------- KM
def build_km(scale: int = 1):
    """K-means: 3 Lloyd iterations (distances, argmin, centroid update)."""
    r = _rng(4)
    N, D, K, ITERS = 24 * scale, 4, 4, 3
    pts = jnp.asarray(r.normal(size=(N, D)), jnp.float32)
    cent0 = jnp.asarray(r.normal(size=(K, D)), jnp.float32)

    def km(pts, cent0):
        def lloyd(cent, _):
            diff = pts[:, None, :] - cent[None, :, :]    # (N,K,D) sub
            d2 = jnp.sum(diff * diff, axis=-1)           # mul + add chains
            assign = jnp.argmin(d2, axis=-1)             # (N,)
            onehot = (assign[:, None] == jnp.arange(K)[None, :]).astype(jnp.float32)
            counts = jnp.sum(onehot, axis=0)             # (K,)
            sums = onehot.T @ pts                        # (K,D)
            new = sums / jnp.maximum(counts, 1.0)[:, None]
            return new, jnp.sum(d2 * onehot)
        cent, inertia = jax.lax.scan(lloyd, cent0, None, length=ITERS)
        return cent, inertia

    return km, (pts, cent0)
