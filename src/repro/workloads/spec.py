"""SPEC 2006 kernels (Table IV): astar, h264ref, hmmer, mcf — each reduced
to its documented hot loop (DESIGN.md §2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

INF = 10 ** 6


# ---------------------------------------------------------------- astar
def build_astar(scale: int = 1):
    """Grid A*: open-set relaxation with f = g + h (Manhattan heuristic).
    argmin open-node select + neighbor relax per step."""
    r = np.random.default_rng(13)
    n = 8 * scale
    cost = jnp.asarray(r.integers(1, 8, (n, n)), jnp.int32)
    STEPS = 3 * n

    def astar(cost):
        N = n * n
        gx = jnp.arange(N, dtype=jnp.int32) // n
        gy = jnp.arange(N, dtype=jnp.int32) % n
        h = (n - 1 - gx) + (n - 1 - gy)                  # Manhattan to corner
        g0 = jnp.full((N,), INF, jnp.int32).at[0].set(0)
        open0 = jnp.zeros((N,), jnp.int32).at[0].set(1)
        closed0 = jnp.zeros((N,), jnp.int32)

        def step(state, _):
            g, open_, closed = state
            f = jnp.where(open_ > 0, g + h, INF)
            u = jnp.argmin(f)                            # cheapest open node
            open_ = open_.at[u].set(0)
            closed = closed.at[u].set(1)
            ux, uy = u // n, u % n
            for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                vx, vy = ux + dx, uy + dy
                ok = (vx >= 0) & (vx < n) & (vy >= 0) & (vy < n)
                v = jnp.clip(vx * n + vy, 0, N - 1)
                cand = g[u] + cost[jnp.clip(vx, 0, n - 1), jnp.clip(vy, 0, n - 1)]
                better = ok & (cand < g[v]) & (closed[v] == 0)
                g = g.at[v].set(jnp.where(better, cand, g[v]))
                open_ = open_.at[v].set(jnp.where(better, 1, open_[v]))
            return (g, open_, closed), None

        (g, open_, closed), _ = jax.lax.scan(step, (g0, open0, closed0),
                                             None, length=STEPS)
        return g[N - 1], g

    return astar, (cost,)


# -------------------------------------------------------------- h264ref
def build_h264ref(scale: int = 1):
    """Motion-estimation SAD search: sum of absolute differences of the
    current 8x8 block against every candidate in a search window (integer
    sub/abs/add chains — the encoder's dominant kernel)."""
    r = np.random.default_rng(14)
    B, W = 8, 6 * scale                                 # block, window
    cur = jnp.asarray(r.integers(0, 255, (B, B)), jnp.int32)
    ref = jnp.asarray(r.integers(0, 255, (B + W, B + W)), jnp.int32)

    def h264(cur, ref):
        def sad_at(dy, dx):
            win = jax.lax.dynamic_slice(ref, (dy, dx), (B, B))
            return jnp.sum(jnp.abs(win - cur))
        offs = jnp.arange(W, dtype=jnp.int32)
        sads = jax.vmap(lambda dy: jax.vmap(lambda dx: sad_at(dy, dx))(offs))(offs)
        best = jnp.argmin(sads.reshape(-1))
        return best, sads

    return h264, (cur, ref)


# ---------------------------------------------------------------- hmmer
def build_hmmer(scale: int = 1):
    """Viterbi recursion of a profile HMM (hmmsearch's P7Viterbi core):
    dp[t,j] = emit[j,obs_t] + max_i(dp[t-1,i] + trans[i,j]) — integer
    add/max in fixed-point, exactly the CiM-supported pair."""
    r = np.random.default_rng(15)
    M, T, A = 8 * scale, 16, 4                         # states, seq len, alphabet
    obs = jnp.asarray(r.integers(0, A, (T,)), jnp.int32)
    emit = jnp.asarray(r.integers(-32, 0, (M, A)), jnp.int32)
    trans = jnp.asarray(r.integers(-16, 0, (M, M)), jnp.int32)

    def hmmer(obs, emit, trans):
        dp0 = emit[:, obs[0]]

        def step(dp, o_t):
            cand = dp[:, None] + trans                  # (M, M) adds
            best = jnp.max(cand, axis=0)                # max chains
            dp2 = best + emit[:, o_t]
            return dp2, jnp.max(dp2)
        dp, path_scores = jax.lax.scan(step, dp0, obs[1:])
        return jnp.max(dp), path_scores

    return hmmer, (obs, emit, trans)


# ------------------------------------------------------------------ mcf
def build_mcf(scale: int = 1):
    """Min-cost-flow price update core (simplified SPFA/Bellman-Ford over
    the residual network's edge list): gather endpoints, relax, scatter —
    pointer-heavy like the real mcf."""
    r = np.random.default_rng(16)
    n, m = 12 * scale, 36 * scale
    src = jnp.asarray(r.integers(0, n, (m,)), jnp.int32)
    dst = jnp.asarray(r.integers(0, n, (m,)), jnp.int32)
    w = jnp.asarray(r.integers(1, 10, (m,)), jnp.int32)

    def mcf(src, dst, w):
        dist0 = jnp.full((n,), INF, jnp.int32).at[0].set(0)

        def relax_round(dist, _):
            def relax_edge(d, e):
                s, t, we = e
                cand = d[s] + we
                better = cand < d[t]
                d = d.at[t].set(jnp.where(better, cand, d[t]))
                return d, better.astype(jnp.int32)
            dist, improved = jax.lax.scan(relax_edge, dist,
                                          (src, dst, w))
            return dist, jnp.sum(improved)
        dist, improvements = jax.lax.scan(relax_round, dist0, None, length=4)
        return dist, improvements

    return mcf, (src, dst, w)
