"""String processing (Table IV): longest common subsequence — the paper's
validation workload (§VI-A compares offload counts on LCS against [23])."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def build_lcs(scale: int = 1):
    """Classic O(n*m) DP:  dp[i,j] = a_i==b_j ? dp[i-1,j-1]+1
                                              : max(dp[i-1,j], dp[i,j-1]).

    Integer adds / max / compares over the DP row — the canonical
    Load-Load-OP-Store workload."""
    r = np.random.default_rng(5)
    n = m = 24 * scale
    a = jnp.asarray(r.integers(0, 4, (n,)), jnp.int32)
    b = jnp.asarray(r.integers(0, 4, (m,)), jnp.int32)

    def lcs(a, b):
        row0 = jnp.zeros((m + 1,), jnp.int32)

        def outer(prev_row, ai):
            def inner(carry, j):
                left = carry                       # dp[i, j-1]
                up = prev_row[j]                   # dp[i-1, j]
                diag = prev_row[j - 1]             # dp[i-1, j-1]
                match = (ai == b[j - 1]).astype(jnp.int32)
                val = jnp.maximum(jnp.maximum(up, left), diag + match)
                return val, val
            _, tail = jax.lax.scan(inner, jnp.int32(0),
                                   jnp.arange(1, m + 1, dtype=jnp.int32))
            row = jnp.concatenate([jnp.zeros((1,), jnp.int32), tail])
            return row, None

        final, _ = jax.lax.scan(outer, row0, a)
        return final[m]

    return lcs, (a, b)
