import sys
import types

import numpy as np
import pytest

# ----------------------------------------------------------------------
# hypothesis compatibility shim: the CI/container image may not ship
# hypothesis.  Property tests then run against a deterministic seeded
# sampler with the same strategy surface (integers / sampled_from / lists),
# so `from hypothesis import given, settings, strategies` keeps working.
# ----------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def _integers(lo, hi):
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    def _sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[int(rng.integers(len(items)))])

    def _lists(elem, min_size=0, max_size=10):
        return _Strategy(lambda rng: [
            elem.sample(rng)
            for _ in range(int(rng.integers(min_size, max_size + 1)))])

    def _given(*strats):
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_max_examples", 20)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    fn(*[s.sample(rng) for s in strats])
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def _settings(max_examples=20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers, _st.sampled_from, _st.lists = _integers, _sampled_from, _lists
    _hyp = types.ModuleType("hypothesis")
    _hyp.given, _hyp.settings, _hyp.strategies = _given, _settings, _st
    _hyp.__stub__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
