"""Differential harness for the accelerated analysis path (PR-7 tentpole).

``repro.core.accel`` re-implements the two numpy hot loops — the cache
replay state machine and Algorithm 1's vectorized placement — as jitted
jax kernels.  The numpy implementations stay in the tree as the
reference oracle, and these tests are the contract that keeps the two
backends interchangeable:

  * random access streams x random geometry *batches* through
    :func:`replay_columns_batch` vs element-exact
    :meth:`CacheHierarchy.replay` — bit-equal level/hit/bank/MSHR
    columns and equal counter dicts (LRU order, MSHR FIFO/merge,
    writeback cascades and all);
  * random programs x geometries x offload configs through the full
    ``trace -> select -> price`` pipeline under ``use_backend("jax")``
    vs numpy — identical candidate tuples, claimed sets, and *exactly*
    equal priced energy/speedup/MACR (the figure artifacts must stay
    byte-identical under ``EVA_CIM_ACCEL=jax``);
  * the Pallas segment-reduce kernels (interpret mode on CPU) vs the
    XLA ``jax.ops`` segment ops they substitute for;
  * the batched ``attach_cache_results_batch`` vs geometry-at-a-time
    numpy attachment.

Strategies stick to the integers/sampled_from/lists surface so the
conftest hypothesis fallback sampler can drive them; geometry parameters
are drawn from a small fixed pool so the jit cache stays bounded (shapes
are padded to powers of two — see ``repro.core.accel.replay``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import accel, trace_program
from repro.core.accel.replay import replay_columns_batch
from repro.core.cache import (CacheConfig, CacheHierarchy, L1_32K, L1_64K,
                              L2_256K, L2_2M, SPM_1M)
from repro.core.offload import OffloadConfig, select_candidates
from repro.core.profiler import profile_system
from repro.core.trace import (attach_cache_results,
                              attach_cache_results_batch, trace_structural)


def _g(sets, assoc, banks, mshrs, name="L1"):
    return CacheConfig(name, sets * 64 * assoc, assoc,
                       banks=banks, mshrs=mshrs)


# small geometries exercise every replacement/merge corner (direct-mapped,
# single-set, one-entry MSHR files) while keeping padded state tiny
GEOMETRIES = (
    (_g(1, 1, 1, 1),),
    (_g(4, 4, 4, 2),),
    (_g(1, 4, 2, 1),),
    (_g(4, 1, 1, 2), _g(4, 4, 4, 2, "L2")),
    (_g(1, 1, 1, 1), _g(4, 1, 2, 1, "L2")),
    (_g(4, 4, 4, 2), _g(4, 4, 1, 2, "L2")),
    (_g(1, 2, 2, 2), _g(1, 4, 4, 1, "L2")),
)
PRESETS = ((L1_32K, L2_256K), (L1_64K, L2_256K), (L1_64K, L2_2M), (SPM_1M,))

_OPS = ("add", "xor", "and", "or", "sub", "max")
_JNP_OP = {"add": "add", "xor": "bitwise_xor", "and": "bitwise_and",
           "or": "bitwise_or", "sub": "subtract", "max": "maximum"}
CFGS = (OffloadConfig(),
        OffloadConfig(cim_levels=("L1",)),
        OffloadConfig(cim_levels=("L2",)))


def _decode_stream(encoded):
    """One int per access: bit 0 is the store flag, the rest the address
    (single-list encoding keeps the strategies stub-compatible)."""
    addrs = np.asarray([v >> 1 for v in encoded], np.int64)
    wr = np.asarray([v & 1 for v in encoded], bool)
    return addrs, wr


def _cand_tuple(c):
    return (c.root_seq, tuple(c.op_seqs), tuple(c.op_classes),
            tuple(c.load_seqs), tuple(c.store_seqs), c.level, c.bank,
            c.moves, c.internal_edges, c.added_loads, c.memval_leaves,
            c.dram_fills)


def _assert_columns_equal(ref_cols, jax_cols):
    for name, a, b in zip(("level", "hit", "bank", "mshr"),
                          ref_cols, jax_cols):
        assert np.array_equal(a, b), name


# ======================================================================
# replay: stream + counters vs the CacheHierarchy oracle  (110 examples)
# ======================================================================
@settings(max_examples=110, deadline=None)
@given(st.lists(st.integers(0, 2 * 26 * 64 - 1), min_size=0, max_size=60),
       st.lists(st.sampled_from(GEOMETRIES), min_size=1, max_size=3))
def test_replay_stream_differential(encoded, geos):
    addrs, wr = _decode_stream(encoded)
    out = replay_columns_batch(addrs, wr, geos)
    assert out is not None and len(out) == len(geos)
    for gi, levels in enumerate(geos):
        hier = CacheHierarchy(levels)
        ref = hier.replay(addrs, wr)
        lvl, hit, bank, mshr, counters = out[gi]
        _assert_columns_equal(ref, (lvl, hit, bank, mshr))
        assert (lvl.dtype, hit.dtype, bank.dtype, mshr.dtype) == \
            (np.int8, np.int8, np.int16, np.bool_)
        assert counters == hier.counters()


# ======================================================================
# end-to-end: select + price under use_backend("jax")  (40 examples)
# ======================================================================
@settings(max_examples=40, deadline=None)
@given(st.integers(4, 24), st.integers(0, 5), st.sampled_from(_OPS),
       st.sampled_from(_OPS), st.sampled_from(GEOMETRIES),
       st.sampled_from(CFGS))
def test_selection_differential(n, seed, op1, op2, geo, cfg):
    if len(geo) == 1 and "L2" in cfg.cim_levels:
        cfg = CFGS[1]          # single-level geometries price L1-CiM only
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.integers(0, 100, (n,)), jnp.int32)
    b = jnp.asarray(r.integers(1, 100, (n,)), jnp.int32)
    f1, f2 = getattr(jnp, _JNP_OP[op1]), getattr(jnp, _JNP_OP[op2])

    def prog(a, b):
        c = f1(a, b)
        d = f2(c, a)
        return jnp.sum(d) + jnp.max(c)

    struct = trace_structural(prog, a, b)
    tr_np = attach_cache_results(struct, geo)
    res_np = select_candidates(tr_np.trace, cfg=cfg)
    rep_np = profile_system(tr_np, cfg, offload=res_np)
    with accel.use_backend("jax"):
        tr_j = attach_cache_results(struct, geo)
        res_j = select_candidates(tr_j.trace, cfg=cfg)
        rep_j = profile_system(tr_j, cfg, offload=res_j)

    for col in ("level", "hit", "bank", "mshr"):
        assert np.array_equal(getattr(tr_np.trace, col),
                              getattr(tr_j.trace, col)), col
    assert tr_np.cache.counters() == tr_j.cache.counters()
    assert [_cand_tuple(c) for c in res_np.candidates] == \
        [_cand_tuple(c) for c in res_j.candidates]
    assert res_np.claimed == res_j.claimed
    # pricing must be EXACTLY equal — the figure artifacts are compared
    # byte-for-byte across backends
    assert rep_np.energy_improvement == rep_j.energy_improvement
    assert rep_np.speedup == rep_j.speedup
    assert rep_np.macr == rep_j.macr


# ======================================================================
# pallas segment kernels vs the XLA ops they replace  (30 examples)
# ======================================================================
@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 70 * 41 - 1), min_size=0, max_size=300),
       st.integers(1, 40))
def test_pallas_segment_ops_match_xla(encoded, n_seg):
    from repro.core.accel import pallas_ops
    ids = jnp.asarray([v % n_seg for v in encoded], jnp.int32)
    vals = jnp.asarray([v // 41 - 10 for v in encoded], jnp.int32)
    s_ref = jax.ops.segment_sum(vals, ids, num_segments=n_seg)
    m_ref = jax.ops.segment_max(vals, ids, num_segments=n_seg)
    assert np.array_equal(pallas_ops.segment_sum(vals, ids, n_seg), s_ref)
    assert np.array_equal(pallas_ops.segment_max(vals, ids, n_seg), m_ref)


# ======================================================================
# batched attachment vs geometry-at-a-time numpy  (20 examples)
# ======================================================================
@settings(max_examples=20, deadline=None)
@given(st.integers(4, 24), st.integers(0, 5),
       st.lists(st.sampled_from(GEOMETRIES), min_size=1, max_size=3))
def test_attach_batch_differential(n, seed, geos):
    r = np.random.default_rng(seed + 7)
    a = jnp.asarray(r.integers(0, 64, (n,)), jnp.int32)

    def prog(a):
        return jnp.sum((a * 3) ^ a)

    struct = trace_structural(prog, a)
    with accel.use_backend("jax"):
        batch = attach_cache_results_batch(struct, geos)
    for gi, geo in enumerate(geos):
        ref = attach_cache_results(struct, geo)
        for col in ("level", "hit", "bank", "mshr"):
            assert np.array_equal(getattr(ref.trace, col),
                                  getattr(batch[gi].trace, col)), col
        assert ref.cache.counters() == batch[gi].cache.counters()


# ======================================================================
# deterministic cases
# ======================================================================
def test_nb_fig14_geometries_bit_exact():
    """The fig14 sweep's real workload x cache presets: full pipeline
    equality on the artifact-bearing path (trace columns, counters,
    candidates, and exactly-equal priced reports)."""
    from repro.workloads import build
    fn, args = build("NB")
    struct = trace_structural(fn, *args)
    for geo in PRESETS:
        cfg = OffloadConfig() if len(geo) > 1 \
            else OffloadConfig(cim_levels=("L1",))
        tr_np = attach_cache_results(struct, geo)
        res_np = select_candidates(tr_np.trace, cfg=cfg)
        rep_np = profile_system(tr_np, cfg, offload=res_np)
        with accel.use_backend("jax"):
            tr_j = attach_cache_results(struct, geo)
            res_j = select_candidates(tr_j.trace, cfg=cfg)
            rep_j = profile_system(tr_j, cfg, offload=res_j)
        for col in ("level", "hit", "bank", "mshr"):
            assert np.array_equal(getattr(tr_np.trace, col),
                                  getattr(tr_j.trace, col)), (geo, col)
        assert tr_np.cache.counters() == tr_j.cache.counters()
        assert [_cand_tuple(c) for c in res_np.candidates] == \
            [_cand_tuple(c) for c in res_j.candidates]
        assert rep_np.energy_improvement == rep_j.energy_improvement
        assert rep_np.speedup == rep_j.speedup
        assert rep_np.macr == rep_j.macr


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 24), st.integers(0, 5), st.sampled_from(_OPS),
       st.sampled_from(GEOMETRIES), st.sampled_from(CFGS))
def test_place_candidates_jax_differential(n, seed, op1, geo, cfg):
    """place_candidates_jax vs its numpy twin ``offload._place``, called
    directly on the same structural partition (not through the backend
    switch) — identical candidate tuples in identical order."""
    from repro.core.accel.place import place_candidates_jax
    from repro.core.idg import IDGBuilder
    from repro.core.offload import _partition, _place, build_flow_index

    if len(geo) == 1 and "L2" in cfg.cim_levels:
        cfg = CFGS[1]
    r = np.random.default_rng(seed + 13)
    a = jnp.asarray(r.integers(0, 100, (n,)), jnp.int32)
    b = jnp.asarray(r.integers(1, 100, (n,)), jnp.int32)
    f1 = getattr(jnp, _JNP_OP[op1])

    def prog(a, b):
        c = f1(a, b)
        return jnp.sum(c ^ a) + jnp.max(c)

    struct = trace_structural(prog, a, b)
    ct = attach_cache_results(struct, geo).trace
    part = _partition(ct, IDGBuilder(ct), build_flow_index(ct), cfg)
    with accel.use_backend("numpy"):
        ref = _place(part, ct, cfg)
    got = place_candidates_jax(part, ct, cfg)
    assert got is not None
    assert [_cand_tuple(c) for c in got] == [_cand_tuple(c) for c in ref]


def test_backend_switch():
    """Env-var default, in-process override, and validation."""
    assert accel.backend() in ("numpy", "jax")
    with accel.use_backend("jax"):
        assert accel.enabled()
        with accel.use_backend("numpy"):
            assert not accel.enabled()
        assert accel.enabled()
    with pytest.raises(ValueError):
        accel.set_backend("cuda")
    # numpy backend: the batched entry points decline immediately
    with accel.use_backend("numpy"):
        assert accel.replay_columns(np.zeros(1, np.int64), np.zeros(1, bool),
                                    [PRESETS[0]]) is None


def test_jit_compile_accounting():
    """Replaying an already-compiled shape must not add specializations —
    the service's zero-recompile guarantee hangs off this counter."""
    addrs = (np.arange(40, dtype=np.int64) % 7) * 64
    wr = np.zeros(40, bool)
    geos = [GEOMETRIES[0], GEOMETRIES[3]]
    replay_columns_batch(addrs, wr, geos)
    before = accel.jit_compiles()
    assert before > 0
    out = replay_columns_batch(addrs + 64, ~wr, geos)
    assert out is not None
    assert accel.jit_compiles() == before


def test_replay_overflow_falls_back():
    """Streams beyond the kernel's int32 line budget decline the batch;
    attachment then transparently uses the numpy oracle."""
    addrs = np.asarray([0, 2 ** 40], np.int64)
    assert replay_columns_batch(addrs, np.zeros(2, bool),
                                [GEOMETRIES[0]]) is None

    a = jnp.arange(16, dtype=jnp.int32)
    struct = trace_structural(lambda a: jnp.sum(a + a), a)
    ref = attach_cache_results(struct, PRESETS[0])
    with accel.use_backend("jax"):
        out = attach_cache_results(struct, PRESETS[0])
    for col in ("level", "hit", "bank", "mshr"):
        assert np.array_equal(getattr(ref.trace, col),
                              getattr(out.trace, col))
