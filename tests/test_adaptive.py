"""repro.dse.adaptive: frontier-driven refinement — neighborhood move set,
coarse seeding, cross-round dedup, stability termination, warm-store round
costs, multi-round merge accounting, and frontier parity with the
exhaustive cross-product."""
import dataclasses

import pytest

from repro.dse import (AdaptiveDSE, DSEEngine, SweepResults, SweepSpace,
                       coarse_seed, frontier_stable, neighborhood)
from repro.dse.results import SweepRecord


def _record(i, workload="NB", energy=1.0, speedup=1.0, rnd=0):
    return SweepRecord(
        index=i, workload=workload, cache="32K+256K", cim_levels="L1+L2",
        tech="sram", cim_set="stt", host="A9-1GHz",
        energy_improvement=energy, speedup=speedup, macr=0.1, macr_l1=0.1,
        base_energy_pj=1.0, cim_energy_pj=1.0, base_cycles=1.0,
        cim_cycles=1.0, base_runtime_ms=1.0, cim_runtime_ms=1.0,
        processor_ratio=0.5, cache_ratio=0.5, n_instructions=1,
        n_mem_accesses=1, n_candidates=1, n_cim_ops=1, round=rnd)


class _CountingEngine(DSEEngine):
    """DSEEngine that records every design identity it is asked to price."""

    def __init__(self, **kwargs):
        super().__init__(**kwargs)
        self.priced_keys = []

    def run(self, space):
        points = space.points() if isinstance(space, SweepSpace) else space
        self.priced_keys.extend(p.key for p in points)
        return super().run(space)


# -------------------------------------------------------------- move set
def test_neighborhood_single_axis_moves():
    space = SweepSpace(workloads=("KM",),
                       caches=("32K+256K", "64K+256K", "64K+2M"),
                       cim_levels=("L1_only", "L2_only", "both"),
                       techs=("sram", "fefet"),
                       hosts=("A9-1GHz", "inorder-1GHz"))
    start = next(p for p in space.points()
                 if p.cache.name == "64K+256K" and p.cim_levels == ("L1",)
                 and p.tech == "sram" and p.host.name == "A9-1GHz")
    moves = neighborhood(start, space)
    # every move changes exactly one axis
    for m in moves:
        diffs = sum((m.cache.levels != start.cache.levels,
                     m.cim_levels != start.cim_levels,
                     m.tech != start.tech, m.cim_set != start.cim_set,
                     m.host != start.host))
        assert diffs == 1
    caches = {m.cache.name for m in moves if m.cache != start.cache}
    assert caches == {"32K+256K", "64K+2M"}          # adjacent geometries
    levels = {m.cim_levels for m in moves if m.cim_levels != start.cim_levels}
    assert levels == {("L1", "L2")}                  # strict supersets only
    assert {m.tech for m in moves if m.tech != start.tech} == {"fefet"}
    assert {m.host.name for m in moves
            if m.host != start.host} == {"inorder-1GHz"}
    # edges clamp: first cache has one cache-neighbor, 'both' no superset
    edge = next(p for p in space.points()
                if p.cache.name == "32K+256K" and p.cim_levels == ("L1", "L2"))
    edge_moves = neighborhood(edge, space)
    assert {m.cache.name for m in edge_moves
            if m.cache != edge.cache} == {"64K+256K"}
    assert all(m.cim_levels == edge.cim_levels or set(edge.cim_levels)
               < set(m.cim_levels) for m in edge_moves)


def test_coarse_seed_covers_every_workload_from_the_bottom():
    space = SweepSpace(workloads=("KM", "NB"),
                       caches=("32K+256K", "64K+2M"),
                       cim_levels=("L1_only", "L2_only", "both"),
                       techs=("sram", "fefet"),
                       hosts=("A9-1GHz", "inorder-1GHz"))
    seed = coarse_seed(space)
    assert {p.workload for p in seed} == {"KM", "NB"}
    # minimal level sets only — supersets are reachable, 'both' is not a seed
    assert {p.cim_levels for p in seed} == {("L1",), ("L2",)}
    # first value of every other axis
    assert {p.cache.name for p in seed} == {"32K+256K"}
    assert {p.tech for p in seed} == {"sram"}
    assert {p.host.name for p in seed} == {"A9-1GHz"}
    assert len(seed) == 4


def test_frontier_stable_predicate():
    a = [_record(0, energy=2.0, speedup=1.0), _record(1, energy=1.0,
                                                      speedup=2.0)]
    b = [_record(5, energy=2.0, speedup=1.0), _record(9, energy=1.0,
                                                      speedup=2.0)]
    obj = ("energy_improvement", "speedup")
    assert frontier_stable(a, b, obj)                 # same values, any index
    assert not frontier_stable(None, a, obj)          # no earlier round
    assert not frontier_stable(a, a[:1], obj)
    # a key function distinguishes identically-priced distinct designs
    assert not frontier_stable(a, b, obj, key=lambda r: r.index)


# ------------------------------------------------------- merge accounting
def test_merge_sums_counters_and_reindexes():
    r1 = SweepResults(records=[_record(0), _record(1)],
                      stats={"trace_builds": 2, "offload_builds": 3},
                      elapsed_s=1.0)
    r2 = SweepResults(records=[_record(0, rnd=1)],
                      stats={"trace_builds": 1, "store_l1_hits": 4},
                      elapsed_s=0.5)
    merged = r1.merge(r2)
    assert [r.index for r in merged] == [0, 1, 2]     # contiguous reindex
    assert [r.round for r in merged] == [0, 0, 1]     # provenance survives
    # counters sum over the UNION of keys — nothing silently dropped
    assert merged.stats == {"trace_builds": 3, "offload_builds": 3,
                            "store_l1_hits": 4}
    assert merged.elapsed_s == pytest.approx(1.5)
    # inputs untouched
    assert len(r1) == 2 and r1.stats["trace_builds"] == 2
    # the markdown report gets a real number, never the '?' fallback
    assert "3 trace analyses" in merged.to_markdown()
    assert "?" not in merged.to_markdown().splitlines()[2]


# ------------------------------------------------------------ the driver
_SPACE = SweepSpace(workloads=("NB",),
                    caches=("32K+256K", "64K+256K"),
                    cim_levels=("L1_only", "L2_only", "both"),
                    techs=("sram", "fefet"))


def test_adaptive_never_prices_a_point_twice():
    eng = _CountingEngine()
    result = AdaptiveDSE(_SPACE, engine=eng).run()
    assert len(eng.priced_keys) == len(set(eng.priced_keys))
    assert len(eng.priced_keys) == result.n_priced == len(result.results)
    # record identities are unique too (merge kept every round distinct)
    ids = [(r.workload, r.cache, r.cim_levels, r.tech, r.cim_set, r.host)
           for r in result.results]
    assert len(ids) == len(set(ids))
    # provenance: round tags are monotone over the merged record order
    rounds = [r.round for r in result.results]
    assert rounds == sorted(rounds) and rounds[0] == 0


def test_adaptive_matches_exhaustive_frontier_with_fewer_points():
    def ident(r):
        return (r.workload, r.cache, r.cim_levels, r.tech, r.cim_set, r.host)
    exhaustive = DSEEngine().run(_SPACE)
    ex_front = {ident(r) for r in
                exhaustive.pareto(("energy_improvement", "speedup"))}
    result = AdaptiveDSE(_SPACE, engine=DSEEngine()).run()
    assert {ident(r) for r in result.frontier} == ex_front
    assert result.n_priced < len(_SPACE)
    assert result.space_size == len(_SPACE)
    assert result.savings > 1.0
    md = result.to_markdown()
    assert "round" in md and "Pareto frontier" in md


def test_adaptive_terminates_on_stable_frontier():
    space = SweepSpace(workloads=("NB",),
                       caches=("32K+256K", "64K+256K", "64K+2M"),
                       cim_levels=("L1_only", "L2_only", "both"),
                       techs=("sram", "fefet"))
    result = AdaptiveDSE(space, engine=DSEEngine(), max_rounds=20).run()
    # stopped well short of both the round budget and the full grid ...
    assert len(result.rounds) < 20
    last = result.rounds[-1]
    # ... either because a round moved nothing (stable) or proposed nothing
    assert last.stable or result.n_priced == len(space)
    assert result.n_priced < len(space)
    # rounds after the first reuse the already-built analyses of their
    # neighborhoods where geometry repeats: per-round stats prove the math
    total_builds = sum(r.stats.get("trace_builds", 0) for r in result.rounds)
    priced_keys = {(rec.workload, rec.cache) for rec in result.results}
    assert total_builds == len(priced_keys)
    # max_rounds=0 prices exactly the seed and stops
    seed_only = AdaptiveDSE(space, engine=DSEEngine(), max_rounds=0).run()
    assert len(seed_only.rounds) == 1
    assert seed_only.n_priced == len(coarse_seed(space))


def test_adaptive_rounds_are_free_on_warm_store(tmp_path):
    """An exhaustive sweep warms the persistent store; every adaptive round
    after that — including round 0 — does zero analysis work."""
    DSEEngine(store=tmp_path).run(_SPACE)             # warm the artifacts
    result = AdaptiveDSE(_SPACE, engine=DSEEngine(store=tmp_path)).run()
    for info in result.rounds:
        assert info.stats.get("trace_builds", 0) == 0
        assert info.stats.get("offload_builds", 0) == 0
    assert result.rounds[0].stats.get("store_l1_hits", 0) >= 1
    # and without pre-warming, only round 0 pays for the seed's analyses:
    # later rounds only build when refinement steps onto a NEW geometry
    cold = AdaptiveDSE(_SPACE, engine=DSEEngine()).run()
    assert cold.rounds[0].stats["trace_builds"] >= 1
    for info in cold.rounds[1:]:
        seen_before = {(rec.workload, rec.cache)
                       for rec in cold.results
                       if rec.round < info.round}
        new_geoms = {(rec.workload, rec.cache)
                     for rec in cold.results
                     if rec.round == info.round} - seen_before
        assert info.stats["trace_builds"] == len(new_geoms)


def test_adaptive_respects_explicit_seed_and_universe():
    seed = SweepSpace(workloads=("NB",), caches=("32K+256K",),
                      cim_levels=("both",))
    result = AdaptiveDSE(_SPACE, engine=DSEEngine()).run(seed)
    assert result.results.records[0].cim_levels == "L1+L2"
    # every priced point stays inside the declared universe
    universe = {p.key for p in _SPACE.points()}
    labels = {(r.workload, r.cache, r.cim_levels, r.tech) for r in
              result.results}
    allowed = {(p.workload, p.cache.name, "+".join(p.cim_levels), p.tech)
               for p in _SPACE.points()}
    assert labels <= allowed
    assert len(universe) == len(_SPACE)
    # any out-of-universe seed point fails loudly — a partially valid seed
    # must not silently shrink coverage (workload moves don't exist)
    outside = SweepSpace(workloads=("KM", "NB"))  # KM not in _SPACE
    with pytest.raises(ValueError, match="outside the design space"):
        AdaptiveDSE(_SPACE, engine=DSEEngine()).run(outside)


def test_run_iter_streams_the_same_run():
    """run() is a thin drain of run_iter(): consuming the generator by
    hand must reproduce the drained result exactly — same rounds, same
    frontier, same merged records — with each event carrying the frontier
    as it stood after that round (the DSE service streams these)."""
    space = SweepSpace(workloads=("NB",),
                       caches=("32K+256K", "64K+256K"),
                       cim_levels=("L1_only", "both"),
                       techs=("sram", "fefet"))
    drained = AdaptiveDSE(space, engine=DSEEngine()).run()

    events = list(AdaptiveDSE(space, engine=DSEEngine()).run_iter())
    assert [e.info.round for e in events] == list(range(len(events)))
    # elapsed_s is wall-clock noise; everything else must match round-for-round
    assert [(e.info.round, e.info.n_candidates, e.info.n_priced,
             e.info.frontier_size, e.info.stable) for e in events] == \
        [(r.round, r.n_candidates, r.n_priced, r.frontier_size, r.stable)
         for r in drained.rounds]
    assert [r.config_label for r in events[-1].frontier] == \
        [r.config_label for r in drained.frontier]
    assert [r.energy_improvement for r in events[-1].results] == \
        [r.energy_improvement for r in drained.results]
    # the merged-results object accumulates: earlier events see prefixes
    assert len(events[0].results) <= len(events[-1].results)
    assert events[-1].info.stable or len(events) == 9   # 8 rounds + seed
