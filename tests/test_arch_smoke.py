"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates its family-preserving reduced config and runs one train
step + prefill + decode on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCHS, get_config, reduced_config
from repro.models import inputs as minputs
from repro.models.transformer import init_params
from repro.train import steps

# every test here jit-compiles full (reduced) model architectures — tens of
# seconds of XLA work per arch; the fast CI job skips the module
pytestmark = pytest.mark.slow

ALL_ARCHS = sorted(ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    assert cfg.arch_id == arch
    assert cfg.param_count() > 0
    # assigned table spot-checks
    table = {
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 202048),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 163840),
        "xlstm-125m": (12, 768, 4, 4, 50304),
        "hymba-1.5b": (32, 1600, 25, 5, 32001),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 151936),
        "gemma3-1b": (26, 1152, 4, 1, 262144),
        "yi-34b": (60, 7168, 56, 8, 64000),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 200064),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 256206),
        "pixtral-12b": (40, 5120, 32, 8, 131072),
    }
    L, d, h, kv, vocab = table[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads,
            cfg.n_kv_heads, cfg.vocab_size) == (L, d, h, kv, vocab)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_train_step(arch):
    cfg = reduced_config(arch)
    rng = jax.random.PRNGKey(0)
    state = steps.init_train_state(rng, cfg)
    batch = minputs.make_train_batch(rng, cfg, batch=2, seq_len=32)
    step = jax.jit(steps.make_train_step(cfg, TrainConfig()))
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params keep shapes + stay finite
    for leaf in jax.tree_util.tree_leaves(state["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_prefill_decode(arch):
    cfg = reduced_config(arch)
    rng = jax.random.PRNGKey(0)
    params = init_params(rng, cfg)
    B, S = 2, 32
    batch = minputs.make_train_batch(rng, cfg, batch=B, seq_len=S)
    batch.pop("labels")
    tok, cache = jax.jit(steps.make_prefill_step(cfg, cache_len=S + 4))(params, batch)
    assert tok.shape == (B, 1) and tok.dtype == jnp.int32
    dec = jax.jit(steps.make_decode_step(cfg))
    tok2, cache = dec(params, tok, cache, jnp.asarray(S, jnp.int32))
    assert tok2.shape == (B, 1)
    assert bool(jnp.all((tok2 >= 0) & (tok2 < cfg.padded_vocab)))


def test_train_loss_decreases_on_learnable_data():
    """End-to-end sanity: a tiny model must fit a repetitive stream."""
    cfg = reduced_config("qwen1.5-0.5b")
    rng = jax.random.PRNGKey(0)
    state = steps.init_train_state(rng, cfg)
    tc = TrainConfig(learning_rate=3e-3, total_steps=40, warmup_steps=4)
    step = jax.jit(steps.make_train_step(cfg, tc))
    toks = jnp.tile(jnp.arange(32, dtype=jnp.int32)[None], (4, 1)) % cfg.vocab_size
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    first = last = None
    for _ in range(40):
        state, m = step(state, batch)
        first = float(m["loss"]) if first is None else first
        last = float(m["loss"])
    assert last < first * 0.7, (first, last)
