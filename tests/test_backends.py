"""repro.dse.backends: the analyze -> select -> price protocol — TPU-mode
sweeps through the shared engine, TpuOption axis enumeration, selection
semantics (threshold + VMEM fit), roofline pricing invariants, and
adaptive refinement over the chip/threshold sub-axes."""
import dataclasses
import pickle

import pytest

from repro.dse import (AdaptiveDSE, CimBackend, DSEEngine, SweepSpace,
                       TPU_PRESETS, TpuBackend, TpuOption, parse_bytes,
                       tpu_neighbors)
from repro.dse.backends import (TpuCandidate, TpuSelection,
                                TpuWorkloadAnalysis)

# the two cheapest arch-registry workloads (~1-2s of jaxpr/HLO analysis
# each); the module-scoped engine below amortizes them across all tests
ARCHS2 = ("qwen1.5-0.5b", "xlstm-125m")
KB = 1 << 10


@pytest.fixture(scope="module")
def tpu_engine():
    return DSEEngine(backend=TpuBackend())


# --------------------------------------------------------------- options
def test_tpu_option_of_and_labels():
    opt = TpuOption.of("v5e")
    assert opt.chip == TPU_PRESETS["v5e"]
    assert opt.name == "v5e/thr64K"
    assert TpuOption.of(opt) is opt
    assert TpuOption.of(TPU_PRESETS["v4"]).chip_label == "v4"
    with pytest.raises(KeyError):
        TpuOption.of("v99")
    scaled = TpuOption(TPU_PRESETS["v5e"], 1 << 20, vmem_scale=0.5,
                       hbm_bw_scale=2.0)
    assert scaled.threshold_label == "thr1M"
    assert "vmem0.5" in scaled.chip_label and "bw2" in scaled.chip_label
    chip = scaled.effective_chip()
    assert chip.vmem_bytes == TPU_PRESETS["v5e"].vmem_bytes * 0.5
    assert chip.hbm_bw == TPU_PRESETS["v5e"].hbm_bw * 2.0
    # unscaled options hand back the preset object itself
    assert TpuOption.of("v5p").effective_chip() is TPU_PRESETS["v5p"]


def test_parse_bytes():
    assert parse_bytes("16K") == 1 << 14
    assert parse_bytes("1M") == 1 << 20
    assert parse_bytes("4096") == 4096
    assert parse_bytes(512) == 512


def test_tpu_presets_frozen_hashable():
    assert len({hash(c) for c in TPU_PRESETS.values()}) == 3
    with pytest.raises(dataclasses.FrozenInstanceError):
        TPU_PRESETS["v4"].hbm_bw = 1.0
    # capability-ordered declaration (the adjacency contract)
    peaks = [c.peak_flops_bf16 for c in TPU_PRESETS.values()]
    assert peaks == sorted(peaks)


# ------------------------------------------------------------ enumeration
def test_space_tpu_axis_enumeration():
    tpus = (TpuOption.of("v5e"), TpuOption(TPU_PRESETS["v4"], 32 * KB))
    space = SweepSpace(workloads=ARCHS2, tpus=tpus)
    pts = space.points()
    assert len(pts) == len(space) == 4
    # TPU axis iterates innermost and never splits the per-workload
    # analysis chunk (one jaxpr/HLO pass per workload)
    assert [p.tpu.chip_label for p in pts[:2]] == ["v5e", "v4"]
    assert len({p.analysis_key for p in pts}) == 2
    assert pts[0].analysis_key == ("qwen1.5-0.5b", "tpu")
    # TPU points hash (dedup backbone) and carry the option in key/label
    assert len({hash(p) for p in pts}) == 4
    assert len({p.key for p in pts}) == 4
    assert pts[1].label == "qwen1.5-0.5b/v4/thr32K"
    # CiM spaces are untouched by the new axis default
    cim = SweepSpace(workloads=("KM",))
    assert cim.points()[0].tpu is None


# -------------------------------------------------------------- selection
def _analysis(candidates):
    return TpuWorkloadAnalysis(
        workload="w", batch=2, seq_len=32, flops=1e9,
        total_bytes=sum(c.saved_bytes for c in candidates) * 2 or 1,
        collective_bytes=0.0, hlo_bytes=0.0, n_eqns=9,
        candidates=tuple(candidates))


def test_selection_threshold_and_vmem_fit():
    small = TpuCandidate(n_ops=2, input_bytes=4 * KB, output_bytes=4 * KB,
                         saved_bytes=8 * KB)
    big = TpuCandidate(n_ops=5, input_bytes=64 * KB, output_bytes=64 * KB,
                       saved_bytes=512 * KB)
    an = _analysis([small, big])
    # threshold filters the small chain out
    sel = TpuBackend._select(an, min_saved_bytes=64 * KB, vmem_bytes=1e9)
    assert (sel.n_accepted, sel.saved_bytes) == (1, 512 * KB)
    # zero threshold accepts both
    sel = TpuBackend._select(an, min_saved_bytes=0, vmem_bytes=1e9)
    assert sel.n_accepted == 2 and sel.accepted_ops == 7
    # a VMEM too small for the big chain's working set rejects it even
    # though it clears the threshold (workset = in + out + saved/2)
    assert big.workset_bytes == (64 + 64 + 256) * KB
    sel = TpuBackend._select(an, min_saved_bytes=0,
                             vmem_bytes=big.workset_bytes - 1)
    assert sel.n_accepted == 1 and sel.saved_bytes == small.saved_bytes


# ------------------------------------------------------------- end-to-end
def test_tpu_sweep_end_to_end(tpu_engine):
    tpus = [TpuOption(TPU_PRESETS[c], t)
            for c in ("v5e", "v4") for t in (16 * KB, 256 * KB)]
    space = SweepSpace(workloads=ARCHS2, tpus=tpus)
    results = tpu_engine.run(space)
    assert len(results) == 8
    st = results.stats
    # one jaxpr/HLO analysis per workload; one fusion selection per
    # (workload, threshold) — chips share both layers (pricing-only)
    assert st["trace_builds"] == 2
    assert st["offload_builds"] == 4
    for r in results:
        assert r.backend == "tpu"
        assert r.tech == "tpu" and r.cim_levels == "VMEM"
        assert 0.0 <= r.macr <= 1.0
        assert r.speedup >= 1.0 and r.energy_improvement >= 1.0
        assert r.base_energy_pj > r.cim_energy_pj or r.macr == 0.0
        assert r.n_candidates > 0
    # fusion aggressiveness is monotone: a higher threshold never saves
    # more traffic than a lower one (same workload, same chip)
    by = {(r.workload, r.cache, r.cim_set): r for r in results}
    for w in ARCHS2:
        for chip in ("v5e", "v4"):
            assert (by[(w, chip, "thr16K")].macr
                    >= by[(w, chip, "thr256K")].macr)
    # re-running does zero analysis work (per-run counter deltas)
    again = tpu_engine.run(space)
    assert again.stats["trace_builds"] == 0
    assert again.stats["offload_builds"] == 0
    assert [r.energy_improvement for r in again] == \
        [r.energy_improvement for r in results]


def test_vmem_scale_gates_selection(tpu_engine):
    """A VMEM scaled to nothing rejects every candidate: the point prices
    as the unfused baseline (macr 0, improvement exactly 1.0)."""
    opt = TpuOption(TPU_PRESETS["v5e"], 16 * KB, vmem_scale=1e-9)
    space = SweepSpace(workloads=(ARCHS2[1],), tpus=(opt,))
    (rec,) = tpu_engine.run(space).records
    assert rec.macr == 0.0
    assert rec.energy_improvement == 1.0 and rec.speedup == 1.0


def test_tpu_records_report_and_pareto(tpu_engine):
    tpus = [TpuOption(TPU_PRESETS["v5e"], t) for t in (16 * KB, 256 * KB)]
    results = tpu_engine.run(SweepSpace(workloads=(ARCHS2[0],), tpus=tpus))
    md = results.to_markdown(columns=("workload", "cache", "cim_set",
                                      "energy_improvement", "speedup"))
    assert "thr16K" in md and "Pareto frontier" in md
    front = results.pareto(("energy_improvement", "speedup"))
    assert front and all(r.backend == "tpu" for r in front)


# ------------------------------------------------------------- neighbors
def test_tpu_neighbors_single_knob_moves():
    chips = [TPU_PRESETS[c] for c in ("v5e", "v4", "v5p")]
    thrs = [16 * KB, 64 * KB, 256 * KB]
    grid = [TpuOption(c, t) for c in chips for t in thrs]
    mid = TpuOption(chips[1], thrs[1])
    nbs = tpu_neighbors(mid, grid)
    # exactly one knob per move: adjacent chips at the same threshold,
    # adjacent thresholds on the same chip
    assert {(n.chip.name, n.min_saved_bytes) for n in nbs} == {
        (chips[0].name, thrs[1]), (chips[2].name, thrs[1]),
        (chips[1].name, thrs[0]), (chips[1].name, thrs[2])}
    corner = TpuOption(chips[0], thrs[0])
    assert len(tpu_neighbors(corner, grid)) == 2
    # sparse universes stay sparse: undeclared combinations never appear
    sparse = [TpuOption(chips[0], thrs[0]), TpuOption(chips[1], thrs[1])]
    assert tpu_neighbors(TpuOption(chips[0], thrs[0]), sparse) == []
    assert tpu_neighbors(None, grid) == []
    # ...and the full-point neighborhood emits them as tpu-axis moves
    from repro.dse import neighborhood
    space = SweepSpace(workloads=(ARCHS2[0],), tpus=tuple(grid))
    point = space.points()[4]                      # the mid option
    moves = neighborhood(point, space)
    assert {m.tpu for m in moves if m.tpu != point.tpu} == set(nbs)


# ------------------------------------------------- adaptive (acceptance)
def test_adaptive_tpu_matches_exhaustive_with_fewer_points(tpu_engine):
    """AdaptiveDSE over the TPU space reproduces the exhaustive
    per-workload Pareto frontier at fewer priced points."""
    tpus = [TpuOption(TPU_PRESETS[c], t)
            for c in ("v5e", "v4", "v5p")
            for t in (8 * KB, 32 * KB, 128 * KB, 512 * KB)]
    space = SweepSpace(workloads=ARCHS2, tpus=tpus)
    exhaustive = tpu_engine.run(space)
    adaptive = AdaptiveDSE(space, engine=tpu_engine).run()

    def ident(rec):
        return (rec.workload, rec.cache, rec.cim_set)

    assert ({ident(r) for r in adaptive.frontier}
            == {ident(r) for r in exhaustive.pareto()})
    assert adaptive.n_priced < len(space)
    assert adaptive.rounds[-1].stable or adaptive.n_priced == len(space)
    # refinement rounds reused the warmed analyses: zero builds anywhere
    assert all(r.stats.get("trace_builds", 0) == 0
               for r in adaptive.rounds)


# ---------------------------------------------------------------- protocol
def test_default_backend_is_cim():
    eng = DSEEngine()
    assert isinstance(eng.backend, CimBackend)
    (rec,) = eng.run(SweepSpace(workloads=("NB",))).records
    assert rec.backend == "cim"


def test_backends_pickle_roundtrip():
    """Backends ride to spawned process workers: they must pickle, and
    equal-by-value copies must behave identically."""
    for b in (CimBackend(), TpuBackend(), TpuBackend(batch=4, seq_len=16)):
        clone = pickle.loads(pickle.dumps(b))
        assert clone == b and clone.name == b.name
    opt = TpuOption(TPU_PRESETS["v5p"], 64 * KB, vmem_scale=0.25)
    assert pickle.loads(pickle.dumps(opt)) == opt
    sel = TpuSelection(1, 2, 3, 4, 5.0)
    assert pickle.loads(pickle.dumps(sel)) == sel
