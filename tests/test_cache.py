"""Cache-hierarchy simulator: LRU semantics, level-of-service, writebacks,
MSHR merging — including a hypothesis property test against a brute-force
reference LRU model."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.cache import (LINE, AccessResult, CacheConfig, CacheHierarchy)


def _tiny():
    return CacheHierarchy((CacheConfig("L1", 4 * LINE, 2, banks=2),
                           CacheConfig("L2", 16 * LINE, 4)))


def test_cold_miss_then_hit():
    h = _tiny()
    r1 = h.access(0x1000, False)
    assert r1.level == "MEM" and not r1.hit
    r2 = h.access(0x1008, False)                   # same line
    assert r2.level == "L1" and r2.hit


def test_lru_eviction_to_l2():
    h = _tiny()
    # L1: 2 sets x 2 ways; lines mapping to set 0: line % 2 == 0
    lines = [0, 2, 4]                              # 3 lines -> one eviction
    for ln in lines:
        h.access(ln * LINE, False)
    # line 0 was LRU -> evicted from L1, still in L2
    r = h.access(0, False)
    assert r.level == "L2"


def test_writeback_dirty_victim():
    h = _tiny()
    h.access(0, True)                              # dirty line 0 (set 0)
    h.access(2 * LINE, False)
    h.access(4 * LINE, False)                      # evicts dirty line 0
    assert h.levels[0].writebacks == 1


def test_residency_and_banks():
    h = _tiny()
    h.access(0x40, False)
    assert h.residency(0x40) == "L1"
    assert h.residency(0x9999999) == "MEM"
    b0 = h.bank_of(0 * LINE, "L1")
    b1 = h.bank_of(1 * LINE, "L1")
    assert b0 != b1                                # interleaved banks


def test_mshr_merge():
    # 2 sets x 1 way: lines 0 and 2 conflict in set 0
    h2 = CacheHierarchy((CacheConfig("L1", 2 * LINE, 1, mshrs=4),))
    h2.access(0, False)                             # miss, MSHR entry line 0
    h2.access(2 * LINE, False)                      # conflict-evicts line 0
    r = h2.access(0, False)                         # misses again
    assert r.level == "MEM" and r.mshr              # merged into MSHR entry


class _RefLRU:
    """Brute-force fully-parameterized single-level LRU reference."""

    def __init__(self, n_sets, assoc):
        self.n_sets, self.assoc = n_sets, assoc
        self.sets = [[] for _ in range(n_sets)]

    def access(self, line):
        s = self.sets[line % self.n_sets]
        if line in s:
            s.remove(line)
            s.append(line)
            return True
        if len(s) >= self.assoc:
            s.pop(0)
        s.append(line)
        return False


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 31), min_size=1, max_size=200),
       st.sampled_from([(2, 2), (4, 2), (2, 4)]))
def test_property_l1_matches_reference_lru(lines, shape):
    n_sets, assoc = shape
    h = CacheHierarchy((CacheConfig("L1", n_sets * assoc * LINE, assoc),))
    ref = _RefLRU(n_sets, assoc)
    for ln in lines:
        got = h.access(ln * LINE, False)
        exp_hit = ref.access(ln)
        assert (got.level == "L1") == exp_hit
    st_ = h.stats()["L1"]
    assert st_["hits"] + st_["misses"] == len(lines)


# ----------------------------------------------------------------------
# batched replay memoization (PR-7 satellite): one kernel launch serves
# every geometry of a sweep through AnalysisCache.replay_group
# ----------------------------------------------------------------------
def test_replay_group_batches_once():
    from repro.core import accel
    from repro.dse.engine import AnalysisCache
    from repro.dse.space import CacheOption

    cache = AnalysisCache()
    caches = [CacheOption.of(n)
              for n in ("32K+256K", "64K+256K", "64K+2M")]
    with accel.use_backend("jax"):
        cache.replay_group("NB", caches)
        # all three geometries built, ONE batched replay launch
        assert cache.trace_builds == 3
        assert cache.trace_hits == 0
        assert cache.replay_batches == 1
        # the per-point path now memo-hits every geometry
        for c in caches:
            cache.trace("NB", c)
        assert cache.trace_builds == 3
        assert cache.trace_hits == 3
        # a repeated sweep's warm pass does no replay work at all
        cache.replay_group("NB", caches)
        assert cache.trace_builds == 3
        assert cache.replay_batches == 1
    assert cache.stats()["replay_batches"] == 1


def test_replay_group_numpy_backend_degrades_to_trace():
    from repro.core import accel
    from repro.dse.engine import AnalysisCache
    from repro.dse.space import CacheOption

    cache = AnalysisCache()
    caches = [CacheOption.of(n) for n in ("32K+256K", "64K+256K")]
    with accel.use_backend("numpy"):
        cache.replay_group("NB", caches)
    assert cache.trace_builds == 2
    assert cache.replay_batches == 0        # no batched launch on numpy
