"""Columnar-vs-row path equivalence (the PR-5 tentpole's safety net).

The columnar trace core re-derives everything the object-based pipeline
used to build incrementally — RUT/IHT, the producer index, the flow maps,
the IDG forest, the candidate partition — vectorized from the columns.
These tests drive random small jaxpr programs (hypothesis, or the conftest
fallback sampler) plus the three Fig. 4 pattern variants through BOTH
paths and require identical results:

  * the ``Inst`` row views are faithful to the columns, and reconstructing
    RUT/IHT with the original incremental commit-time algorithm from those
    rows matches the vectorized tables;
  * the flow index (reg consumers / stores / load sources) matches the
    original object-at-a-time construction;
  * IDG forests have identical shapes, node seqs, and leaf payloads;
  * Algorithm 1 returns identical candidate sets, claimed sets, reshapes,
    and (approx-equal) priced reports through both paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import trace_program
from repro.core.columnar import ColumnarTrace
from repro.core.idg import IDGBuilder, _build_flow_rows, build_flow_index
from repro.core.isa import CIM_SET_STT, SRC_IMM, SRC_REG
from repro.core.offload import OffloadConfig, select_candidates
from repro.core.profiler import profile_system
from repro.core.reshape import reshape

# ----------------------------------------------------------------------
# the three Fig. 4 pattern variants as explicit programs
# ----------------------------------------------------------------------
def _variant_a(n):          # Load-Load-OP-Store: both operands from memory
    a = jnp.arange(n, dtype=jnp.int32)
    b = jnp.arange(n, dtype=jnp.int32) * 2
    return (lambda a, b: (a + b) ^ a), (a, b)


def _variant_b(n):          # Load-Imm-OP-Store: literal lowers to immediate
    a = jnp.arange(n, dtype=jnp.int32)
    return (lambda a: (a & 7) + 3), (a,)


def _variant_c(n):          # OP-(reg)-OP chains: reduction accumulators
    a = jnp.asarray(np.random.default_rng(0).integers(0, 50, n), jnp.int32)
    return (lambda a: jnp.sum((a + 1) ^ a)), (a,)


FIG4_VARIANTS = (_variant_a, _variant_b, _variant_c)


def _rebuild_rut_iht_incremental(rows, n_regs):
    """The original probe algorithm: RUT/IHT built at commit time."""
    rut = {r: [] for r in range(n_regs + 1)}
    iht = {}
    for inst in rows:
        srcs_regs = [v for t, v in inst.srcs if t == SRC_REG]
        iht[inst.seq] = [(r, len(rut[r]) - 1) for r in srcs_regs]
        if inst.dst is not None:
            rut[inst.dst].append(inst.seq)
    return rut, iht


def _forest_shape(forest):
    """Comparable structure of an IDG forest: node seqs + leaf payloads."""
    def node_shape(node):
        out = [("op", node.inst.seq)]
        for kind, payload in node.children:
            if kind == "node":
                out.append(("sub", node_shape(payload)))
            elif kind in ("load", "memval"):
                out.append((kind, payload.seq))
            else:
                out.append((kind, payload))
        return out

    return [node_shape(t) for t in forest]


def _cand_tuple(c):
    return (c.root_seq, tuple(c.op_seqs), tuple(c.op_classes),
            tuple(c.load_seqs), tuple(c.store_seqs), c.level, c.bank,
            c.moves, c.internal_edges, c.added_loads, c.memval_leaves,
            c.dram_fills)


def _check_equivalence(fn, args, cfg=OffloadConfig()):
    tr = trace_program(fn, *args)
    ct = tr.trace
    assert isinstance(ct, ColumnarTrace)
    rows = list(ct)                                    # materialized row path

    # --- row views faithful to the columns ------------------------------
    for seq, inst in enumerate(rows):
        assert inst.seq == seq
        assert inst.op == ct.op[seq] or True           # decoded below
    from repro.core.isa import LEVELS, OPS, UNITS
    for seq in (0, len(rows) // 2, len(rows) - 1):
        inst = rows[seq]
        assert inst.op == OPS[ct.op[seq]]
        assert inst.unit == UNITS[ct.unit[seq]]
        assert inst.level == LEVELS[ct.level[seq]]
        assert (inst.dst if inst.dst is not None else -1) == ct.dst[seq]

    # --- RUT/IHT: vectorized == incremental over the same stream --------
    ref_rut, ref_iht = _rebuild_rut_iht_incremental(rows, ct.n_regs)
    assert tr.rut == ref_rut
    assert tr.iht == ref_iht

    # --- flow maps: vectorized == object-at-a-time ----------------------
    fast = build_flow_index(ct)
    slow = _build_flow_rows(rows, ref_rut, ref_iht)
    assert fast.reg_consumers == slow.reg_consumers
    assert fast.store_of == slow.store_of
    assert fast.load_source == slow.load_source
    assert fast.value_loads == slow.value_loads

    # --- IDG forests ----------------------------------------------------
    fast_forest = IDGBuilder(ct).build_forest(cfg.cim_set)
    slow_forest = IDGBuilder(rows, ref_rut, ref_iht).build_forest(cfg.cim_set)
    assert _forest_shape(fast_forest) == _forest_shape(slow_forest)

    # --- Algorithm 1: candidates, claimed, reshape, pricing -------------
    fast_res = select_candidates(ct, cfg=cfg)
    slow_res = select_candidates(rows, ref_rut, ref_iht, cfg)
    assert [_cand_tuple(c) for c in fast_res.candidates] == \
        [_cand_tuple(c) for c in slow_res.candidates]
    assert fast_res.claimed == slow_res.claimed
    fast_rs = reshape(ct, fast_res)
    slow_rs = reshape(rows, slow_res)
    assert fast_rs.host_seqs == slow_rs.host_seqs
    assert fast_rs.cim_groups == slow_rs.cim_groups
    assert fast_rs.moves == slow_rs.moves
    assert fast_rs.added_loads == slow_rs.added_loads
    assert fast_rs.dram_fills == slow_rs.dram_fills

    rep_fast = profile_system(tr, cfg, offload=fast_res, reshaped=fast_rs)
    rep_slow = profile_system(tr, cfg, offload=slow_res, reshaped=slow_rs)
    assert rep_fast.energy_improvement == \
        pytest.approx(rep_slow.energy_improvement)
    assert rep_fast.speedup == pytest.approx(rep_slow.speedup)
    assert rep_fast.macr == rep_slow.macr
    return tr


# ---------------------------------------------------------------- fig. 4
@pytest.mark.parametrize("variant", FIG4_VARIANTS,
                         ids=["load_load_op", "load_imm_op", "reg_chain"])
def test_fig4_variants_equivalent(variant):
    fn, args = variant(24)
    tr = _check_equivalence(fn, args)
    kinds = set()
    for inst in tr.trace:
        if inst.op in ("add", "xor", "and"):
            tags = tuple(t for t, _ in inst.srcs)
            if tags == (SRC_REG, SRC_REG):
                kinds.add("reg_reg")
            if SRC_IMM in tags:
                kinds.add("imm")
    assert kinds                                   # the pattern is present


def test_same_bank_config_equivalent():
    """Placement-constrained configs run the generic single-pass path on
    columns — still identical to the row path."""
    fn, args = _variant_a(32)
    _check_equivalence(fn, args, OffloadConfig(require_same_bank=True))
    _check_equivalence(fn, args, OffloadConfig(allow_cross_level=False,
                                               cim_levels=("L1",)))


# ------------------------------------------------------- random programs
_OPS = ("add", "xor", "and", "or", "sub", "max")


@settings(max_examples=12, deadline=None)
@given(st.integers(4, 40), st.integers(0, 6), st.sampled_from(_OPS),
       st.sampled_from(_OPS))
def test_property_random_programs_equivalent(n, seed, op1, op2):
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.integers(0, 100, (n,)), jnp.int32)
    b = jnp.asarray(r.integers(1, 100, (n,)), jnp.int32)
    f1 = getattr(jnp, {"add": "add", "xor": "bitwise_xor",
                       "and": "bitwise_and", "or": "bitwise_or",
                       "sub": "subtract", "max": "maximum"}[op1])
    f2 = getattr(jnp, {"add": "add", "xor": "bitwise_xor",
                       "and": "bitwise_and", "or": "bitwise_or",
                       "sub": "subtract", "max": "maximum"}[op2])

    def prog(a, b):
        c = f1(a, b)
        d = f2(c, a)
        return jnp.sum(d) + jnp.max(c)

    _check_equivalence(prog, (a, b))


@settings(max_examples=6, deadline=None)
@given(st.integers(3, 12), st.integers(0, 4))
def test_property_scan_programs_equivalent(n, seed):
    r = np.random.default_rng(seed + 100)
    x = jnp.asarray(r.integers(0, 20, (n,)), jnp.int32)

    def prog(x):
        def body(c, t):
            c = c + (t ^ c)
            return c, c
        return jax.lax.scan(body, jnp.int32(1), x)

    _check_equivalence(prog, (x,))


# ----------------------------------------------------- key-lock pruning
def test_analysis_cache_key_locks_pruned():
    """Satellite: completed layers release their build locks — long
    adaptive runs must not leak one threading.Lock per analysis key."""
    from repro.dse import AnalysisCache
    from repro.dse.space import CacheOption
    cache = AnalysisCache()
    cache.trace("NB", CacheOption.of("32K+256K"))
    cache.offload("NB", CacheOption.of("32K+256K"), OffloadConfig())
    cache.artifact(1, ("blob", "x"), lambda: 42)
    assert cache._key_locks == {}
    # and the artifacts really are memoized (hits, not rebuilds)
    cache.trace("NB", CacheOption.of("32K+256K"))
    assert cache.trace_hits >= 1 and cache._key_locks == {}
