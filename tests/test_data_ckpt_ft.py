"""Data pipeline determinism/seekability, checkpoint atomicity + resume,
fault-tolerant runner recovery, straggler monitor."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (CheckpointManager, latest_step,
                                   load_checkpoint, save_checkpoint)
from repro.data.pipeline import (DataConfig, ShardedTokenPipeline,
                                 write_synthetic_corpus)
from repro.ft.manager import FaultTolerantRunner, StragglerMonitor


# ------------------------------------------------------------------- data
def test_pipeline_pure_in_step():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
    p1, p2 = ShardedTokenPipeline(cfg), ShardedTokenPipeline(cfg)
    for step in (0, 7, 123):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch_at(0)["tokens"],
                              p1.batch_at(1)["tokens"])


def test_pipeline_labels_shifted():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
    b = ShardedTokenPipeline(cfg).batch_at(3)
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)


def test_corpus_host_sharding(tmp_path):
    write_synthetic_corpus(str(tmp_path), vocab_size=50, n_tokens=4000,
                           n_shards=4)
    cfgs = [DataConfig(vocab_size=50, seq_len=8, global_batch=4,
                       corpus_dir=str(tmp_path), host_id=h, num_hosts=2)
            for h in range(2)]
    pipes = [ShardedTokenPipeline(c) for c in cfgs]
    b0, b1 = pipes[0].batch_at(5), pipes[1].batch_at(5)
    assert b0["tokens"].shape == (2, 8)             # host slice of global 4
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# ------------------------------------------------------------------- ckpt
def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v, jnp.bfloat16)},
            "step": jnp.asarray(int(v), jnp.int32)}


def test_checkpoint_roundtrip_bf16(tmp_path):
    s = _state(3.0)
    path = save_checkpoint(str(tmp_path), 7, s)
    got = load_checkpoint(path, jax.tree_util.tree_map(np.asarray, _state()))
    np.testing.assert_array_equal(np.asarray(got["params"]["w"], np.float32),
                                  np.full((4, 4), 3.0, np.float32))
    assert int(got["step"]) == 3


def test_no_tmp_files_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, _state())
    assert not list(pathlib.Path(tmp_path).glob("*.tmp"))


def test_manager_retention_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=1)
    for s in range(5):
        mgr.maybe_save(s, _state(float(s)))
    mgr.wait()
    assert latest_step(str(tmp_path)) == 4
    files = sorted(pathlib.Path(tmp_path).glob("*.npz"))
    assert len(files) == 2                           # retention
    step, got = mgr.restore_latest(_state())
    assert step == 4
    np.testing.assert_allclose(np.asarray(got["params"]["w"], np.float32), 4.0)


# --------------------------------------------------------------------- ft
def test_runner_recovers_from_injected_failure(tmp_path):
    calls = []

    def step_fn(state, batch):
        s = dict(state, step=state["step"] + 1)
        calls.append(int(state["step"]))
        return s, {"loss": 1.0 / (1 + float(state["step"]))}

    runner = FaultTolerantRunner(str(tmp_path), save_every=3)
    state = {"step": jnp.asarray(0, jnp.int32)}
    final, report = runner.run(state, 12, step_fn, lambda i: None,
                               log_every=0, fail_at=7)
    assert report.failures_recovered == 1
    assert int(final["step"]) == 12
    # resumed from the last checkpoint before the failure (step 6)
    assert 6 in calls or 7 in calls


def test_runner_auto_resume_fresh_process(tmp_path):
    def step_fn(state, batch):
        return dict(state, step=state["step"] + 1), {"x": 0.0}

    r1 = FaultTolerantRunner(str(tmp_path), save_every=2)
    s, _ = r1.run({"step": jnp.asarray(0, jnp.int32)}, 6, step_fn,
                  lambda i: None, log_every=0)
    # second runner: resumes, runs only the remaining steps
    r2 = FaultTolerantRunner(str(tmp_path), save_every=2)
    s2, rep = r2.run({"step": jnp.asarray(0, jnp.int32)}, 10, step_fn,
                     lambda i: None, log_every=0)
    assert rep.resumed_from == 5
    assert int(s2["step"]) == 10


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(window=16, threshold=2.0)
    flagged = []
    for i in range(20):
        t = 0.1 if i != 15 else 0.5
        if m.observe(i, t):
            flagged.append(i)
    assert flagged == [15]
    assert m.report()["n_straggles"] == 1
