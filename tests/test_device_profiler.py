"""Device model (Table III + Fig. 11) and system profiler invariants."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CIM_SET_STT, FEFET, L1_64K, L2_256K, L1_32K, L2_2M,
                        OffloadConfig, SRAM, profile_system, trace_program)
from repro.core.cache import CacheConfig
from repro.core.device_model import TECHS


# --------------------------------------------------------------- Table III
TABLE3 = {
    ("sram", "L1"): {"read": 61.0, "CiM-OR": 71.0, "CiM-AND": 72.0,
                     "CiM-XOR": 79.0, "CiM-ADD": 79.0},
    ("sram", "L2"): {"read": 314.0, "CiM-OR": 341.0, "CiM-AND": 344.0,
                     "CiM-XOR": 365.0, "CiM-ADD": 365.0},
    ("fefet", "L1"): {"read": 34.0, "CiM-OR": 35.0, "CiM-AND": 88.0,
                      "CiM-XOR": 105.0, "CiM-ADD": 105.0},
    ("fefet", "L2"): {"read": 70.0, "CiM-OR": 72.0, "CiM-AND": 146.0,
                      "CiM-XOR": 205.0, "CiM-ADD": 205.0},
}


@pytest.mark.parametrize("tech", ["sram", "fefet"])
@pytest.mark.parametrize("level,cfg", [("L1", L1_64K), ("L2", L2_256K)])
def test_table3_reproduced_exactly(tech, level, cfg):
    """The scaling law must pass through the published anchors verbatim."""
    got = TECHS[tech].table3_row(cfg)
    for op, exp in TABLE3[(tech, level)].items():
        assert abs(got[op] - exp) < 0.51, (tech, level, op, got[op], exp)


def test_scaling_monotonic_in_size():
    """Paper finding (iii): larger arrays -> higher per-op CiM energy."""
    for tech in TECHS.values():
        for op in ("read", "CiM-ADD", "CiM-XOR"):
            sizes = [32 * 1024, 64 * 1024, 256 * 1024, 2 * 1024 * 1024]
            es = [tech.energy(op, CacheConfig("LX", s, 4)) for s in sizes]
            assert all(a < b for a, b in zip(es, es[1:])), (tech.tech, op, es)


def test_fig11_latency_relations():
    assert SRAM.latency("CiM-OR", "L1") == SRAM.latency("read", "L1")
    assert SRAM.latency("CiM-ADD", "L1") == SRAM.latency("read", "L1") + 4
    for op in ("read", "CiM-OR", "CiM-ADD"):
        assert FEFET.latency(op, "L2") <= SRAM.latency(op, "L2")


# --------------------------------------------------------------- profiler
def _trace():
    a = jnp.arange(128, dtype=jnp.int32)
    b = jnp.arange(128, dtype=jnp.int32) * 3
    return trace_program(lambda a, b: jnp.sum((a + b) ^ b), a, b)


def test_profiler_accounting_consistency():
    tr = _trace()
    rep = profile_system(tr)
    for eb in (rep.base, rep.cim):
        assert eb.total == pytest.approx(eb.processor + eb.caches)
        assert eb.total_with_dram == pytest.approx(eb.total + eb.dram)
    assert rep.base_cycles > 0 and rep.cim_cycles > 0
    assert 0.0 <= rep.macr <= 1.0
    assert rep.macr == pytest.approx(rep.macr_l1 + rep.macr_other)
    # Table VI ratio rows sum to 1 by construction
    assert rep.processor_ratio + rep.cache_ratio == pytest.approx(1.0)


def test_cim_beneficial_on_bitwise_program():
    rep = profile_system(_trace())
    assert rep.energy_improvement > 1.0
    assert rep.speedup > 1.0
    assert rep.n_cim_ops > 0


def test_empty_cimset_is_identity():
    tr = _trace()
    rep = profile_system(tr, OffloadConfig(cim_set=frozenset()))
    assert rep.n_cim_ops == 0
    assert rep.energy_improvement == pytest.approx(1.0)
    assert rep.speedup == pytest.approx(1.0)


def test_l2_only_not_better_than_both():
    """Paper §VI-D: L2-only CiM gives lower improvement than L1(+L2)."""
    tr = _trace()
    both = profile_system(tr, OffloadConfig(cim_levels=("L1", "L2")))
    l2 = profile_system(tr, OffloadConfig(cim_levels=("L2",)))
    assert l2.energy_improvement <= both.energy_improvement + 1e-9


def test_techs_differ():
    tr = _trace()
    rs = profile_system(tr, tech="sram")
    rf = profile_system(tr, tech="fefet")
    assert rs.cim.caches != pytest.approx(rf.cim.caches)
